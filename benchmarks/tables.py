"""Paper-table benchmarks (Tables II-III, Figures 5-9 analogues).

Each ``run(fast)`` returns CSV rows: (name, us_per_call, derived).
"""
from __future__ import annotations

import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ART, get_surrogate, timeit
from repro.apps import ALL_APPS, miniweather


# ------------------------------------------------- Table II: code impact --
def loc_table(fast=False):
    """Integration cost: HPAC-ML statements per benchmark (Table II)."""
    rows = []
    for name, app in ALL_APPS.items():
        src = inspect.getsource(app)
        total = len([l for l in src.splitlines() if l.strip()])
        functors = src.count("tensor_functor(")
        regions = src.count("approx_ml(")
        # API statements == the paper's "directives": functor decls + region
        directives = functors + regions
        rows.append((f"loc_table/{name}", 0.0,
                     f"total_loc={total};hpacml_statements={directives};"
                     f"functors={functors};regions={regions}"))
    return rows


# -------------------------------------- Table III: data collection cost --
def collect_overhead(fast=False):
    n = 256 if fast else 1024
    rows = []
    for name, app in ALL_APPS.items():
        # warm both paths first (the paper's Table III times steady-state
        # runs, not first-call jit traces)
        if name == "miniweather":
            s = app.init_state()
            t_plain = timeit(jax.jit(lambda s: app.timestep(s)), s, reps=3)
            region = app.make_region(mode="collect",
                                     database=str(ART / "bench_db" / name))
            region(state=s)
            t0 = time.perf_counter()
            region(state=s)
            t_col = time.perf_counter() - t0
        elif name == "particlefilter":
            frames, _ = app.make_video(64 if fast else 128)
            t_plain = timeit(lambda f: app.track(f), frames, reps=3)
            region = app.make_region(frames.shape[0], mode="collect",
                                     database=str(ART / "bench_db" / name))
            flat = frames.reshape(frames.shape[0], -1)
            region(frames=flat)
            t0 = time.perf_counter()
            region(frames=flat)
            t_col = time.perf_counter() - t0
        else:
            x = app.make_inputs(n)
            key0 = {"minibude": "poses", "binomial": "opts", "bonds": "bonds"}[name]
            t_plain = timeit(lambda x: app.accurate(x)["out"], x, reps=3)
            region = app.make_region(n, mode="collect",
                                     database=str(ART / "bench_db" / name))
            region(**{key0: x})
            t0 = time.perf_counter()
            region(**{key0: x})
            t_col = time.perf_counter() - t0
        region.db.flush()
        g = region.db.group(name)
        size_mb = sum(f.stat().st_size for f in g.dir.glob("chunk_*.npz")) / 1e6
        rows.append((f"collect_overhead/{name}", t_plain * 1e6,
                     f"plain_s={t_plain:.4f};with_collect_s={t_col:.4f};"
                     f"overhead_x={t_col/max(t_plain,1e-9):.2f};"
                     f"data_mb={size_mb:.2f}"))
    return rows


# ------------------------------------ Fig 5: speedup + QoI error, 5 apps --
def speedup_error(fast=False):
    rows = []
    n_test = 256 if fast else 512
    for name, app in ALL_APPS.items():
        mp = get_surrogate(name, app, n=512 if fast else 1024,
                           epochs=12 if fast else 25,
                           outer=3 if fast else 5)
        if name == "miniweather":
            s = app.init_state()
            region = app.make_region(mode="infer", model=mp)
            t_acc = timeit(jax.jit(app.timestep), s, reps=3)
            f_ml = lambda s: region(state=s)["state"]
            t_ml = timeit(f_ml, s, reps=3)
            err = app.qoi_error(app.timestep(s), f_ml(s))
            metric = "rmse"
        elif name == "particlefilter":
            frames, truth = app.make_video(n_test, seed=5)
            region = app.make_region(n_test, mode="infer", model=mp)
            t_acc = timeit(lambda f: app.track(f), frames, reps=3)
            flat = frames.reshape(n_test, -1)
            f_ml = lambda f: region(frames=f)["loc"]
            t_ml = timeit(f_ml, flat, reps=3)
            err = app.qoi_error(truth, f_ml(flat))
            err_orig = app.qoi_error(truth, app.track(frames))
            metric = f"rmse(orig_algo={err_orig:.3f})"
        else:
            x = app.make_inputs(n_test, seed=5)
            key0 = {"minibude": "poses", "binomial": "opts", "bonds": "bonds"}[name]
            region = app.make_region(n_test, mode="infer", model=mp)
            t_acc = timeit(lambda x: app.accurate(x)["out"], x, reps=3)
            f_ml = lambda x: region(**{key0: x})["out"]
            t_ml = timeit(f_ml, x, reps=3)
            err = app.qoi_error(app.accurate(x)["out"], f_ml(x))
            metric = "mape%" if name == "minibude" else "rmse"
        rows.append((f"speedup_error/{name}", t_ml * 1e6,
                     f"speedup_x={t_acc/max(t_ml,1e-9):.2f};"
                     f"qoi_{metric}={err:.4f}"))
    return rows


# ----------------------- Fig 6: bridge vs inference runtime breakdown ----
def runtime_breakdown(fast=False):
    rows = []
    n = 512
    for name in ("minibude", "binomial", "bonds"):
        app = ALL_APPS[name]
        key0 = {"minibude": "poses", "binomial": "opts", "bonds": "bonds"}[name]
        mp = get_surrogate(name, app, n=512, epochs=12, outer=3)
        x = app.make_inputs(n, seed=6)
        region = app.make_region(n, mode="infer", model=mp)
        t_bridge = timeit(jax.jit(lambda x: region.bridge_in({key0: x})), x,
                          reps=5)
        eng = region.engine()
        X = region.bridge_in({key0: x})
        Xb = X.reshape((-1,) + tuple(eng.spec["in_shape"][1:])).astype(jnp.float32)
        t_inf = timeit(lambda X: eng(X), Xb, reps=5)
        frac = t_bridge / max(t_bridge + t_inf, 1e-12)
        rows.append((f"runtime_breakdown/{name}", (t_bridge + t_inf) * 1e6,
                     f"bridge_us={t_bridge*1e6:.1f};infer_us={t_inf*1e6:.1f};"
                     f"bridge_frac={frac*100:.1f}%"))
    return rows


# ----------------------------------- Fig 9d: MiniWeather interleaving ----
def interleave(fast=False):
    app = miniweather
    mp = get_surrogate("miniweather", app, epochs=12 if fast else 25,
                       outer=3)
    region = app.make_region(mode="predicated", model=mp)
    s0 = app.init_state()
    horizon = 16 if fast else 32
    ref = app.run(s0, horizon)
    t_acc = timeit(jax.jit(app.timestep), s0, reps=3)
    rows = []
    for (na, ns) in [(1, 0), (3, 1), (1, 1), (1, 3), (0, 1)]:
        out = app.run(s0, horizon, region=region, interleave=(na, ns))
        err = app.qoi_error(ref, out)
        cyc = na + ns
        est_speedup = cyc / (na + ns * 0.2) if cyc else 1.0
        rows.append((f"interleave/acc{na}_ml{ns}", t_acc * 1e6,
                     f"rmse@{horizon}={err:.5f};cycle={na}:{ns}"))
    return rows


# -------------------------------- Fig 7/8: Pareto sweeps (reduced BO) ----
def pareto_sweep(fast=False):
    from repro.nas.nested import nested_search
    from repro.core.database import SurrogateDB
    rows = []
    apps = ["binomial"] if fast else ["binomial", "minibude"]
    for name in apps:
        app = ALL_APPS[name]
        get_surrogate(name, app, n=512, epochs=10, outer=3)  # ensures db
        db = SurrogateDB(ART / "db" / name)
        res = nested_search(app, db.group(name), outer_iters=4 if fast else 8,
                            inner_iters=0, epochs=10, verbose=False)
        for i in res["pareto"]:
            t = res["trials"][i]
            rows.append((f"pareto/{name}/{i}", t["latency"] * 1e6,
                         f"val_rmse={t['val_rmse']:.4f};arch={t['arch']}"))
    return rows
