"""Summarize dry-run artifacts: pick hillclimb targets, dump tables.

  PYTHONPATH=src python benchmarks/summarize_dryrun.py [--markdown]

``--markdown`` emits the EXPERIMENTS.md roofline table (one row per
compiled cell: dominant bottleneck, step time, useful-FLOPs fraction,
per-chip memory).
"""
import json
import pathlib
import sys

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def markdown_table():
    recs = [json.loads(p.read_text())
            for p in sorted(ART.glob("*__baseline.json"))]
    lines = ["| arch | shape | mesh | status | dominant | t_step (ms) | "
             "useful FLOPs | MFU bound | resident GB/chip | coll GB/dev |",
             "|---|---|---|---|---|---:|---:|---:|---:|---:|"]
    for r in recs:
        status = r.get("status", "?")
        if status != "ok":
            short = status if len(status) < 40 else status[:37] + "..."
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{short} | | | | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {rf['dominant']} | {rf['step_time_s'] * 1e3:.1f} "
            f"| {rf['useful_flops_fraction'] * 100:.0f}% "
            f"| {rf['mfu_bound'] * 100:.1f}% "
            f"| {r['analytic']['est_hbm_per_chip'] / 1e9:.2f} "
            f"| {r['coll_bytes_corrected_per_dev'] / 1e9:.2f} |")
    return "\n".join(lines)


def main():
    if "--markdown" in sys.argv:
        print(markdown_table())
        return
    recs = []
    for p in sorted(ART.glob("*__baseline.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    sp = [r for r in recs if r["mesh"] == "pod16x16"]
    mp = [r for r in recs if r["mesh"] == "pod2x16x16"]
    print(f"{len(sp)} single-pod cells, {len(mp)} multi-pod cells")
    ok = [r for r in sp if r.get("status") == "ok"]
    skip = [r for r in sp if "skipped" in r.get("status", "")]
    fail = [r for r in sp if r.get("status", "").startswith("FAIL")]
    print(f"single-pod: ok={len(ok)} skipped={len(skip)} fail={len(fail)}")
    for r in fail:
        print("  FAIL:", r["arch"], r["shape"], r["status"][:120])
    mp_ok = [r for r in mp if r.get("status") == "ok"]
    mp_fail = [r for r in mp if r.get("status", "").startswith("FAIL")]
    print(f"multi-pod: ok={len(mp_ok)} fail={len(mp_fail)}")
    for r in mp_fail:
        print("  FAIL:", r["arch"], r["shape"], r["status"][:120])

    print("\n== worst useful-FLOPs fraction (roofline candidates) ==")
    rows = sorted((r for r in ok), key=lambda r: r["roofline"]["useful_flops_fraction"])
    for r in rows[:8]:
        rf = r["roofline"]
        print(f"  {r['arch']:22s} {r['shape']:12s} useful="
              f"{rf['useful_flops_fraction']*100:5.1f}% dom={rf['dominant']:10s} "
              f"t={rf['step_time_s']*1e3:9.2f}ms mfu_bound={rf['mfu_bound']*100:5.2f}%")
    print("\n== most collective-bound ==")
    rows = sorted(ok, key=lambda r: -(r["roofline"]["collective_s"]
                                      / max(r["roofline"]["step_time_s"], 1e-12)))
    for r in rows[:8]:
        rf = r["roofline"]
        print(f"  {r['arch']:22s} {r['shape']:12s} coll={rf['collective_s']:8.3f}s "
              f"of t={rf['step_time_s']:8.3f}s dom={rf['dominant']}")
    print("\n== memory fits (analytic resident+activations) ==")
    for r in ok:
        if not r.get("fits_16GB_analytic", True):
            print(f"  OVER: {r['arch']} {r['shape']} "
                  f"{r['analytic']['est_hbm_per_chip']/1e9:.1f}GB")


if __name__ == "__main__":
    main()
