"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only loc_table,...]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import tables
    from benchmarks.kernel_bench import kernel_bench
    from benchmarks.multihost_bench import bench_rows as multihost_rows
    from benchmarks.roofline import roofline_rows
    from benchmarks.serve_bench import serving_throughput
    from benchmarks.tune_bench import tune_rows

    benches = {
        "loc_table": tables.loc_table,                 # paper Table II
        "collect_overhead": tables.collect_overhead,   # paper Table III
        "speedup_error": tables.speedup_error,         # paper Fig 5
        "runtime_breakdown": tables.runtime_breakdown, # paper Fig 6
        "pareto_sweep": tables.pareto_sweep,           # paper Fig 7/8
        "interleave": tables.interleave,               # paper Fig 9d
        "kernel_bench": kernel_bench,                  # Pallas kernels
        "roofline": roofline_rows,                     # §Roofline (dry-run)
        "serve_throughput": serving_throughput,        # repro.serve coalescing
        "tune": tune_rows,                             # repro.tune autotuning
        "multihost": multihost_rows,                   # pod serving (2 procs)
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    ok = True
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            for row in fn(fast=args.fast):
                n, us, derived = row
                print(f"{n},{us:.2f},{derived}", flush=True)
        except Exception as e:
            ok = False
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
