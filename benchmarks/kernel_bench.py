"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — not a performance measurement), so wall-clock rows are taken
from the jnp reference paths; the kernels' TPU value is argued in the
roofline analysis.  Rows still record interpret-mode validation deltas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit


def kernel_bench(fast=False):
    rows = []
    rng = np.random.default_rng(0)

    # stencil gather (data bridge hot path)
    from repro.kernels.stencil_gather.ref import stencil_gather_ref
    x = jnp.asarray(rng.normal(size=(512, 512)).astype(np.float32))
    offs = ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2))
    f = jax.jit(lambda x: stencil_gather_ref(x, offs, 508, 508, origin=(1, 1)))
    t = timeit(f, x, reps=5)
    bytes_moved = 508 * 508 * 5 * 4 * 2
    rows.append(("kernel/stencil_gather_ref_512", t * 1e6,
                 f"gb_s={bytes_moved/t/1e9:.2f}"))

    # fused MLP surrogate inference
    from repro.kernels.fused_mlp.ref import fused_mlp_ref
    ws = [jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(256, 1)).astype(np.float32))]
    bs = [jnp.zeros(256), jnp.zeros(256), jnp.zeros(1)]
    xx = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    f = jax.jit(lambda x: fused_mlp_ref(x, ws, bs, ("relu", "relu", "identity")))
    t = timeit(f, xx, reps=5)
    flops = 2 * 4096 * (64 * 256 + 256 * 256 + 256)
    rows.append(("kernel/fused_mlp_ref_b4096", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # flash attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    S = 256 if fast else 512
    q = jnp.asarray(rng.normal(size=(1, S, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, S, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, S, 2, 64)).astype(np.float32))
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    t = timeit(f, q, k, v, reps=3)
    flops = 4 * S * S * 8 * 64
    rows.append((f"kernel/flash_attention_ref_s{S}", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # rwkv6 chunk
    from repro.kernels.rwkv6_chunk.ref import rwkv6_chunk_ref
    B, T, H, hd = 2, 128, 8, 64
    r, kk, vv = (jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
                 for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32))
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    f = jax.jit(lambda *a: rwkv6_chunk_ref(*a)[0])
    t = timeit(f, r, kk, vv, w, u, s0, reps=3)
    flops = B * T * H * hd * hd * 6
    rows.append((f"kernel/rwkv6_chunk_ref_t{T}", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # interpret-mode validation deltas (correctness, not speed)
    from repro.kernels.flash_attention.flash_attention import flash_attention
    a = flash_attention(q[:, :64], k[:, :64], v[:, :64], causal=True,
                        block_q=32, block_k=32)
    b = flash_attention_ref(q[:, :64], k[:, :64], v[:, :64], causal=True)
    rows.append(("kernel/flash_interpret_maxerr", 0.0,
                 f"err={float(jnp.abs(a-b).max()):.2e}"))
    return rows
