"""Kernel micro-benchmarks + the quantized-tier acceptance gate.

On this CPU container the Pallas kernels run in interpret mode (Python
emulation — not a performance measurement), so wall-clock rows are taken
from the jnp reference paths; the kernels' TPU value is argued in the
roofline analysis.  Rows still record interpret-mode validation deltas.

``--quant-check`` gates the int8 serving tier end to end (see
:func:`quant_check`): per-bundle gate RMSE within budget on real
calibration rows, the engine actually serving the gated int8 path under
``REPRO_QUANT=force``, a >= :data:`QUANT_MIN_SPEEDUP` rows/s win on at
least one bandwidth-bound served shape, and — the part that matters
most — a deliberately mis-calibrated bundle *failing* the gate and
serving f32 bit-identically, with the fail counter incremented.  The
speedup leg follows this file's standing rule: measured wall-clock on
TPU, roofline-priced off-TPU (XLA's CPU int8 dot is slower than its
f32 one, so CPU wall-clock would gate nothing about the TPU tier).
"""
from __future__ import annotations

import os
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit, write_bench_json

#: gated int8 fused_mlp must beat f32 rows/s by at least this factor on
#: >= 1 served shape (HBM-bound regime: weights quarter, io unchanged)
QUANT_MIN_SPEEDUP = 1.5
#: per-bundle RMSE budget as a fraction of the f32 output RMS — the
#: relative form keeps one constant meaningful across apps whose output
#: scales differ by orders of magnitude (option prices vs BUDE energies)
QUANT_BUDGET_REL = 0.03
#: deliberately wrong calibration for the fail-path drill: scales
#: inflated 64x crush every weight into a couple of int8 steps
QUANT_BAD_SCALE = 64.0

#: (in_dim, hidden, hidden, out_dim) per app — the NAS-winner shapes the
#: serving benchmarks use for these bundles
QUANT_APP_SHAPES = (
    ("binomial", (5, 256, 256, 1)),
    ("bonds", (4, 512, 512, 2)),
    ("minibude", (6, 1024, 1024, 1)),
)
#: the bucket the speedup leg prices.  256 rows is the bandwidth-bound
#: serving regime for these nets — the weight stream dominates the
#: roofline (at 1024 rows the f32 compute term takes over and
#: quantizing the weights moves nothing, on the model *or* the chip)
QUANT_BUCKET = 256


def kernel_bench(fast=False):
    rows = []
    rng = np.random.default_rng(0)

    # stencil gather (data bridge hot path)
    from repro.kernels.stencil_gather.ref import stencil_gather_ref
    x = jnp.asarray(rng.normal(size=(512, 512)).astype(np.float32))
    offs = ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2))
    f = jax.jit(lambda x: stencil_gather_ref(x, offs, 508, 508, origin=(1, 1)))
    t = timeit(f, x, reps=5)
    bytes_moved = 508 * 508 * 5 * 4 * 2
    rows.append(("kernel/stencil_gather_ref_512", t * 1e6,
                 f"gb_s={bytes_moved/t/1e9:.2f}"))

    # fused MLP surrogate inference
    from repro.kernels.fused_mlp.ref import fused_mlp_ref
    ws = [jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(256, 1)).astype(np.float32))]
    bs = [jnp.zeros(256), jnp.zeros(256), jnp.zeros(1)]
    xx = jnp.asarray(rng.normal(size=(4096, 64)).astype(np.float32))
    f = jax.jit(lambda x: fused_mlp_ref(x, ws, bs, ("relu", "relu", "identity")))
    t = timeit(f, xx, reps=5)
    flops = 2 * 4096 * (64 * 256 + 256 * 256 + 256)
    rows.append(("kernel/fused_mlp_ref_b4096", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # flash attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    S = 256 if fast else 512
    q = jnp.asarray(rng.normal(size=(1, S, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, S, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, S, 2, 64)).astype(np.float32))
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    t = timeit(f, q, k, v, reps=3)
    flops = 4 * S * S * 8 * 64
    rows.append((f"kernel/flash_attention_ref_s{S}", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # rwkv6 chunk
    from repro.kernels.rwkv6_chunk.ref import rwkv6_chunk_ref
    B, T, H, hd = 2, 128, 8, 64
    r, kk, vv = (jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
                 for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32))
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    f = jax.jit(lambda *a: rwkv6_chunk_ref(*a)[0])
    t = timeit(f, r, kk, vv, w, u, s0, reps=3)
    flops = B * T * H * hd * hd * 6
    rows.append((f"kernel/rwkv6_chunk_ref_t{T}", t * 1e6,
                 f"gflops_s={flops/t/1e9:.2f}"))

    # interpret-mode validation deltas (correctness, not speed)
    from repro.kernels.flash_attention.flash_attention import flash_attention
    a = flash_attention(q[:, :64], k[:, :64], v[:, :64], causal=True,
                        block_q=32, block_k=32)
    b = flash_attention_ref(q[:, :64], k[:, :64], v[:, :64], causal=True)
    rows.append(("kernel/flash_interpret_maxerr", 0.0,
                 f"err={float(jnp.abs(a-b).max()):.2e}"))

    # int8 variants vs their int8-simulating oracles (interpret mode)
    from repro.kernels.fused_mlp import int8 as q_mlp
    prob = dict(q_mlp.SPEC.default_problems[0])
    arrs = q_mlp._make(prob, rng)
    d = jnp.abs(q_mlp._run(prob, arrs, {"batch_tile": 64}, interpret=True)
                - q_mlp._ref(prob, arrs))
    rows.append(("kernel/fused_mlp_int8_interpret_maxerr", 0.0,
                 f"err={float(d.max()):.2e}"))
    from repro.kernels.flash_attention import int8 as q_fa
    prob = dict(q_fa.SPEC.default_problems[0])
    arrs = q_fa._make(prob, rng)
    d = jnp.abs(q_fa._run(prob, arrs, {"block_q": 32, "block_kv": 128},
                          interpret=True) - q_fa._ref(prob, arrs))
    rows.append(("kernel/flash_attention_int8_interpret_maxerr", 0.0,
                 f"err={float(d.max()):.2e}"))
    return rows


# ======================================================== quant gate ========
def _quant_bundle(path, shape, app_name, seed=0):
    """An app-shaped MLP bundle plus a SurrogateDB holding assimilation
    rows for it: inputs from the app's own sampler (real input
    distributions, not gaussians), outputs from the bundle's f32
    forward — so the held-out split isolates quantization error
    exactly."""
    import importlib

    from repro.core.database import SurrogateDB
    from repro.nn import MLP
    from repro.nn.serialize import save_model

    in_dim, h1, h2, out_dim = shape
    net = MLP((1, in_dim), [h1, h2], out_dim)
    params = net.init(jax.random.PRNGKey(seed))
    mp = save_model(pathlib.Path(path) / "surrogate", net, params)

    app = importlib.import_module(f"repro.apps.{app_name}")
    x = np.asarray(app.make_inputs(1024), np.float32).reshape(1024, -1)
    y = np.asarray(jax.jit(net.apply)(params, jnp.asarray(x)))
    db = SurrogateDB(pathlib.Path(path) / "db")
    db.group(app_name).append(x, y, 0.0)
    db.flush()
    return mp, db


def _quant_speedup(widths, bucket):
    """(f32_rows_s, int8_rows_s) for one served shape.

    On TPU: measured wall-clock through the engine's two tiers.  Off
    TPU: roofline-priced (weight stream at 1 byte vs 4) — the module
    docstring's standing rule, because XLA's CPU int8 dot_general is
    *slower* than f32 and would invert the comparison the gate is
    about."""
    from repro.tune.controller import predict_batch_latency_s
    if jax.default_backend() == "tpu":
        from repro.kernels.fused_mlp.fused_mlp import fused_mlp
        from repro.kernels.fused_mlp.int8 import fused_mlp_int8
        from repro.quant.quantize import quantize_params
        rng = np.random.default_rng(0)
        ws = [rng.normal(size=(a, b)).astype(np.float32) * 0.3
              for a, b in zip(widths[:-1], widths[1:])]
        bs = [rng.normal(size=(b,)).astype(np.float32) * 0.1
              for b in widths[1:]]
        acts = ("relu",) * (len(widths) - 2) + ("identity",)
        x = jnp.asarray(rng.normal(size=(bucket, widths[0])), jnp.float32)
        qlayers = quantize_params(ws, bs)
        wj = [jnp.asarray(w) for w in ws]
        bj = [jnp.asarray(b) for b in bs]
        f32 = jax.jit(lambda x: fused_mlp(x, wj, bj, acts, interpret=False))
        i8 = jax.jit(lambda x: fused_mlp_int8(x, qlayers, acts,
                                              interpret=False))
        return (bucket / timeit(f32, x, reps=10),
                bucket / timeit(i8, x, reps=10))
    # overhead_s is the fixed dispatch floor — identical for both tiers,
    # so it is excluded: the gate is about the memory-bound kernel term
    t32 = predict_batch_latency_s(widths, bucket, overhead_s=0.0)
    t8 = predict_batch_latency_s(widths, bucket, overhead_s=0.0,
                                 weight_dtype_bytes=1)
    return bucket / t32, bucket / t8


def quant_check(fast=False, markdown=False):
    """The quantized-tier acceptance gate (CI: ``--quant-check``).

    Per app bundle: harvest held-out calibration rows, register the
    per-bundle RMSE budget in the shared registry, run the accuracy
    gate, then serve the bundle under ``REPRO_QUANT=force`` and check
    the engine resolved the int8 tier, produced all-finite outputs
    within budget of its f32 serving, and counted the served rows.
    Then the fail path: re-gate the first bundle with a deliberately
    wrong calibration (``scale_mult=QUANT_BAD_SCALE``), and require the
    gate to FAIL, the fail counter to increment, and the engine to fall
    back to bit-identical f32 serving.  Finally the speedup leg:
    >= :data:`QUANT_MIN_SPEEDUP` int8-vs-f32 rows/s on at least one
    served shape.
    """
    import tempfile

    from repro.core.engine import InferenceEngine
    from repro.obs import metrics as _m
    from repro.quant.budgets import set_rmse_budget
    from repro.quant.calibrate import calibration_rows
    from repro.quant.gate import gate_bundle, gate_passed

    n_cal = 512 if fast else 2048
    prev_env = os.environ.get("REPRO_QUANT")
    served = _m.counter("repro_quant_served_rows_total",
                        "rows served by the gated int8 tier", ("bundle",))
    fails = _m.counter("repro_quant_gate_fail_total",
                       "quant gate evaluations that failed the RMSE budget",
                       ("bundle",))
    results = []
    try:
        for app_name, shape in QUANT_APP_SHAPES:
            tmp = tempfile.mkdtemp(prefix=f"quant_bench_{app_name}_")
            mp, db = _quant_bundle(tmp, shape, app_name)
            rows = calibration_rows(db, app_name, max_rows=n_cal)

            # budget: relative to this bundle's f32 output scale, then
            # registered where BOTH the gate and the shadow scorer look
            from repro.nn.serialize import load_model
            net, params, _ = load_model(mp)
            y32 = np.asarray(jax.jit(net.apply)(params, jnp.asarray(rows)))
            budget = QUANT_BUDGET_REL * float(
                np.sqrt(np.mean(np.square(y32))) or 1.0)
            set_rmse_budget(mp, budget)

            rec = gate_bundle(mp, rows)
            if not rec["exact"] or rec["rmse"] > budget:
                raise SystemExit(
                    f"quant check FAILED: {app_name} gate rmse "
                    f"{rec['rmse']:.4g} vs budget {budget:.4g} "
                    f"(exact={rec['exact']})")
            if not gate_passed(mp):
                raise SystemExit(f"quant check FAILED: {app_name} verdict "
                                 f"did not persist/bind to the bundle")

            # serve the gated tier for real (off-TPU this runs the int8
            # simulation oracle — same numbers the gate certified)
            x = jnp.asarray(rows[:256])
            os.environ["REPRO_QUANT"] = "never"
            InferenceEngine.invalidate(mp)
            y_f32 = np.asarray(InferenceEngine.get(mp).apply_batched(x))
            os.environ["REPRO_QUANT"] = "force"
            InferenceEngine.invalidate(mp)
            eng = InferenceEngine.get(mp)
            before = served.value(bundle=mp)
            y_q = np.asarray(eng.apply_batched(x))
            if eng.tier != "int8":
                raise SystemExit(f"quant check FAILED: {app_name} engine "
                                 f"resolved tier {eng.tier!r} under force "
                                 f"with a passing gate")
            if not np.isfinite(y_q).all():
                raise SystemExit(f"quant check FAILED: {app_name} int8 "
                                 f"serving produced non-finite outputs")
            if served.value(bundle=mp) - before < x.shape[0]:
                raise SystemExit(f"quant check FAILED: {app_name} served "
                                 f"rows not counted")
            serve_rmse = float(np.sqrt(np.mean((y_q - y_f32) ** 2)))
            if serve_rmse > budget:
                raise SystemExit(
                    f"quant check FAILED: {app_name} served int8-vs-f32 "
                    f"rmse {serve_rmse:.4g} exceeds budget {budget:.4g}")

            f32_rs, i8_rs = _quant_speedup(shape, QUANT_BUCKET)
            results.append({"app": app_name, "widths": shape,
                            "rmse": rec["rmse"], "budget": budget,
                            "serve_rmse": serve_rmse, "f32_rows_s": f32_rs,
                            "int8_rows_s": i8_rs,
                            "speedup": i8_rs / f32_rs, "mp": mp,
                            "x": np.asarray(x), "y_f32": y_f32})
            print(f"[quant] {app_name}: gate rmse={rec['rmse']:.3g} "
                  f"budget={budget:.3g} serve rmse={serve_rmse:.3g} "
                  f"speedup={i8_rs / f32_rs:.2f}x "
                  f"({'measured' if jax.default_backend() == 'tpu' else 'roofline'})",
                  flush=True)

        # ---- fail path: a mis-calibrated bundle must NOT serve int8 ----
        r0 = results[0]
        mp = r0["mp"]
        rows = r0["x"]
        fails_before = fails.value(bundle=mp)
        rec = gate_bundle(mp, rows, scale_mult=QUANT_BAD_SCALE)
        if rec["exact"] or gate_passed(mp):
            raise SystemExit(
                f"quant check FAILED: mis-calibrated (scale_mult="
                f"{QUANT_BAD_SCALE}) bundle PASSED the gate "
                f"(rmse={rec['rmse']:.4g} vs budget {rec['budget']:.4g})")
        if fails.value(bundle=mp) - fails_before < 1:
            raise SystemExit("quant check FAILED: gate-fail counter did "
                             "not increment")
        os.environ["REPRO_QUANT"] = "force"
        InferenceEngine.invalidate(mp)
        eng = InferenceEngine.get(mp)
        y_after = np.asarray(eng.apply_batched(jnp.asarray(rows)))
        if eng.tier != "f32":
            raise SystemExit(f"quant check FAILED: engine serves tier "
                             f"{eng.tier!r} after a gate fail")
        if not np.array_equal(y_after, r0["y_f32"]):
            raise SystemExit("quant check FAILED: post-gate-fail serving "
                             "is not bit-identical to the f32 path")
        # the fail-record must never be resolvable as a tuned winner
        from repro.tune.cache import best_params
        from repro.quant.gate import GATE_NAMESPACE, _key
        if best_params(GATE_NAMESPACE, [_key(mp)]) is not None:
            raise SystemExit("quant check FAILED: gate-fail record "
                             "resolvable via best_params")
        print(f"[quant] fail path OK: scale_mult={QUANT_BAD_SCALE} gate "
              f"rmse={rec['rmse']:.3g} > budget {rec['budget']:.3g}; "
              f"engine fell back to bit-identical f32", flush=True)

        best = max(results, key=lambda r: r["speedup"])
        if best["speedup"] < QUANT_MIN_SPEEDUP:
            raise SystemExit(
                f"quant check FAILED: best int8 speedup "
                f"{best['speedup']:.2f}x ({best['app']}) < "
                f"{QUANT_MIN_SPEEDUP}x")
        print(f"[quant] OK: best speedup {best['speedup']:.2f}x "
              f"({best['app']}), all gates within budget", flush=True)
    finally:
        if prev_env is None:
            os.environ.pop("REPRO_QUANT", None)
        else:
            os.environ["REPRO_QUANT"] = prev_env
        InferenceEngine.invalidate()

    if markdown:
        basis = ("measured" if jax.default_backend() == "tpu"
                 else "roofline")
        print("\n## Quantization gate (int8 tier vs f32, "
              f"rows/s {basis})\n")
        print("| app | widths | f32 rows/s | int8 rows/s | speedup | "
              "gate RMSE | budget | gated |")
        print("|---|---|---|---|---|---|---|---|")
        for r in results:
            w = "-".join(str(v) for v in r["widths"])
            print(f"| {r['app']} | {w} | {r['f32_rows_s']:,.0f} | "
                  f"{r['int8_rows_s']:,.0f} | {r['speedup']:.2f}x | "
                  f"{r['rmse']:.3g} | {r['budget']:.3g} | yes |")
        print()
    return results


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--quant-check", action="store_true",
                    help="run the int8-tier acceptance gate")
    args = ap.parse_args(argv)
    if args.quant_check:
        results = quant_check(fast=args.fast, markdown=args.markdown)
        write_bench_json("quant", {
            "apps": [{k: v for k, v in r.items()
                      if k not in ("mp", "x", "y_f32", "widths")}
                     | {"widths": list(r["widths"])}
                     for r in results],
            "gate": {"min_speedup_x": QUANT_MIN_SPEEDUP,
                     "budget_rel": QUANT_BUDGET_REL,
                     "best_speedup_x": max(r["speedup"] for r in results)},
        })
        return 0
    for name, us, note in kernel_bench(fast=args.fast):
        print(f"{name:45s} {us:10.1f}us  {note}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
