"""Render the machine-readable bench results as one trajectory table.

Every ``--check`` bench persists its gate numbers as
``artifacts/bench-json/BENCH_<name>.json`` (see
:func:`benchmarks.common.write_bench_json`): rows/s, latency
percentiles, and gate ratios stamped with the git sha and timestamp.
This module folds whatever subset of those files exists into one
compact markdown table for the CI job summary — the per-run point of
the cross-PR perf trajectory.  It never runs a benchmark itself and
exits 0 when no files exist (benches that didn't run this job simply
don't get a row).

  PYTHONPATH=src python -m benchmarks.bench_trajectory --markdown
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import BENCH_JSON


def _fmt(v, unit=""):
    if v is None:
        return "-"
    if isinstance(v, float):
        v = f"{v:,.0f}" if abs(v) >= 100 else f"{v:.2f}"
    return f"{v}{unit}"


def _row(doc):
    """One table row per bench file; each bench nominates its headline
    throughput / latency / gate numbers (schemas differ by bench)."""
    name = doc.get("bench", "?")
    rows_s = p50 = p99 = None
    gate = doc.get("gate") or {}
    if name == "serve":
        rows_s = doc.get("rows_per_s")
        p50, p99 = doc.get("p50_ms"), doc.get("p99_ms")
        g = (f"speedup {_fmt(gate.get('speedup_x'))}x "
             f"(>= {_fmt(gate.get('required_speedup_x'))}x)")
    elif name == "tenant":
        worst = max((t for t in doc.get("tenants", [])),
                    key=lambda t: t.get("p99_ratio", 0), default=None)
        if worst:
            p99 = worst.get("skew_p99_ms")
        res = doc.get("residency") or {}
        g = (f"worst p99 ratio {_fmt(gate.get('worst_p99_ratio'))}x "
             f"(<= {_fmt(gate.get('p99_max_ratio'))}x), "
             f"{res.get('evictions', 0)} evictions within budget")
    elif name == "tune":
        pols = doc.get("policies") or {}
        ad = pols.get("adaptive") or {}
        rows_s, p50 = ad.get("burst_rows_s"), ad.get("trickle_p50_ms")
        p99 = ad.get("trickle_p99_ms")
        g = (f"measured-loop burst {_fmt(gate.get('burst_ratio'))}x "
             f"(>= {_fmt(gate.get('measured_burst_min_ratio'))}x), "
             f"p99 {_fmt(gate.get('p99_ratio'))}x")
    elif name == "quant":
        apps = doc.get("apps") or []
        best = max(apps, key=lambda a: a.get("speedup", 0), default={})
        rows_s = best.get("int8_rows_s")
        g = (f"best int8 {_fmt(gate.get('best_speedup_x'))}x "
             f"(>= {_fmt(gate.get('min_speedup_x'))}x)")
    else:
        g = ", ".join(f"{k}={_fmt(v)}" for k, v in sorted(gate.items()))
    sha = str(doc.get("git_sha", ""))[:9] or "-"
    when = str(doc.get("iso_time", ""))[:19] or "-"
    return (f"| {name} | {_fmt(rows_s)} | {_fmt(p50)} | {_fmt(p99)} | "
            f"{g or '-'} | {sha} | {when} |")


def render(paths):
    docs = []
    for p in sorted(paths):
        try:
            docs.append(json.loads(pathlib.Path(p).read_text()))
        except (OSError, ValueError):
            docs.append({"bench": pathlib.Path(p).stem, "gate": {}})
    out = ["### Bench trajectory", "",
           "| bench | rows/s | p50 ms | p99 ms | gate | sha | when (UTC) |",
           "|---|---:|---:|---:|---|---|---|"]
    out += [_row(d) for d in docs]
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--markdown", action="store_true",
                    help="(default) print the markdown trajectory table")
    ap.add_argument("--dir", default=str(BENCH_JSON),
                    help="directory holding BENCH_<name>.json files")
    args = ap.parse_args(argv)
    paths = sorted(pathlib.Path(args.dir).glob("BENCH_*.json"))
    if not paths:
        print(f"(no bench-json files under {args.dir})")
        return 0
    print(render(paths))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
