"""Shared benchmark plumbing: timing, one-time surrogate training cache,
machine-readable bench results."""
from __future__ import annotations

import datetime
import json
import os
import pathlib
import subprocess
import time

import jax
import numpy as np

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
MODELS = ART / "models"
BENCH_JSON = ART / "bench-json"


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA", "").strip()
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=str(ART.parent), timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def write_bench_json(name: str, payload: dict) -> pathlib.Path:
    """Persist one bench gate's numbers as ``BENCH_<name>.json``.

    The human-readable markdown tables are per-PR artifacts; these JSON
    files are the *machine-readable* perf trajectory — rows/s, latency
    percentiles, and gate ratios stamped with the git sha and timestamp,
    uploaded from CI so regressions across PRs are diffable by tooling
    rather than by eyeball (rendered per-run by
    :mod:`benchmarks.bench_trajectory`).
    """
    BENCH_JSON.mkdir(parents=True, exist_ok=True)
    doc = {
        "bench": name,
        "git_sha": _git_sha(),
        "timestamp": time.time(),
        "iso_time": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "backend": jax.default_backend(),
    }
    doc.update(payload)
    out = BENCH_JSON / f"BENCH_{name}.json"
    out.write_text(json.dumps(doc, indent=1, sort_keys=True,
                              default=float) + "\n")
    return out


def timeit(fn, *args, reps=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def get_surrogate(app_name, app, *, n=1024, epochs=20, outer=4, inner=0,
                  force=False):
    """Train (once) and cache a surrogate bundle for `app`."""
    from repro.nas.nested import best_trial, nested_search, save_trial
    path = MODELS / app_name
    if (path / "spec.json").exists() and not force:
        return str(path)
    db_dir = ART / "db" / app_name
    if app_name == "miniweather":
        region = app.make_region(mode="collect", database=str(db_dir))
        s = app.init_state()
        for _ in range(max(80, n // 8)):
            s = region(state=s)["state"]
    elif app_name == "particlefilter":
        frames, _ = app.make_video(n)
        region = app.make_region(n, mode="collect", database=str(db_dir))
        region(frames=frames.reshape(n, -1))
    else:
        x = app.make_inputs(n)
        region = app.make_region(n, mode="collect", database=str(db_dir))
        key = list(region.inputs)[0]
        region(**{key: x})
    region.db.flush()
    res = nested_search(app, region.db.group(app_name), outer_iters=outer,
                        inner_iters=inner, epochs=epochs, verbose=False)
    return save_trial(best_trial(res), path)
