"""Shared benchmark plumbing: timing, one-time surrogate training cache."""
from __future__ import annotations

import pathlib
import time

import jax
import numpy as np

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"
MODELS = ART / "models"


def timeit(fn, *args, reps=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def get_surrogate(app_name, app, *, n=1024, epochs=20, outer=4, inner=0,
                  force=False):
    """Train (once) and cache a surrogate bundle for `app`."""
    from repro.nas.nested import best_trial, nested_search, save_trial
    path = MODELS / app_name
    if (path / "spec.json").exists() and not force:
        return str(path)
    db_dir = ART / "db" / app_name
    if app_name == "miniweather":
        region = app.make_region(mode="collect", database=str(db_dir))
        s = app.init_state()
        for _ in range(max(80, n // 8)):
            s = region(state=s)["state"]
    elif app_name == "particlefilter":
        frames, _ = app.make_video(n)
        region = app.make_region(n, mode="collect", database=str(db_dir))
        region(frames=frames.reshape(n, -1))
    else:
        x = app.make_inputs(n)
        region = app.make_region(n, mode="collect", database=str(db_dir))
        key = list(region.inputs)[0]
        region(**{key: x})
    region.db.flush()
    res = nested_search(app, region.db.group(app_name), outer_iters=outer,
                        inner_iters=inner, epochs=epochs, verbose=False)
    return save_trial(best_trial(res), path)
