"""Roofline reporting: reads artifacts/dryrun/*.json into the §Roofline
table (terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO ratio)."""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

ARCHS = ["whisper-medium", "rwkv6-1.6b", "qwen1.5-32b", "llama3.2-3b",
         "qwen3-4b", "qwen1.5-110b", "jamba-v0.1-52b", "qwen2-vl-7b",
         "deepseek-v2-lite-16b", "grok-1-314b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cell(arch, shape, mesh="pod16x16", variant="baseline"):
    p = ART / f"{arch}__{shape}__{mesh}__{variant}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_rows(fast=False):
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(arch, shape)
            if rec is None:
                rows.append((f"roofline/{arch}/{shape}", 0.0, "missing"))
                continue
            if "roofline" not in rec:
                rows.append((f"roofline/{arch}/{shape}", 0.0,
                             rec.get("status", "?")))
                continue
            r = rec["roofline"]
            rows.append((
                f"roofline/{arch}/{shape}",
                r["step_time_s"] * 1e6,
                f"dom={r['dominant']};compute_s={r['compute_s']:.4g};"
                f"memory_s={r['memory_s']:.4g};"
                f"collective_s={r['collective_s']:.4g};"
                f"useful_flops={r['useful_flops_fraction']*100:.0f}%;"
                f"mfu_bound={r['mfu_bound']*100:.1f}%;"
                f"mem_chip_gb={rec['memory']['peak_per_chip_bytes']/1e9:.1f}",
            ))
    return rows


def markdown_table(mesh="pod16x16", variant="baseline"):
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful FLOPs | MFU bound | resident GB/chip | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(arch, shape, mesh, variant)
            if rec is None:
                continue
            if "roofline" not in rec:
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"{rec.get('status','?')} | - | - | - | - |")
                continue
            r = rec["roofline"]
            res = rec.get("analytic", {}).get("est_hbm_per_chip", 0) / 1e9
            fits = "yes" if rec.get("fits_16GB_analytic") else "NO"
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.3g} | "
                f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
                f"**{r['dominant']}** | "
                f"{r['useful_flops_fraction']*100:.0f}% | "
                f"{r['mfu_bound']*100:.1f}% | {res:.2f} | {fits} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
