"""Autotuning benchmark: tuned-vs-default kernel configs across the
registry, adaptive-vs-static flush policies, measured-vs-open-loop
latency control.

Three measurements, three gate families (``--check``, the CI autotune
smoke):

  1. **Kernels**: sweep every tunable registered kernel — ``fused_mlp``
     batch tiles, ``flash_attention`` block_q/block_kv,
     ``stencil_gather`` row/column tiles — via ``repro.tune.sweep``
     (persisted per kernel in ``artifacts/tune/<kernel>.json``).  Gate:
     the tuned config must be >= 1.0x the spec default (structural: the
     default is always swept, the winner is the measured argmin) and
     every winner validated against the jitted ref oracle
     (bit-identical where the spec demands it; flash attention to its
     declared f32 tolerance — the online-softmax block order
     legitimately changes rounding).
  2. **Serving**: drive a surrogate region queue under a fast burst
     (throughput regime) and a slow trickle (latency regime) for each
     static deadline and for the adaptive controller.  Gate: adaptive
     achieves >= ``CHECK_RATIO`` x the best static deadline's burst
     rows/s AND a trickle p99 no worse than that same best-throughput
     static's — the adaptive policy must win the latency regime without
     giving up the throughput regime.
  3. **Measured loop**: the closed-loop controller (ServeStats batch
     latencies blended into the deadline model) vs the same controller
     open-loop (`use_measured=False`).  Gate: closing the loop must not
     regress either regime beyond measurement noise
     (>= ``MEASURED_BURST_RATIO`` x burst rows/s, trickle p99 within
     ``MEASURED_P99_SLACK``).

``--markdown`` renders the result sets as tables (the EXPERIMENTS.md
"Autotuning" section is regenerated from this).

  PYTHONPATH=src python -m benchmarks.tune_bench --check [--fast]
"""
import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json

CHECK_RATIO = 0.9        # adaptive rows/s vs best static
MEASURED_BURST_RATIO = 0.85   # closed-loop rows/s vs open-loop (median)
MEASURED_P99_SLACK = 1.5      # closed-loop p99 <= slack x open-loop (median)
STATIC_DEADLINES_S = (0.005, 0.02, 0.05)
BURST_REQUESTS, TRICKLE_REQUESTS = 96, 24
ROWS_PER_REQUEST = 8
TRICKLE_GAP_S = 0.005

# NAS-representative pure-MLP surrogate shapes: (widths, serve bucket)
KERNEL_SHAPES = (
    ((5, 128, 128, 1), 256),    # binomial/bonds-like scalar regressor
    ((16, 256, 256, 4), 512),   # wider multi-output head
)

# registered-kernel problems swept alongside fused_mlp (kept small: the
# sweep runs Pallas interpret mode on CPU; winners persist in
# artifacts/tune so CI only re-sweeps on kernel/tuner changes)
REGISTRY_PROBLEMS = (
    ("flash_attention",
     {"b": 1, "sq": 128, "skv": 128, "h": 4, "kv": 2, "hd": 32,
      "causal": True, "q_offset": 0, "dtype": "float32"},
     {"b": 1, "sq": 64, "skv": 64, "h": 2, "kv": 1, "hd": 16,
      "causal": True, "q_offset": 0, "dtype": "float32"}),
    ("stencil_gather",
     {"h": 256, "w": 288, "out_h": 252, "out_w": 284,
      "offsets": ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2)),
      "origin": (1, 1), "dtype": "float32"},
     {"h": 128, "w": 160, "out_h": 124, "out_w": 156,
      "offsets": ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2)),
      "origin": (1, 1), "dtype": "float32"}),
)


# ------------------------------------------------------------- kernel ------
def _fmt_params(params):
    return "/".join(f"{k}={v}" for k, v in sorted(params.items()))


def kernel_rows(fast=False, force=False):
    """Sweep fused_mlp + every other tunable registered kernel."""
    from repro.kernels import registry
    from repro.tune import sweep, sweep_fused_mlp
    reps = 3 if fast else 5
    rows = []
    shapes = KERNEL_SHAPES[:1] if fast else KERNEL_SHAPES
    for widths, bucket in shapes:
        rec = sweep_fused_mlp(list(widths), bucket, force=force, reps=reps)
        name = "tune/fused_mlp_" + "-".join(map(str, widths)) + f"_b{bucket}"
        derived = (f"kernel=fused_mlp;params={_fmt_params(rec['params'])};"
                   f"default=batch_tile=128;"
                   f"tuned_us={rec['us']};default_us={rec['default_us']};"
                   f"speedup_x={rec['speedup_x']};exact={rec['exact']};"
                   f"backend={rec['backend']}")
        rows.append((name, rec["us"] or 0.0, derived))
    for kernel, full, small in REGISTRY_PROBLEMS:
        spec = registry.get_spec(kernel)
        problem = small if fast else full
        rec = sweep(spec, problem, force=force, reps=reps)
        tag = spec.cache_key(dict(problem), rec["backend"]).split("|")[0]
        derived = (f"kernel={kernel};params={_fmt_params(rec['params'])};"
                   f"default={_fmt_params(spec.defaults())};"
                   f"tuned_us={rec['us']};default_us={rec['default_us']};"
                   f"speedup_x={rec['speedup_x']};exact={rec['exact']};"
                   f"backend={rec['backend']}")
        rows.append((f"tune/{kernel}_{tag}", rec["us"] or 0.0, derived))
    return rows


# ------------------------------------------------------------ serving ------
def _bundle(path):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 5), [128, 128], 1)
    params = net.init(jax.random.PRNGKey(0))
    return save_model(path, net, params)


def _prewarm(mp):
    """Compile every bucket shape (donated + caller-owned applies) the
    scenarios can dispatch, so the timed runs compare flush policies —
    not which config happened to hit a fresh jit shape first."""
    import jax.numpy as jnp

    from repro.core.engine import InferenceEngine
    eng = InferenceEngine.get(mp)
    b = 8
    while b <= 1024:
        eng.apply_batched(jnp.zeros((b, 5), np.float32))
        eng.apply_batched(jnp.zeros((b, 5), np.float32), donate=True,
                          prepadded=True)
        b *= 2


def _drive(mp, make_queue, n_requests, gap_s, seed=0):
    """Run one serving scenario; returns (wall_s, stats snapshot)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    blocks = [jnp.asarray(rng.normal(size=(ROWS_PER_REQUEST, 5))
                          .astype(np.float32)) for _ in range(n_requests)]
    q = make_queue()
    with q:
        t0 = time.perf_counter()
        futs = []
        for b in blocks:
            futs.append(q.submit(mp, b))
            if gap_s:
                time.sleep(gap_s)
        for f in futs:
            f.result(30)
        wall = time.perf_counter() - t0
    return wall, q.stats(mp).snapshot()


def _scenarios(mp, make_queue, fast=False):
    """(burst rows/s, trickle p50/p99 ms) for one queue configuration.

    Both regimes take the best of several short runs: a trickle p99 over
    a couple dozen requests is a max-of-N statistic, and on a shared CI
    machine a single draw is dominated by scheduler noise — best-of
    measures what the policy can do, which is what the gates compare."""
    n_burst = BURST_REQUESTS // (2 if fast else 1)
    n_trickle = TRICKLE_REQUESTS // (2 if fast else 1)
    # warmup: compile every bucket shape this config will serve, so the
    # timed runs compare policies, not jit cache luck
    _drive(mp, make_queue, n_burst, 0.0, seed=99)
    burst_rows_s = 0.0
    for i in range(4):
        wall, _ = _drive(mp, make_queue, n_burst, 0.0, seed=i)
        burst_rows_s = max(burst_rows_s, n_burst * ROWS_PER_REQUEST / wall)
    p50 = p99 = float("inf")
    for i in range(4):
        _, st = _drive(mp, make_queue, n_trickle, TRICKLE_GAP_S, seed=i)
        p50 = min(p50, st["latency_p50_ms"])
        p99 = min(p99, st["latency_p99_ms"])
    return {"burst_rows_s": burst_rows_s,
            "trickle_p50_ms": p50,
            "trickle_p99_ms": p99}


def _paired_ratios(mp, make_a, make_b, fast=False, pairs=4):
    """Median per-pair (B / A) metric ratios, runs interleaved.

    Two scenario blocks measured seconds apart on a shared machine see
    different background load; comparing their absolutes turns drift
    into false regressions.  Back-to-back pairs share the drift, so the
    per-pair ratio isolates the *policy* difference, and the median of
    a few pairs shrugs off one noisy draw."""
    n_burst = BURST_REQUESTS // (2 if fast else 1)
    n_trickle = TRICKLE_REQUESTS // (2 if fast else 1)
    burst, p99 = [], []
    for i in range(pairs):
        wa, _ = _drive(mp, make_a, n_burst, 0.0, seed=10 + i)
        wb, _ = _drive(mp, make_b, n_burst, 0.0, seed=10 + i)
        burst.append(wa / wb)  # rows/s ratio = inverse wall ratio
    for i in range(pairs):
        _, sa = _drive(mp, make_a, n_trickle, TRICKLE_GAP_S, seed=20 + i)
        _, sb = _drive(mp, make_b, n_trickle, TRICKLE_GAP_S, seed=20 + i)
        p99.append(sb["latency_p99_ms"] / max(sa["latency_p99_ms"], 1e-9))
    return {"burst_ratio": float(np.median(burst)),
            "p99_ratio": float(np.median(p99))}


def serving_rows(fast=False):
    """Adaptive controller (closed- and open-loop) vs each static
    deadline, both regimes."""
    import pathlib
    import tempfile

    from repro.serve import FlushPolicy, ServeQueue
    from repro.tune import AdaptiveFlushController

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="tune_bench_"))
    mp = _bundle(tmp / "surrogate")
    _prewarm(mp)
    results = {}
    for d in STATIC_DEADLINES_S:
        pol = FlushPolicy(max_batch_rows=4096, max_pending_rows=1 << 16,
                          max_delay_s=d)
        results[f"static_{d * 1e3:g}ms"] = _scenarios(
            mp, lambda p=pol: ServeQueue(p), fast=fast)
    ctrl_pol = FlushPolicy(max_batch_rows=4096, max_pending_rows=1 << 16,
                           max_delay_s=max(STATIC_DEADLINES_S))

    def adaptive_queue(use_measured=True):
        return ServeQueue(ctrl_pol, controller=AdaptiveFlushController(
            ctrl_pol, warmup_requests=4, use_measured=use_measured))

    # open-loop first so the closed-loop run cannot ride its jit warmth
    results["adaptive_openloop"] = _scenarios(
        mp, lambda: adaptive_queue(use_measured=False), fast=fast)
    results["adaptive"] = _scenarios(mp, adaptive_queue, fast=fast)
    # closed-vs-open gate metrics come from interleaved pairs (drift-
    # immune), not from the absolute scenario blocks above
    measured = _paired_ratios(mp, lambda: adaptive_queue(use_measured=False),
                              adaptive_queue, fast=fast)

    rows = []
    for name, r in results.items():
        derived = (f"burst_rows_s={r['burst_rows_s']:.0f};"
                   f"trickle_p50_ms={r['trickle_p50_ms']:.2f};"
                   f"trickle_p99_ms={r['trickle_p99_ms']:.2f}")
        rows.append((f"tune/serve_{name}", 0.0, derived))
    rows.append(("tune/serve_measured_vs_openloop", 0.0,
                 f"burst_ratio={measured['burst_ratio']:.3f};"
                 f"p99_ratio={measured['p99_ratio']:.3f}"))
    results["measured_vs_openloop"] = measured
    return rows, results


def tune_rows(fast=False):
    """benchmarks.run entry: kernel + serving CSV rows."""
    rows = kernel_rows(fast=fast)
    srows, _ = serving_rows(fast=fast)
    return rows + srows


# ------------------------------------------------------------- output ------
def _markdown(krows, results):
    out = ["### Autotuned kernel configs", "",
           "| kernel | problem | tuned params | tuned us | default us | "
           "speedup | validated |",
           "|---|---|---|---|---|---|---|"]
    for name, _, derived in krows:
        kv = dict(item.split("=", 1) for item in derived.split(";"))
        problem = name.split("/", 1)[1].split(kv["kernel"] + "_", 1)[-1]
        out.append(f"| {kv['kernel']} | {problem} | {kv['params']} | "
                   f"{kv['tuned_us']} | {kv['default_us']} | "
                   f"{kv['speedup_x']}x | {kv['exact']} |")
    out += ["", "### Adaptive vs static flush policies", "",
            "| policy | burst rows/s | trickle p50 ms | trickle p99 ms |",
            "|---|---|---|---|"]
    for name, r in results.items():
        if "burst_rows_s" not in r:
            continue
        out.append(f"| {name} | {r['burst_rows_s']:.0f} | "
                   f"{r['trickle_p50_ms']:.2f} | {r['trickle_p99_ms']:.2f} |")
    m = results.get("measured_vs_openloop")
    if m:
        out += ["", "Closed- vs open-loop controller (interleaved pairs, "
                     "median ratios): "
                     f"burst {m['burst_ratio']:.2f}x rows/s, "
                     f"trickle p99 {m['p99_ratio']:.2f}x."]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail unless every tuned kernel >= 1.0x default, "
                         f"adaptive >= {CHECK_RATIO}x best-static rows/s "
                         "with no worse trickle p99, and the measured-"
                         "latency loop does not regress the open-loop "
                         "controller")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep even if the tune cache has entries")
    ap.add_argument("--markdown", action="store_true",
                    help="print markdown tables (for EXPERIMENTS.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run with tracing on and write the Chrome trace "
                         "+ metrics snapshots to PATH(.metrics.json/.prom) "
                         "— controller decisions, tune-cache hit/miss and "
                         "kernel-dispatch provenance all land in the "
                         "metrics dump")
    args = ap.parse_args()
    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()

    krows = kernel_rows(fast=args.fast, force=args.force)
    srows, results = serving_rows(fast=args.fast)
    if args.trace:
        import json
        import pathlib

        from repro.obs import TRACER, default_registry
        path = pathlib.Path(args.trace)
        events = TRACER.export_chrome_trace(path)
        path.with_suffix(".metrics.json").write_text(
            json.dumps(default_registry().collect(), indent=1))
        path.with_suffix(".prom").write_text(default_registry().dump())
        print(f"[tune trace] {len(events)} events -> {path}", flush=True)
    if args.markdown:
        print(_markdown(krows, results))
    else:
        print("name,us_per_call,derived")
        for n, us, derived in krows + srows:
            print(f"{n},{us:.2f},{derived}", flush=True)

    def _num(v):
        try:
            return float(v)
        except (TypeError, ValueError):
            return v

    bench_json = {
        "kernels": {
            name.split("/", 1)[1]: {
                k: (_num(v) if k in ("tuned_us", "default_us", "speedup_x")
                    else v)
                for k, v in (item.split("=", 1)
                             for item in derived.split(";"))}
            for name, _, derived in krows},
        "policies": {name: {"burst_rows_s": r["burst_rows_s"],
                            "trickle_p50_ms": r["trickle_p50_ms"],
                            "trickle_p99_ms": r["trickle_p99_ms"]}
                     for name, r in results.items()
                     if "burst_rows_s" in r},
        "gate": {"adaptive_min_ratio": CHECK_RATIO,
                 "measured_burst_min_ratio": MEASURED_BURST_RATIO,
                 "measured_p99_max_ratio": MEASURED_P99_SLACK,
                 **results["measured_vs_openloop"]},
    }
    write_bench_json("tune", bench_json)
    if args.check:
        failures = []
        for name, _, derived in krows:
            kv = dict(item.split("=", 1) for item in derived.split(";"))
            if kv["exact"] != "True":
                failures.append(f"{name}: tuned config not validated "
                                "against the ref oracle")
            if float(kv["speedup_x"]) < 1.0:
                failures.append(f"{name}: tuned {kv['speedup_x']}x < 1.0x "
                                "default")
        statics = {k: v for k, v in results.items()
                   if k.startswith("static_")}
        best_name = max(statics, key=lambda k: statics[k]["burst_rows_s"])
        best = statics[best_name]
        ad = results["adaptive"]
        if ad["burst_rows_s"] < CHECK_RATIO * best["burst_rows_s"]:
            failures.append(
                f"adaptive burst {ad['burst_rows_s']:.0f} rows/s < "
                f"{CHECK_RATIO}x best static {best_name} "
                f"({best['burst_rows_s']:.0f})")
        if ad["trickle_p99_ms"] > best["trickle_p99_ms"]:
            failures.append(
                f"adaptive trickle p99 {ad['trickle_p99_ms']:.2f}ms worse "
                f"than best-throughput static {best_name} "
                f"({best['trickle_p99_ms']:.2f}ms)")
        m = results["measured_vs_openloop"]
        if m["burst_ratio"] < MEASURED_BURST_RATIO:
            failures.append(
                f"measured-latency burst ratio {m['burst_ratio']:.3f} < "
                f"{MEASURED_BURST_RATIO}x open-loop (median of interleaved "
                "pairs)")
        if m["p99_ratio"] > MEASURED_P99_SLACK:
            failures.append(
                f"measured-latency trickle p99 ratio {m['p99_ratio']:.3f} > "
                f"{MEASURED_P99_SLACK}x open-loop (median of interleaved "
                "pairs)")
        if failures:
            raise SystemExit("tune smoke FAILED:\n  " + "\n  ".join(failures))
        print(f"[tune smoke] OK: kernels tuned, adaptive "
              f"{ad['burst_rows_s']:.0f} rows/s vs best static "
              f"{best['burst_rows_s']:.0f} ({best_name}), trickle p99 "
              f"{ad['trickle_p99_ms']:.2f}ms vs {best['trickle_p99_ms']:.2f}"
              f"ms; measured loop vs open-loop (paired medians) "
              f"burst {m['burst_ratio']:.2f}x, p99 {m['p99_ratio']:.2f}x")


if __name__ == "__main__":
    main()
