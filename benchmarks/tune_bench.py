"""Autotuning benchmark: tuned-vs-default kernel tiles, adaptive-vs-static
flush policies.

Two measurements, two gates (``--check``, the CI autotune smoke):

  1. **Kernel**: sweep ``fused_mlp`` batch tiles for NAS-representative
     surrogate shapes (via ``repro.tune.sweep_fused_mlp``, persisted in
     ``artifacts/tune/``).  Gate: the tuned tile must be >= 1.0x the
     hardcoded default (structural: the default is always swept, the
     winner is the measured argmin) with bit-identical outputs.
  2. **Serving**: drive a surrogate region queue under a fast burst
     (throughput regime) and a slow trickle (latency regime) for each
     static deadline and for the adaptive controller.  Gate: adaptive
     achieves >= ``CHECK_RATIO`` x the best static deadline's burst
     rows/s AND a trickle p99 no worse than that same best-throughput
     static's — the adaptive policy must win the latency regime without
     giving up the throughput regime.

``--markdown`` renders both result sets as tables (the EXPERIMENTS.md
"Autotune" section is regenerated from this).

  PYTHONPATH=src python -m benchmarks.tune_bench --check [--fast]
"""
import argparse
import time

import jax
import numpy as np

CHECK_RATIO = 0.9        # adaptive rows/s vs best static
STATIC_DEADLINES_S = (0.005, 0.02, 0.05)
BURST_REQUESTS, TRICKLE_REQUESTS = 48, 24
ROWS_PER_REQUEST = 8
TRICKLE_GAP_S = 0.005

# NAS-representative pure-MLP surrogate shapes: (widths, serve bucket)
KERNEL_SHAPES = (
    ((5, 128, 128, 1), 256),    # binomial/bonds-like scalar regressor
    ((16, 256, 256, 4), 512),   # wider multi-output head
)


# ------------------------------------------------------------- kernel ------
def kernel_rows(fast=False, force=False):
    from repro.tune import sweep_fused_mlp
    shapes = KERNEL_SHAPES[:1] if fast else KERNEL_SHAPES
    rows = []
    for widths, bucket in shapes:
        rec = sweep_fused_mlp(list(widths), bucket, force=force,
                              reps=3 if fast else 5)
        name = "tune/fused_mlp_" + "-".join(map(str, widths)) + f"_b{bucket}"
        derived = (f"tile={rec['batch_tile']};default_tile=128;"
                   f"tuned_us={rec['us']};default_us={rec['default_us']};"
                   f"speedup_x={rec['speedup_x']};exact={rec['exact']};"
                   f"backend={rec['backend']}")
        rows.append((name, rec["us"] or 0.0, derived))
    return rows


# ------------------------------------------------------------ serving ------
def _bundle(path):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 5), [128, 128], 1)
    params = net.init(jax.random.PRNGKey(0))
    return save_model(path, net, params)


def _prewarm(mp):
    """Compile every bucket shape (donated + caller-owned applies) the
    scenarios can dispatch, so the timed runs compare flush policies —
    not which config happened to hit a fresh jit shape first."""
    import jax.numpy as jnp

    from repro.core.engine import InferenceEngine
    eng = InferenceEngine.get(mp)
    b = 8
    while b <= 1024:
        eng.apply_batched(jnp.zeros((b, 5), np.float32))
        eng.apply_batched(jnp.zeros((b, 5), np.float32), donate=True,
                          prepadded=True)
        b *= 2


def _drive(mp, make_queue, n_requests, gap_s, seed=0):
    """Run one serving scenario; returns (wall_s, stats snapshot)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    blocks = [jnp.asarray(rng.normal(size=(ROWS_PER_REQUEST, 5))
                          .astype(np.float32)) for _ in range(n_requests)]
    q = make_queue()
    with q:
        t0 = time.perf_counter()
        futs = []
        for b in blocks:
            futs.append(q.submit(mp, b))
            if gap_s:
                time.sleep(gap_s)
        for f in futs:
            f.result(30)
        wall = time.perf_counter() - t0
    return wall, q.stats(mp).snapshot()


def _scenarios(mp, make_queue, fast=False):
    """(burst rows/s, trickle p50/p99 ms) for one queue configuration."""
    n_burst = BURST_REQUESTS // (2 if fast else 1)
    n_trickle = TRICKLE_REQUESTS // (2 if fast else 1)
    # warmup: compile every bucket shape this config will serve, so the
    # timed runs compare policies, not jit cache luck
    _drive(mp, make_queue, n_burst, 0.0, seed=99)
    wall, _ = _drive(mp, make_queue, n_burst, 0.0)
    burst_rows_s = n_burst * ROWS_PER_REQUEST / wall
    _, st = _drive(mp, make_queue, n_trickle, TRICKLE_GAP_S)
    return {"burst_rows_s": burst_rows_s,
            "trickle_p50_ms": st["latency_p50_ms"],
            "trickle_p99_ms": st["latency_p99_ms"]}


def serving_rows(fast=False):
    """Adaptive controller vs each static deadline, both regimes."""
    import pathlib
    import tempfile

    from repro.serve import FlushPolicy, ServeQueue
    from repro.tune import AdaptiveFlushController

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="tune_bench_"))
    mp = _bundle(tmp / "surrogate")
    _prewarm(mp)
    results = {}
    for d in STATIC_DEADLINES_S:
        pol = FlushPolicy(max_batch_rows=4096, max_pending_rows=1 << 16,
                          max_delay_s=d)
        results[f"static_{d * 1e3:g}ms"] = _scenarios(
            mp, lambda p=pol: ServeQueue(p), fast=fast)
    pol = FlushPolicy(max_batch_rows=4096, max_pending_rows=1 << 16,
                      max_delay_s=max(STATIC_DEADLINES_S))
    ctrl_pol = pol

    def adaptive_queue():
        return ServeQueue(ctrl_pol, controller=AdaptiveFlushController(
            ctrl_pol, warmup_requests=4))

    results["adaptive"] = _scenarios(mp, adaptive_queue, fast=fast)

    rows = []
    for name, r in results.items():
        derived = (f"burst_rows_s={r['burst_rows_s']:.0f};"
                   f"trickle_p50_ms={r['trickle_p50_ms']:.2f};"
                   f"trickle_p99_ms={r['trickle_p99_ms']:.2f}")
        rows.append((f"tune/serve_{name}", 0.0, derived))
    return rows, results


def tune_rows(fast=False):
    """benchmarks.run entry: kernel + serving CSV rows."""
    rows = kernel_rows(fast=fast)
    srows, _ = serving_rows(fast=fast)
    return rows + srows


# ------------------------------------------------------------- output ------
def _markdown(krows, results):
    out = ["### Autotuned fused_mlp tiles", "",
           "| widths | bucket | tuned tile | tuned us | default(128) us | "
           "speedup | exact |",
           "|---|---|---|---|---|---|---|"]
    for name, _, derived in krows:
        kv = dict(item.split("=") for item in derived.split(";"))
        shape = name.split("fused_mlp_")[1]
        widths, bucket = shape.rsplit("_b", 1)
        out.append(f"| {widths} | {bucket} | {kv['tile']} | "
                   f"{kv['tuned_us']} | {kv['default_us']} | "
                   f"{kv['speedup_x']}x | {kv['exact']} |")
    out += ["", "### Adaptive vs static flush policies", "",
            "| policy | burst rows/s | trickle p50 ms | trickle p99 ms |",
            "|---|---|---|---|"]
    for name, r in results.items():
        out.append(f"| {name} | {r['burst_rows_s']:.0f} | "
                   f"{r['trickle_p50_ms']:.2f} | {r['trickle_p99_ms']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail unless tuned >= 1.0x default and adaptive "
                         f">= {CHECK_RATIO}x best-static rows/s with no "
                         "worse trickle p99")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep even if the tune cache has entries")
    ap.add_argument("--markdown", action="store_true",
                    help="print markdown tables (for EXPERIMENTS.md)")
    args = ap.parse_args()

    krows = kernel_rows(fast=args.fast, force=args.force)
    srows, results = serving_rows(fast=args.fast)
    if args.markdown:
        print(_markdown(krows, results))
    else:
        print("name,us_per_call,derived")
        for n, us, derived in krows + srows:
            print(f"{n},{us:.2f},{derived}", flush=True)

    if args.check:
        failures = []
        for name, _, derived in krows:
            kv = dict(item.split("=") for item in derived.split(";"))
            if kv["exact"] != "True":
                failures.append(f"{name}: tuned tile not bit-identical")
            if float(kv["speedup_x"]) < 1.0:
                failures.append(f"{name}: tuned {kv['speedup_x']}x < 1.0x "
                                "default")
        statics = {k: v for k, v in results.items() if k != "adaptive"}
        best_name = max(statics, key=lambda k: statics[k]["burst_rows_s"])
        best = statics[best_name]
        ad = results["adaptive"]
        if ad["burst_rows_s"] < CHECK_RATIO * best["burst_rows_s"]:
            failures.append(
                f"adaptive burst {ad['burst_rows_s']:.0f} rows/s < "
                f"{CHECK_RATIO}x best static {best_name} "
                f"({best['burst_rows_s']:.0f})")
        if ad["trickle_p99_ms"] > best["trickle_p99_ms"]:
            failures.append(
                f"adaptive trickle p99 {ad['trickle_p99_ms']:.2f}ms worse "
                f"than best-throughput static {best_name} "
                f"({best['trickle_p99_ms']:.2f}ms)")
        if failures:
            raise SystemExit("tune smoke FAILED:\n  " + "\n  ".join(failures))
        print(f"[tune smoke] OK: kernels tuned, adaptive "
              f"{ad['burst_rows_s']:.0f} rows/s vs best static "
              f"{best['burst_rows_s']:.0f} ({best_name}), trickle p99 "
              f"{ad['trickle_p99_ms']:.2f}ms vs {best['trickle_p99_ms']:.2f}ms")


if __name__ == "__main__":
    main()
