"""Multi-process pod serving benchmark: NAS-retrain-under-load.

The end-to-end scenario the ROADMAP's "serve hardening at pod scale"
item asks for: N real ``jax.distributed`` processes (spawn_local_pod)
serve a stream of cross-host mega-batches for one surrogate bundle while
the bundle is *retrained between batches* — host 0 rewrites
``params.npz`` exactly like the NAS loop does, and every host's
``InferenceEngine.get`` must pick the new weights up through mtime
staleness before the next pod batch.

Checked invariants (``--check``):

  * every round's results are bit-identical to single-process (eager,
    mesh-less) serving of the same rows under the same weights, on every
    host;
  * after each retrain, every host's outputs actually change (bundle
    invalidation propagated cross-process — nobody served stale weights);
  * every dispatched batch spans the pod axis (remote rows > 0).

Usage:
  PYTHONPATH=src python -m benchmarks.multihost_bench --check [--fast]
  PYTHONPATH=src python -m benchmarks.multihost_bench --markdown
"""
import argparse
import os
import tempfile
import time


def _pod_worker(tmp: str, rounds: int, callers_per_host: int,
                rows_per_caller: int):
    """One pod process of the retrain-under-load loop."""
    import jax
    import numpy as np

    from repro.core.engine import InferenceEngine
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_pod_mesh
    from repro.launch.multihost import barrier
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    from repro.serve import FlushPolicy, ServeQueue

    pid, nproc = jax.process_index(), jax.process_count()
    bundle = os.path.join(tmp, "surrogate")
    net = MLP((1, 5), [32, 32], 1)

    def retrain(round_no: int):
        # the NAS loop's bundle rewrite: fresh params, same architecture
        params = net.init(jax.random.PRNGKey(100 + round_no))
        save_model(bundle, net, params)

    if pid == 0:
        retrain(0)
    barrier("bundle-ready")

    rng = np.random.default_rng(42)
    full = rng.standard_normal(
        (nproc * callers_per_host * rows_per_caller, 5)).astype(np.float32)
    mine = full.reshape(nproc, callers_per_host, rows_per_caller, 5)[pid]

    mesh = make_pod_mesh()
    queue = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    rows_local = callers_per_host * rows_per_caller

    results = []
    prev = None
    t_serve = 0.0
    for rnd in range(rounds):
        t0 = time.monotonic()
        with use_mesh(mesh, multi_pod=True):
            futs = [queue.submit(bundle, mine[c])
                    for c in range(callers_per_host)]
            queue.pod_flush(bundle)
        got = np.concatenate(
            [np.asarray(f.result(timeout=120)) for f in futs])
        t_serve += time.monotonic() - t0
        # reference under the *current* weights, eager and mesh-less
        eng = InferenceEngine.get(bundle)
        ref = np.concatenate(
            [np.asarray(eng(mine[c])) for c in range(callers_per_host)])
        results.append({
            "round": rnd,
            "equal": bool(np.array_equal(got, ref)),
            "changed": bool(prev is None or not np.array_equal(got, prev)),
        })
        prev = got
        # retrain between batches: host 0 rewrites, everyone syncs so no
        # host races the rewrite with its next engine fingerprint check
        barrier(f"round-{rnd}-served")
        if pid == 0 and rnd + 1 < rounds:
            retrain(rnd + 1)
        barrier(f"round-{rnd}-retrained")

    snap = queue.stats(bundle).snapshot()
    return {
        "pid": pid,
        "nproc": nproc,
        "rounds": results,
        "rows_local": rows_local,
        "rows_per_s": rounds * rows_local / t_serve if t_serve else 0.0,
        "pod_batches": int(snap["pod_batches"]),
        "remote_rows": int(snap["remote_rows"]),
        "bucket_rows": int(snap["bucket_rows"]),
        "occupancy": float(snap["batch_occupancy"]),
    }


def run_bench(fast: bool = False, processes: int = 2,
              devices_per_host: int = 2):
    from repro.launch.multihost import spawn_local_pod
    rounds = 3 if fast else 5
    tmp = tempfile.mkdtemp(prefix="repro_mh_bench_")
    res = spawn_local_pod(
        processes, "benchmarks.multihost_bench:_pod_worker",
        (tmp, rounds, 4, 8), devices_per_host=devices_per_host,
        timeout_s=600.0)
    failures = []
    for r in res:
        for rec in r["rounds"]:
            if not rec["equal"]:
                failures.append(f"p{r['pid']} round {rec['round']}: diverged "
                                f"from single-process serving")
            if not rec["changed"]:
                failures.append(f"p{r['pid']} round {rec['round']}: outputs "
                                f"unchanged after retrain — served a stale "
                                f"bundle")
        if processes > 1 and r["remote_rows"] <= 0:
            failures.append(f"p{r['pid']}: no remote rows — batches did not "
                            f"span the pod axis")
        if r["pod_batches"] != rounds:
            failures.append(f"p{r['pid']}: {r['pod_batches']} pod batches, "
                            f"expected {rounds}")
    return res, failures


def bench_rows(fast: bool = False):
    """benchmarks.run entry: CSV rows."""
    res, failures = run_bench(fast=fast)
    total_rows_s = sum(r["rows_per_s"] for r in res)
    rounds = len(res[0]["rounds"])
    derived = (f"processes={len(res)};rounds={rounds};"
               f"rows_per_s={total_rows_s:.0f};"
               f"occupancy={res[0]['occupancy']:.2f};"
               f"remote_rows={res[0]['remote_rows']};"
               f"all_equal={not failures}")
    us = (1e6 / total_rows_s) if total_rows_s else 0.0
    return [("multihost/nas_retrain_under_load", us, derived)]


def _markdown(res):
    rounds = len(res[0]["rounds"])
    out = ["### Pod serving: NAS-retrain-under-load "
           f"({len(res)} processes, {rounds} retrain rounds)", "",
           "| host | rows/s | pod batches | remote rows | occupancy | "
           "bit-identical | invalidation seen |",
           "|---:|---:|---:|---:|---:|---|---|"]
    for r in res:
        eq = all(rec["equal"] for rec in r["rounds"])
        ch = all(rec["changed"] for rec in r["rounds"])
        out.append(f"| p{r['pid']} | {r['rows_per_s']:.0f} | "
                   f"{r['pod_batches']} | {r['remote_rows']} | "
                   f"{r['occupancy']:.2f} | {eq} | {ch} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail unless every host serves bit-identically and "
                         "sees every retrain")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-host", type=int, default=2)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    res, failures = run_bench(fast=args.fast, processes=args.processes,
                              devices_per_host=args.devices_per_host)
    if args.markdown:
        print(_markdown(res))
    else:
        print("name,us_per_call,derived")
        total = sum(r["rows_per_s"] for r in res)
        print(f"multihost/nas_retrain_under_load,"
              f"{(1e6 / total) if total else 0.0:.2f},"
              f"rows_per_s={total:.0f};all_equal={not failures}")
    if args.check:
        if failures:
            raise SystemExit("multihost bench FAILED:\n" + "\n".join(failures))
        print(f"[multihost bench] OK: {len(res)} hosts, "
              f"{len(res[0]['rounds'])} retrain rounds, bit-identical, "
              f"invalidation propagated", flush=True)


if __name__ == "__main__":
    main()
