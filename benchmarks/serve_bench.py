"""Serving-throughput benchmark: per-call vs coalesced mesh-wide batching.

Models the paper-at-scale regime: many independent callers (solver
instances / ensemble members / sweep chunks), each invoking the same
surrogate region with a small row block per sweep step.

  * per-call   — every caller runs ``MLRegion._infer`` synchronously:
                 one bridge + placement + jit dispatch per caller;
  * coalesced  — callers enqueue on a ``ServeQueue``; one flush serves
                 the whole sweep as a single padded mega-batch placed
                 over the mesh ``data`` axis.

Standalone (the CI smoke) forces an 8-device host platform so placement
really spans a mesh:

  PYTHONPATH=src python -m benchmarks.serve_bench --check

``--check`` exits non-zero unless coalesced achieves >= CHECK_SPEEDUP x
the per-call rows/s — the serving-regression gate.
"""
import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json

CHECK_SPEEDUP = 3.0
#: instrumentation gate: tracing ON must keep >= this fraction of the
#: tracing-OFF rows/s (interleaved-pair median ratio, drift-immune)
OVERHEAD_MIN_RATIO = 0.98
#: a sampled request's spans must cover >= this much of its measured
#: enqueue->resolve window (no unaccounted gaps)
TRACE_MIN_COVERAGE = 0.95
#: shadow-quality gate: sampling ON must keep >= this fraction of the
#: unsampled rows/s (same interleaved-pair minimum as the tracing gate)
SHADOW_MIN_RATIO = 0.98
#: shadow sampling fraction under test (overridable for sweeps)
SHADOW_RATE = float(os.environ.get("REPRO_SHADOW_RATE", "") or 0.05)
#: injected weight corruption must flip the drift alert to CRITICAL
#: within this many shadow samples
SHADOW_ALERT_SAMPLES = 20
#: drift-alert budget for the corruption drill.  Registered in the
#: shared per-bundle registry (``repro.quant.budgets``) rather than set
#: directly on the scorer: the check exercises the same resolution path
#: the quant gate certifies int8 eligibility through, so this bench
#: fails if the two accuracy gates ever stop reading the same numbers.
SHADOW_RMSE_BUDGET = float(
    os.environ.get("REPRO_SHADOW_RMSE_BUDGET", "") or 0.05)
#: resilience gate: the breaker board enabled (idle, CLOSED) must keep
#: >= this fraction of the board-disabled rows/s on the coalesced path
FAULT_IDLE_MIN_RATIO = 0.98
#: injected dispatch faults must trip the breaker OPEN within this many
#: failing batches
FAULT_OPEN_BATCHES = 8
#: tenancy gate: the hot tenant submits this many times the traffic of
#: each latency tenant in the skewed run
TENANT_SKEW = 10
#: tenancy gate: no tenant's p99 may degrade more than this factor vs
#: the unskewed baseline (per-tenant, measured on the same scheduler)
TENANT_P99_MAX_RATIO = 2.0
#: tenancy gate: p99s below this floor compare as equal — at sub-ms
#: latencies the ratio is scheduler noise, not starvation
TENANT_P99_FLOOR_MS = 2.0
#: residency gate: byte budget in units of one bundle's params, chosen
#: so 3 served bundles never fit resident at once
TENANT_RESIDENCY_FIT = 2.5


def _bundle(path):
    """A NAS-shaped MLP surrogate bundle (weights need not be trained:
    throughput is architecture- and batch-shaped, not accuracy-shaped)."""
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 5), [128, 128], 1)
    params = net.init(jax.random.PRNGKey(0))
    return save_model(path, net, params)


def _measure(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def serving_throughput(fast=False, *, n_callers=None, rows_per_call=8):
    """benchmarks.run entry: CSV rows only (drops the latency table)."""
    rows, _ = serving_throughput_full(fast=fast, n_callers=n_callers,
                                      rows_per_call=rows_per_call)
    return rows


def serving_throughput_full(fast=False, *, n_callers=None, rows_per_call=8):
    """CSV rows comparing per-call vs coalesced serving on the host mesh,
    plus the per-bucket measured-vs-roofline latency table."""
    import pathlib
    import tempfile

    import jax.numpy as jnp

    from repro.apps import binomial
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = n_callers or (16 if fast else 64)
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_bench_"))
    mp = _bundle(tmp / "surrogate")

    ndev = len(jax.devices())
    mesh_shape = (ndev, 1)
    mesh = make_local_mesh(mesh_shape)
    opts = binomial.make_inputs(total, seed=7)
    chunks = [opts[i:i + rows_per_call] for i in range(0, total,
                                                      rows_per_call)]

    from repro.tune import AdaptiveFlushController
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    ad_policy = FlushPolicy(max_batch_rows=total, max_pending_rows=4 * total,
                            max_delay_s=0.05)
    ad_queue = ServeQueue(ad_policy,
                          controller=AdaptiveFlushController(ad_policy))
    r_sync = binomial.make_region(rows_per_call, mode="infer", model=mp)
    r_async = binomial.make_region(rows_per_call, mode="infer_async",
                                   model=mp, serving=queue)
    r_adapt = binomial.make_region(rows_per_call, mode="infer_async",
                                   model=mp, serving=ad_queue)

    with use_mesh(mesh):
        def per_call():
            outs = [r_sync(opts=c)["out"] for c in chunks]
            jax.block_until_ready(outs)
            return outs

        def coalesced():
            handles = [r_async(opts=c) for c in chunks]
            queue.flush(mp, reason="sweep_step")
            outs = [h.result()["out"] for h in handles]
            jax.block_until_ready(outs)
            return outs

        def adaptive():
            # no explicit flush: the controller's deadline/batch trigger
            # decides when the mega-batches go out
            handles = [r_adapt(opts=c) for c in chunks]
            outs = [h.result(30)["out"] for h in handles]
            jax.block_until_ready(outs)
            return outs

        t_call = _measure(per_call)
        t_coal = _measure(coalesced)
        with ad_queue:  # dispatcher thread enforces the adaptive deadline
            t_adapt = _measure(adaptive)
        # exactness: coalesced rows must match per-call rows bit-for-bit
        same = all(
            bool((np.asarray(a) == np.asarray(b)).all())
            for a, b in zip(per_call(), coalesced()))

    st = queue.stats(mp).snapshot()
    ast = ad_queue.stats(mp).snapshot()
    pool = ad_queue._batcher.scratch.stats()
    rows_s_call = total / t_call
    rows_s_coal = total / t_coal
    rows_s_adapt = total / t_adapt
    speedup = rows_s_coal / rows_s_call
    model_err = latency_model_rows(ad_queue, mp)
    worst_err = max((abs(r["err_pct"]) for r in model_err), default=0.0)
    derived = (f"devices={ndev};callers={n_callers};"
               f"rows_per_call={rows_per_call};"
               f"percall_rows_s={rows_s_call:.0f};"
               f"coalesced_rows_s={rows_s_coal:.0f};"
               f"speedup_x={speedup:.2f};bitwise_equal={same};"
               f"occupancy={st['batch_occupancy']:.2f};"
               f"p50_ms={st['latency_p50_ms']:.2f};"
               f"p99_ms={st['latency_p99_ms']:.2f};"
               f"adaptive_rows_s={rows_s_adapt:.0f};"
               f"adaptive_p50_ms={ast['latency_p50_ms']:.2f};"
               f"adaptive_p99_ms={ast['latency_p99_ms']:.2f};"
               f"scratch_hit_rate={pool['hits'] / max(1, pool['hits'] + pool['misses']):.2f};"
               f"roofline_worst_err_pct={worst_err:.0f}")
    return ([("serve_throughput/binomial", t_coal / n_callers * 1e6,
              derived)], model_err)


def latency_model_rows(ad_queue, mp):
    """Per-bucket measured-vs-roofline batch latency error.

    The adaptive controller's deadline model starts from the roofline
    prediction and converges on measured ``ServeStats`` latencies; this
    table makes the model's drift visible (a large error means the
    open-loop prior was badly miscalibrated for this backend — exactly
    what the measured loop corrects, and what EXPERIMENTS.md should
    show).
    """
    ctrl = ad_queue.controller
    st = ad_queue.stats(mp)
    widths = ctrl._widths_cached(mp) if ctrl is not None else None
    rows = []
    if not widths:
        return rows
    for bucket, (ewma_s, n) in sorted(st.batch_latencies().items()):
        pred_s = ctrl.predict_latency_s(widths, bucket)
        err = (pred_s - ewma_s) / ewma_s * 100.0 if ewma_s > 0 else 0.0
        rows.append({"bucket": bucket, "batches": n,
                     "measured_ms": ewma_s * 1e3,
                     "roofline_ms": pred_s * 1e3, "err_pct": err})
    return rows


def export_trace(path) -> None:
    """Write the Chrome trace + metrics artifacts and gate span coverage.

    The trace must account for each sampled request's whole
    enqueue->resolve window: queue.submit + serve.request tile it by
    construction, so any request whose union coverage drops below
    :data:`TRACE_MIN_COVERAGE` means an instrumentation gap crept into
    the serve path.
    """
    from repro.obs import TRACER, default_registry, request_coverage
    path = pathlib.Path(path)
    events = TRACER.export_chrome_trace(path)
    # sampled = requests whose span set is complete in the ring (the ring
    # evicts oldest-first, so early-warmup requests may be partial)
    full = {t for t in
            ( (e.get("args") or {}).get("trace") for e in events
              if e["name"] == "queue.submit" )
            if t is not None}
    cov = {t: c for t, c in request_coverage(events).items()
           if t in full and c["spans"] >= 2}
    if not cov:
        raise SystemExit("--trace: no fully-sampled request in the trace "
                         "(ring too small for this workload?)")
    worst = min(cov.values(), key=lambda c: c["coverage"])
    metrics = default_registry()
    path.with_suffix(".metrics.json").write_text(
        json.dumps(metrics.collect(), indent=1))
    path.with_suffix(".prom").write_text(metrics.dump())
    print(f"[serve trace] {len(events)} events -> {path}; "
          f"{len(cov)} sampled requests, worst coverage "
          f"{worst['coverage']:.3f} over {worst['window_us']:.0f}us",
          flush=True)
    if worst["coverage"] < TRACE_MIN_COVERAGE:
        raise SystemExit(
            f"--trace FAILED: worst request coverage {worst['coverage']:.3f}"
            f" < {TRACE_MIN_COVERAGE} (unaccounted gap in the serve path)")


def overhead_check(fast=False, pairs=50):
    """Gate instrumentation cost: tracing on vs off, interleaved pairs.

    Runs the coalesced serve path (the instrumented hot path) with the
    tracer toggled every other run; the gate compares the *minimum* off
    time against the minimum on time.  Scheduler noise only ever adds
    time, so each minimum estimates that path's true cost; the tight
    interleave guarantees both sets sample the same machine conditions
    (a sequential off-block/on-block comparison is dominated by drift —
    measured, the drift between two such blocks exceeds the effect being
    gated); and the within-pair order alternates each pair because the
    second run of a pair measures systematically slower than the first
    (also larger than the effect under test).  GC is paused during
    timing, as ``timeit`` does.  Fails below :data:`OVERHEAD_MIN_RATIO`.
    """
    import gc
    import tempfile

    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.obs import TRACER, disable_tracing, enable_tracing
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = 16 if fast else 32
    rows_per_call = 8
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_obs_bench_"))
    mp = _bundle(tmp / "surrogate")
    mesh = make_local_mesh((len(jax.devices()), 1))
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    rng = np.random.default_rng(3)
    chunks = [rng.standard_normal((rows_per_call, 5)).astype(np.float32)
              for _ in range(n_callers)]

    def run_once():
        futs = [queue.submit(mp, c) for c in chunks]
        queue.flush(mp, reason="bench")
        for f in futs:
            f.result(30)

    was_enabled = TRACER.enabled
    offs, ons = [], []
    try:
        with use_mesh(mesh):
            disable_tracing()
            _measure(run_once, reps=1, warmup=3)  # compile outside timing
            gc.disable()
            try:
                for i in range(pairs):
                    halves = [(False, offs), (True, ons)]
                    if i % 2:
                        halves.reverse()
                    for on, times in halves:
                        enable_tracing() if on else disable_tracing()
                        t0 = time.perf_counter()
                        run_once()
                        times.append(time.perf_counter() - t0)
                    if i % 10 == 9:  # bound ring/heap growth, untimed
                        TRACER.clear()
                        gc.collect()
            finally:
                gc.enable()
            TRACER.clear()
    finally:
        TRACER.enabled = was_enabled
    ratio = min(offs) / min(ons)
    print(f"[serve obs overhead] traced serving retains "
          f"{ratio * 100:.1f}% of untraced rows/s over {pairs} "
          f"interleaved pairs (off {min(offs) * 1e3:.3f}ms / on "
          f"{min(ons) * 1e3:.3f}ms)", flush=True)
    if ratio < OVERHEAD_MIN_RATIO:
        raise SystemExit(
            f"obs overhead gate FAILED: traced/untraced rows/s "
            f"ratio {ratio:.3f} < {OVERHEAD_MIN_RATIO} (instrumentation "
            f"costs more than {100 * (1 - OVERHEAD_MIN_RATIO):.0f}%)")
    return ratio


def shadow_overhead_check(fast=False, pairs=50):
    """Gate shadow-sampling cost on the serving hot path.

    The coalesced region path (``MLRegion._infer_async`` — where the
    sampling hook lives) runs with shadow sampling toggled every other
    run at :data:`SHADOW_RATE`, tracing off on both sides, and the gate
    compares minimum unsampled time against minimum sampled time — the
    same interleaved-pair methodology as :func:`overhead_check` (see
    there for why min/min + alternating within-pair order + paused GC).
    The accurate-path replay cost lands on the scorer's background
    thread by design; what this gates is the hot-path hook (an attribute
    check + Bernoulli draw) plus any GIL pressure the replays leak into
    the serving threads.
    """
    import gc
    import tempfile

    from repro.apps import binomial
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.obs import SHADOW, TRACER, disable_tracing
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = 16 if fast else 32
    rows_per_call = 8
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_shadow_bench_"))
    mp = _bundle(tmp / "surrogate")
    mesh = make_local_mesh((len(jax.devices()), 1))
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    region = binomial.make_region(rows_per_call, mode="infer_async",
                                  model=mp, serving=queue)
    opts = binomial.make_inputs(total, seed=11)
    chunks = [opts[i:i + rows_per_call]
              for i in range(0, total, rows_per_call)]

    def run_once():
        handles = [region(opts=c) for c in chunks]
        queue.flush(mp, reason="bench")
        for h in handles:
            h.result(30)

    was_traced, was_shadow = TRACER.enabled, SHADOW.enabled
    prev_rate = SHADOW.rate
    offs, ons = [], []
    try:
        with use_mesh(mesh):
            disable_tracing()
            # warmup at rate 1.0: compiles the surrogate path AND the
            # accurate replay (binomial's 256-step scan) and spins up
            # the scorer thread, all outside timing
            SHADOW.enable(rate=1.0)
            _measure(run_once, reps=1, warmup=3)
            SHADOW.flush(60)
            SHADOW.disable()
            gc.disable()
            try:
                for i in range(pairs):
                    halves = [(False, offs), (True, ons)]
                    if i % 2:
                        halves.reverse()
                    for on, times in halves:
                        if on:
                            SHADOW.enable(rate=SHADOW_RATE)
                        else:
                            SHADOW.disable()
                        t0 = time.perf_counter()
                        run_once()
                        times.append(time.perf_counter() - t0)
                        # drain the scorer after every half, untimed:
                        # residual replays must not bleed GIL time into
                        # the next timed run (that is backlog cost, not
                        # the hot-path hook cost this gates)
                        SHADOW.disable()
                        SHADOW.flush(30)
                    if i % 10 == 9:
                        gc.collect()
            finally:
                gc.enable()
            SHADOW.disable()
            SHADOW.flush(30)
    finally:
        TRACER.enabled = was_traced
        SHADOW.rate = prev_rate
        SHADOW.enabled = was_shadow
    ratio = min(offs) / min(ons)
    print(f"[shadow overhead] sampling at {SHADOW_RATE:.0%} retains "
          f"{ratio * 100:.1f}% of unsampled rows/s over {pairs} "
          f"interleaved pairs (off {min(offs) * 1e3:.3f}ms / on "
          f"{min(ons) * 1e3:.3f}ms)", flush=True)
    if ratio < SHADOW_MIN_RATIO:
        raise SystemExit(
            f"shadow overhead gate FAILED: sampled/unsampled rows/s "
            f"ratio {ratio:.3f} < {SHADOW_MIN_RATIO} (shadow sampling "
            f"costs more than {100 * (1 - SHADOW_MIN_RATIO):.0f}%)")
    return ratio


def shadow_alert_check():
    """Injected weight corruption must actually fire the drift alert.

    A region whose accurate function *is* the surrogate's own original
    forward serves through the queue with shadow sampling at 100%: the
    clean run scores RMSE ~0 and must stay OK.  Then the bundle is
    rewritten with corrupted weights — the engine's mtime-staleness
    reload picks them up on the next batch — and the RMSE EWMA must
    cross the budget and latch CRITICAL within
    :data:`SHADOW_ALERT_SAMPLES` shadow samples, visibly: ``/healthz``
    flips 200 -> 503, ``/metrics`` carries ``repro_quality_rmse`` (and
    validates as Prometheus text), and the pod snapshot reports the
    CRITICAL state.
    """
    import tempfile
    import urllib.error
    import urllib.request

    from repro.core import approx_ml, tensor_functor
    from repro.nn.serialize import load_model, save_model
    from repro.obs import (MONITOR, SHADOW, SLO, ObsServer, pod_snapshot,
                           validate_exposition)
    from repro.serve import FlushPolicy, ServeQueue

    rows_per_call, n_callers = 8, 8
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_shadow_alert_"))
    mp = _bundle(tmp / "surrogate")
    net, params0, _ = load_model(mp)
    ref_apply = jax.jit(net.apply)

    def fn(x):
        return {"out": ref_apply(params0, x)}

    rngs = {"i": (0, rows_per_call)}
    qin = tensor_functor("qin: [i, 0:5] = ([i, 0:5])")
    qout = tensor_functor("qout: [i, 0:1] = ([i, 0:1])")
    queue = ServeQueue(FlushPolicy(max_batch_rows=1024))
    region = approx_ml(fn, name="shadow_probe",
                       inputs={"x": (qin, rngs)},
                       outputs={"out": (qout, rngs)},
                       mode="infer_async", model=mp, serving=queue)
    rng = np.random.default_rng(5)
    chunks = [rng.standard_normal((rows_per_call, 5)).astype(np.float32)
              for _ in range(n_callers)]

    was_shadow, prev_rate = SHADOW.enabled, SHADOW.rate
    SHADOW.enable(rate=1.0)
    # through the shared registry, NOT SHADOW.set_budget: the scorer's
    # fallback chain (explicit > quant.budgets > default) must resolve it
    from repro.quant.budgets import set_rmse_budget
    set_rmse_budget(mp, SHADOW_RMSE_BUDGET)
    MONITOR.track(mp, queue.stats(mp),
                  SLO(latency_threshold_s=5.0, windows_s=(30.0, 120.0),
                      min_events=1))
    server = ObsServer().start().watch_queue("serve", queue)

    def run_batch():
        handles = [region(x=c) for c in chunks]
        queue.flush(mp, reason="bench")
        for h in handles:
            h.result(30)

    def healthz_code():
        try:
            with urllib.request.urlopen(server.url("/healthz"),
                                        timeout=10) as r:
                return r.status
        except urllib.error.HTTPError as e:
            return e.code

    try:
        # clean phase: surrogate == accurate fn, alert must stay OK
        for _ in range(3):
            run_batch()
        if not SHADOW.flush(60):
            raise SystemExit("shadow alert check: scorer backlog did not "
                             "drain on the clean run")
        clean = SHADOW.snapshot()["keys"][mp]
        code = healthz_code()
        print(f"[shadow alert] clean: rmse_ewma="
              f"{clean['rmse_ewma']:.3g} state={clean['state']} "
              f"healthz={code}", flush=True)
        if clean["state"] != "OK" or code != 200:
            raise SystemExit(
                f"shadow alert check FAILED: clean run reports "
                f"{clean['state']}/HTTP {code} (expected OK/200)")

        # corrupt the bundle in place; the engine's mtime fingerprint
        # reloads it on the next batch while fn keeps the true params
        bad = jax.tree_util.tree_map(lambda p: p + 0.5, params0)
        save_model(mp, net, bad)
        fired_at = None
        for batch in range(SHADOW_ALERT_SAMPLES):
            run_batch()
            SHADOW.flush(60)
            if SHADOW.state(mp) == "CRITICAL":
                fired_at = batch + 1
                break
        snap = SHADOW.snapshot()["keys"][mp]
        code = healthz_code()
        print(f"[shadow alert] corrupted: rmse_ewma="
              f"{snap['rmse_ewma']:.3g} state={snap['state']} "
              f"fired_after={fired_at} batches healthz={code}", flush=True)
        if fired_at is None:
            raise SystemExit(
                f"shadow alert check FAILED: drift alert never reached "
                f"CRITICAL within {SHADOW_ALERT_SAMPLES} corrupted "
                f"batches (rmse_ewma={snap['rmse_ewma']:.3g}, budget "
                f"{SHADOW_RMSE_BUDGET})")
        if code != 503:
            raise SystemExit(
                f"shadow alert check FAILED: /healthz returned {code} "
                f"with a CRITICAL drift alert (expected 503)")
        with urllib.request.urlopen(server.url("/metrics"),
                                    timeout=10) as r:
            text = r.read().decode("utf-8")
        validate_exposition(text)
        if "repro_quality_rmse{" not in text:
            raise SystemExit("shadow alert check FAILED: /metrics has no "
                             "repro_quality_rmse samples")
        pod_q = pod_snapshot()[0]["quality"]["keys"].get(mp, {})
        if pod_q.get("state") != "CRITICAL":
            raise SystemExit(
                f"shadow alert check FAILED: pod snapshot reports "
                f"{pod_q.get('state')!r}, expected CRITICAL")
        print(f"[shadow alert] OK: corruption fired CRITICAL after "
              f"{fired_at} batches; healthz 503; exposition valid; pod "
              f"snapshot agrees", flush=True)
    finally:
        server.stop()
        MONITOR.untrack(mp)
        SHADOW.rate = prev_rate
        SHADOW.enabled = was_shadow


def fault_overhead_check(fast=False, pairs=50):
    """Gate the breaker's idle cost on the serving hot path.

    A CLOSED breaker is pure overhead: one ``allow()`` per request
    (a lock acquire + two branches) in ``MLRegion._infer_async`` plus
    one ``record_success`` per dispatched batch in the batcher.  The
    gate runs the coalesced region path with the :data:`BREAKERS` board
    toggled every other run — the same interleaved-pair min/min
    methodology as :func:`overhead_check` (see there for why min/min +
    alternating within-pair order + paused GC) — and fails if the
    enabled side retains less than :data:`FAULT_IDLE_MIN_RATIO` of the
    disabled side's rows/s.
    """
    import gc
    import tempfile

    from repro.apps import binomial
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.obs import SHADOW, TRACER, disable_tracing
    from repro.resilience import BREAKERS
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = 16 if fast else 32
    rows_per_call = 8
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_fault_bench_"))
    mp = _bundle(tmp / "surrogate")
    mesh = make_local_mesh((len(jax.devices()), 1))
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    region = binomial.make_region(rows_per_call, mode="infer_async",
                                  model=mp, serving=queue)
    opts = binomial.make_inputs(total, seed=13)
    chunks = [opts[i:i + rows_per_call]
              for i in range(0, total, rows_per_call)]

    def run_once():
        handles = [region(opts=c) for c in chunks]
        queue.flush(mp, reason="bench")
        for h in handles:
            h.result(30)

    was_traced, was_shadow = TRACER.enabled, SHADOW.enabled
    was_breaker = BREAKERS.enabled
    offs, ons = [], []
    try:
        with use_mesh(mesh):
            disable_tracing()
            SHADOW.enabled = False
            BREAKERS.enabled = True
            _measure(run_once, reps=1, warmup=3)  # compile outside timing
            gc.disable()
            try:
                for i in range(pairs):
                    halves = [(False, offs), (True, ons)]
                    if i % 2:
                        halves.reverse()
                    for on, times in halves:
                        BREAKERS.enabled = on
                        t0 = time.perf_counter()
                        run_once()
                        times.append(time.perf_counter() - t0)
                    if i % 10 == 9:
                        gc.collect()
            finally:
                gc.enable()
    finally:
        TRACER.enabled = was_traced
        SHADOW.enabled = was_shadow
        BREAKERS.enabled = was_breaker
        BREAKERS.reset(mp)
    ratio = min(offs) / min(ons)
    print(f"[breaker idle overhead] breaker-enabled serving retains "
          f"{ratio * 100:.1f}% of breaker-disabled rows/s over {pairs} "
          f"interleaved pairs (off {min(offs) * 1e3:.3f}ms / on "
          f"{min(ons) * 1e3:.3f}ms)", flush=True)
    if ratio < FAULT_IDLE_MIN_RATIO:
        raise SystemExit(
            f"breaker idle overhead gate FAILED: enabled/disabled "
            f"rows/s ratio {ratio:.3f} < {FAULT_IDLE_MIN_RATIO} (an idle "
            f"breaker costs more than "
            f"{100 * (1 - FAULT_IDLE_MIN_RATIO):.0f}%)")
    return ratio


def fault_drill_check():
    """Injected dispatch faults must trip the breaker and lose nothing.

    Drives the breaker through its full CLOSED → OPEN → HALF_OPEN →
    CLOSED cycle end-to-end through the public serving path:

      1. clean phase — batches through the queue resolve finite and the
         breaker stays CLOSED;
      2. fault phase — ``engine.apply:raise:every=1`` makes every batch
         dispatch fail.  Every handle must still resolve (zero-lost:
         ``AsyncRegionResult.result`` degrades to the accurate path) and
         the breaker must trip OPEN within :data:`FAULT_OPEN_BATCHES`
         batches; while OPEN, submits short-circuit to the accurate
         path without touching the queue at all;
      3. recovery phase — faults cleared, the cooldown elapses, probe
         traffic closes the breaker again.

    The cycle must be observable: an ``ObsServer`` scrape during the
    OPEN phase must carry ``repro_resilience_breaker_state``, the
    transition counter and the fallback counter (and validate as
    Prometheus text).  Prints time-to-open, the measured fallback
    latency cost, and time-to-recover for EXPERIMENTS.md.
    """
    import tempfile
    import urllib.request

    from repro.core import approx_ml, tensor_functor
    from repro.obs import ObsServer, validate_exposition
    from repro.resilience import BREAKERS, FAULTS, BreakerPolicy
    from repro.resilience.breaker import CLOSED, OPEN
    from repro.serve import FlushPolicy, ServeQueue

    rows_per_call, n_callers = 8, 8
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_fault_drill_"))
    mp = _bundle(tmp / "surrogate")
    rngs = {"i": (0, rows_per_call)}
    fin = tensor_functor("fin: [i, 0:5] = ([i, 0:5])")
    fout = tensor_functor("fout: [i, 0:1] = ([i, 0:1])")
    queue = ServeQueue(FlushPolicy(max_batch_rows=1024))
    region = approx_ml(lambda x: {"out": x[:, :1] * 2.0},
                       name="fault_drill", inputs={"x": (fin, rngs)},
                       outputs={"out": (fout, rngs)},
                       mode="infer_async", model=mp, serving=queue)
    cooldown = 2.0
    breaker = BREAKERS.configure(mp, BreakerPolicy(
        failure_threshold=0.5, ewma_alpha=0.5, min_samples=4,
        open_cooldown_s=cooldown, probe_n=2, probe_every=1))
    rng = np.random.default_rng(7)
    chunks = [rng.standard_normal((rows_per_call, 5)).astype(np.float32)
              for _ in range(n_callers)]
    submitted = resolved = 0

    def run_batch():
        nonlocal submitted, resolved
        handles = [region(x=c) for c in chunks]
        submitted += len(handles)
        outs = []
        queue.flush(mp, reason="bench")
        for h in handles:
            out = h.result(30)
            if not np.all(np.isfinite(np.asarray(out["out"]))):
                raise SystemExit("fault drill FAILED: non-finite rows "
                                 "reached a caller")
            outs.append(out)
        resolved += len(outs)
        return handles

    was_breaker = BREAKERS.enabled
    BREAKERS.enabled = True
    server = ObsServer().start()
    try:
        # 1. clean phase: surrogate serves, breaker stays CLOSED
        for _ in range(3):
            run_batch()
        if breaker.state != CLOSED:
            raise SystemExit(f"fault drill FAILED: breaker is "
                             f"{breaker.state} after clean traffic")

        # 2. fault phase: every dispatch raises; handles degrade to the
        #    accurate path and the failure EWMA trips the breaker
        FAULTS.configure("engine.apply:raise:every=1")
        t0 = time.perf_counter()
        open_after = None
        for batch in range(FAULT_OPEN_BATCHES):
            run_batch()
            if breaker.state != CLOSED:
                open_after = batch + 1
                break
        time_to_open = time.perf_counter() - t0
        snap = breaker.snapshot()
        if open_after is None:
            raise SystemExit(
                f"fault drill FAILED: breaker still CLOSED after "
                f"{FAULT_OPEN_BATCHES} all-failing batches ({snap})")
        print(f"[fault drill] tripped {snap['state']} after {open_after} "
              f"failing batch(es) in {time_to_open * 1e3:.0f}ms "
              f"(ewma={snap['ewma']})", flush=True)

        # while OPEN every submit short-circuits: accurate-path answers,
        # nothing enqueued.  Time it — this is the fallback latency cost.
        t0 = time.perf_counter()
        handles = run_batch()
        fallback_ms = (time.perf_counter() - t0) * 1e3
        if any(h.deferred() for h in handles):
            raise SystemExit("fault drill FAILED: an OPEN breaker let a "
                             "request reach the serve queue")
        if queue.depth() != 0:
            raise SystemExit(f"fault drill FAILED: {queue.depth()} rows "
                             f"parked on the queue while OPEN")
        print(f"[fault drill] OPEN short-circuit: {n_callers} calls "
              f"served accurately in {fallback_ms:.0f}ms, queue untouched",
              flush=True)

        # the cycle must be scrapeable while it is happening
        with urllib.request.urlopen(server.url("/metrics"),
                                    timeout=10) as r:
            text = r.read().decode("utf-8")
        validate_exposition(text)
        for family in ("repro_resilience_breaker_state{",
                       "repro_resilience_breaker_transitions_total{",
                       "repro_resilience_fallback_total{",
                       "repro_resilience_faults_injected_total{"):
            if family not in text:
                raise SystemExit(f"fault drill FAILED: /metrics has no "
                                 f"{family.rstrip('{')} samples")

        # 3. recovery: faults off, cooldown elapses, probes re-close it
        FAULTS.clear()
        t0 = time.perf_counter()
        time.sleep(cooldown + 0.05)
        recovered_after = None
        for batch in range(6):
            run_batch()
            if breaker.state == CLOSED:
                recovered_after = batch + 1
                break
        time_to_recover = time.perf_counter() - t0
        if recovered_after is None:
            raise SystemExit(f"fault drill FAILED: breaker never closed "
                             f"after recovery ({breaker.snapshot()})")
        if breaker.state == OPEN:
            raise SystemExit("fault drill FAILED: breaker re-opened on "
                             "clean probe traffic")
        print(f"[fault drill] recovered CLOSED after {recovered_after} "
              f"probe batch(es), {time_to_recover:.2f}s past fault "
              f"clear (cooldown {cooldown}s)", flush=True)

        if resolved != submitted:
            raise SystemExit(f"fault drill FAILED: {submitted} submitted "
                             f"but only {resolved} resolved")
        print(f"[fault drill] OK: {submitted}/{submitted} requests "
              f"resolved finite across the full "
              f"CLOSED→OPEN→HALF_OPEN→CLOSED cycle; zero lost", flush=True)
        return {"time_to_open_s": time_to_open,
                "fallback_ms": fallback_ms,
                "time_to_recover_s": time_to_recover}
    finally:
        server.stop()
        FAULTS.clear()
        BREAKERS.enabled = was_breaker
        BREAKERS.reset(mp)


def _tenant_board():
    """3 tenants, mixed QoS: two latency-tier (unequal weights) and one
    throughput-tier tenant that will carry the skewed burst."""
    from repro.serve import TenantBoard, TenantSpec
    return TenantBoard([
        TenantSpec("lat-a", tier="latency", weight=2.0),
        TenantSpec("bulk", tier="throughput", weight=1.0),
        TenantSpec("lat-b", tier="latency", weight=1.0),
    ])


def _tenant_run(bundles, *, skew, rounds, k_chunks=3, rows_per_chunk=8):
    """Drive one tenant-traffic run; returns the board's snapshot.

    Per round every tenant submits ``k_chunks`` chunks against its own
    bundle (the hot tenant submits ``skew``x that), the hot tenant first
    — the worst case for FIFO — then the round drains with an explicit
    all-keys flush, whose key order the tenancy board picks by DRR under
    overload.  Thread-free queue: deterministic timing, caller's thread.
    """
    from repro.serve import FlushPolicy, ServeQueue
    board = _tenant_board()
    policy = FlushPolicy(max_batch_rows=64, max_pending_rows=1 << 16)
    queue = ServeQueue(policy, tenancy=board)
    rng = np.random.default_rng(11)
    chunk = {t: rng.standard_normal((rows_per_chunk, 5)).astype(np.float32)
             for t in bundles}
    order = ["bulk", "lat-a", "lat-b"]

    def one_round():
        futs = []
        for t in order:
            reps = k_chunks * (skew if t == "bulk" else 1)
            futs += [queue.submit(bundles[t], chunk[t], tenant=t)
                     for _ in range(reps)]
        queue.flush()
        for f in futs:
            f.result(30)

    one_round()  # warmup: compiles land outside the measured rounds
    board_fresh = _tenant_board()
    queue.tenancy = board_fresh
    queue._batcher.tenancy = board_fresh
    for _ in range(rounds):
        one_round()
    return board_fresh.snapshot()


def tenant_check(fast=False, markdown=False):
    """Gate the multi-tenant control plane end to end.

    Three gates, per the control-plane contract:

      1. **isolation** — under :data:`TENANT_SKEW`x load skew toward the
         throughput tenant, no tenant's p99 may degrade more than
         :data:`TENANT_P99_MAX_RATIO`x vs the unskewed baseline on the
         same DRR scheduler;
      2. **zero drops** — every submitted request resolves in both runs
         (admission throttles at the door; it never loses work);
      3. **residency** — with the byte budget set so only
         ~:data:`TENANT_RESIDENCY_FIT` of 3 served bundles fit resident,
         the budget is never exceeded (peak watermark), at least one
         LRU eviction happens, and every evicted bundle serves again
         through the shared invalidate->reload path.
    """
    import tempfile

    from repro.core.engine import InferenceEngine
    from repro.serve import FlushPolicy, ServeQueue
    from repro.serve.residency import RESIDENCY

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="tenant_bench_"))
    bundles = {t: _bundle(tmp / t) for t in ("lat-a", "bulk", "lat-b")}
    rounds = 8 if fast else 16

    base = _tenant_run(bundles, skew=1, rounds=rounds)
    skewed = _tenant_run(bundles, skew=TENANT_SKEW, rounds=rounds)

    results = []
    failures = []
    drops_total = 0
    for t in sorted(bundles):
        b99 = base[t]["latency_p99_ms"]
        s99 = skewed[t]["latency_p99_ms"]
        drops = base[t]["dropped_rows"] + skewed[t]["dropped_rows"]
        drops_total += drops
        ratio = (max(s99, TENANT_P99_FLOOR_MS)
                 / max(b99, TENANT_P99_FLOOR_MS))
        results.append({
            "tenant": t, "tier": base[t]["tier"],
            "weight": base[t]["weight"],
            "base_p99_ms": b99, "skew_p99_ms": s99, "p99_ratio": ratio,
            "served_rows_skew": skewed[t]["served_rows"],
            "occupancy_skew": skewed[t]["occupancy"],
            "dropped_rows": drops,
        })
        if ratio > TENANT_P99_MAX_RATIO:
            failures.append(
                f"tenant {t!r} p99 degraded {ratio:.2f}x under "
                f"{TENANT_SKEW}x skew ({b99:.2f}ms -> {s99:.2f}ms, "
                f"max {TENANT_P99_MAX_RATIO}x)")
    if drops_total:
        failures.append(f"{drops_total} rows dropped (must be zero)")

    # --- residency: 3 bundles served through a budget fitting ~2.5 ---
    InferenceEngine.invalidate()  # scenario-local byte accounting
    one = InferenceEngine.get(bundles["lat-a"]).resident_nbytes
    budget = int(one * TENANT_RESIDENCY_FIT)
    RESIDENCY.set_budget(budget)
    RESIDENCY.reset_stats()
    res_drops = 0
    try:
        for b in bundles.values():
            t = RESIDENCY.prefetch(b)  # admission-time warm
            if t is not None:
                t.join(30)
        board = _tenant_board()
        queue = ServeQueue(FlushPolicy(max_batch_rows=128,
                                       max_pending_rows=1 << 16),
                           tenancy=board)
        rng = np.random.default_rng(13)
        for _ in range(3):
            futs = [queue.submit(b, rng.standard_normal((8, 5))
                                 .astype(np.float32), tenant=t)
                    for t, b in bundles.items()]
            queue.flush()
            for f in futs:
                f.result(30)
        rsnap = RESIDENCY.snapshot()
        res_drops = sum(s["dropped_rows"]
                        for s in board.snapshot().values())
    finally:
        RESIDENCY.set_budget(None)
    if rsnap["peak_bytes"] > budget:
        failures.append(f"residency budget exceeded: peak "
                        f"{rsnap['peak_bytes']}B > budget {budget}B")
    if rsnap["evictions"] < 1:
        failures.append("residency never evicted despite 3 bundles over "
                        f"a {TENANT_RESIDENCY_FIT}-bundle budget")
    if res_drops:
        failures.append(f"residency phase dropped {res_drops} rows")

    residency = {"budget_bytes": budget, "peak_bytes": rsnap["peak_bytes"],
                 "evictions": rsnap["evictions"],
                 "prefetches": rsnap["prefetches"],
                 "resident_bundles": rsnap["resident_bundles"],
                 "bundle_bytes": one}
    if markdown:
        print(_tenant_markdown(results, residency))
    for r in results:
        print(f"[tenant {r['tenant']}] tier={r['tier']} "
              f"w={r['weight']:.0f} base_p99={r['base_p99_ms']:.2f}ms "
              f"skew_p99={r['skew_p99_ms']:.2f}ms "
              f"ratio={r['p99_ratio']:.2f} drops={r['dropped_rows']}",
              flush=True)
    print(f"[tenant residency] peak={residency['peak_bytes']}B "
          f"budget={budget}B evictions={residency['evictions']} "
          f"prefetches={residency['prefetches']}", flush=True)
    if failures:
        raise SystemExit("tenant gate FAILED: " + "; ".join(failures))
    print(f"[tenant gate] OK: {len(results)} tenants isolated under "
          f"{TENANT_SKEW}x skew, zero drops, residency within budget",
          flush=True)
    return {"tenants": results, "residency": residency,
            "skew": TENANT_SKEW, "rounds": rounds,
            "gate": {"p99_max_ratio": TENANT_P99_MAX_RATIO,
                     "worst_p99_ratio": max(r["p99_ratio"]
                                            for r in results)}}


def _tenant_markdown(results, residency):
    out = ["### Multi-tenant isolation "
           f"({TENANT_SKEW}x skew toward `bulk`)", "",
           "| tenant | tier | weight | base p99 | skewed p99 | ratio | "
           "drops |", "|---|---|---:|---:|---:|---:|---:|"]
    for r in results:
        out.append(f"| {r['tenant']} | {r['tier']} | {r['weight']:.0f} | "
                   f"{r['base_p99_ms']:.2f}ms | {r['skew_p99_ms']:.2f}ms | "
                   f"{r['p99_ratio']:.2f}x | {r['dropped_rows']} |")
    out += ["", f"Residency: peak {residency['peak_bytes']}B of "
            f"{residency['budget_bytes']}B budget "
            f"({residency['evictions']} evictions, "
            f"{residency['prefetches']} prefetches, "
            f"{residency['resident_bundles']} of 3 bundles resident)."]
    return "\n".join(out)


def _markdown(rows, model_err):
    kv = dict(item.split("=", 1) for item in rows[0][2].split(";"))
    out = ["### Serving throughput (8-device host mesh)", "",
           "| path | rows/s |", "|---|---:|",
           f"| per-call `MLRegion._infer` | {kv['percall_rows_s']} |",
           f"| coalesced `ServeQueue` | {kv['coalesced_rows_s']} |",
           f"| adaptive controller | {kv['adaptive_rows_s']} |",
           "", "### Measured vs roofline batch latency (adaptive queue)",
           "",
           "| bucket | batches | measured ms | roofline ms | error |",
           "|---:|---:|---:|---:|---:|"]
    for r in model_err:
        out.append(f"| {r['bucket']} | {r['batches']} | "
                   f"{r['measured_ms']:.3f} | {r['roofline_ms']:.3f} | "
                   f"{r['err_pct']:+.0f}% |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help=f"fail unless coalesced >= {CHECK_SPEEDUP}x per-call"
                         " rows/s and outputs are bitwise equal")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--markdown", action="store_true",
                    help="print markdown tables incl. the per-bucket "
                         "measured-vs-roofline latency error "
                         "(for EXPERIMENTS.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run with tracing on, write the Chrome trace + "
                         "metrics snapshots to PATH(.metrics.json/.prom) "
                         "and fail unless every sampled request's spans "
                         f"cover >= {TRACE_MIN_COVERAGE:.0%} of its "
                         "enqueue->resolve latency")
    ap.add_argument("--overhead-check", action="store_true",
                    help="gate instrumentation cost: tracing on must "
                         f"retain >= {OVERHEAD_MIN_RATIO:.0%} of untraced "
                         "rows/s (interleaved-pair median ratio)")
    ap.add_argument("--shadow-check", action="store_true",
                    help="gate shadow-quality cost (sampling at "
                         f"{SHADOW_RATE:.0%} must retain >= "
                         f"{SHADOW_MIN_RATIO:.0%} of unsampled rows/s) and "
                         "prove injected weight corruption fires the "
                         "CRITICAL drift alert")
    ap.add_argument("--fault-check", action="store_true",
                    help="gate breaker idle cost (enabled must retain "
                         f">= {FAULT_IDLE_MIN_RATIO:.0%} of disabled "
                         "rows/s) and drive the full fault drill: "
                         "injected dispatch faults trip the breaker "
                         "OPEN, zero requests lost, recovery observable "
                         "on /metrics")
    ap.add_argument("--tenant-check", action="store_true",
                    help="gate the multi-tenant control plane: under "
                         f"{TENANT_SKEW}x load skew no tenant's p99 may "
                         f"degrade > {TENANT_P99_MAX_RATIO}x vs the "
                         "unskewed baseline, zero requests dropped, and "
                         "the residency byte budget is never exceeded "
                         "while serving more bundles than fit resident")
    args = ap.parse_args()
    if args.tenant_check:
        # self-contained scenario (own queues/bundles): run before the
        # throughput sweep so its latency windows see only tenant traffic
        payload = tenant_check(fast=args.fast, markdown=args.markdown)
        write_bench_json("tenant", payload)
        return
    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()
    rows, model_err = serving_throughput_full(fast=args.fast)
    if args.trace:
        export_trace(args.trace)
    if args.markdown:
        print(_markdown(rows, model_err))
    else:
        print("name,us_per_call,derived")
        for n, us, derived in rows:
            print(f"{n},{us:.2f},{derived}", flush=True)
    kv = dict(item.split("=", 1) for item in rows[0][2].split(";"))
    bench_json = {
        "rows_per_s": float(kv["coalesced_rows_s"]),
        "percall_rows_per_s": float(kv["percall_rows_s"]),
        "adaptive_rows_per_s": float(kv["adaptive_rows_s"]),
        "p50_ms": float(kv["p50_ms"]), "p99_ms": float(kv["p99_ms"]),
        "occupancy": float(kv["occupancy"]),
        "gate": {"speedup_x": float(kv["speedup_x"]),
                 "required_speedup_x": CHECK_SPEEDUP,
                 "bitwise_equal": kv["bitwise_equal"] == "True"},
    }
    if args.check:
        speedup = float(kv["speedup_x"])
        same = kv["bitwise_equal"] == "True"
        if speedup < CHECK_SPEEDUP or not same:
            write_bench_json("serve", bench_json)
            raise SystemExit(
                f"serving smoke FAILED: speedup_x={speedup:.2f} "
                f"(need >= {CHECK_SPEEDUP}) bitwise_equal={same}")
        print(f"[serve smoke] OK: {speedup:.2f}x coalesced over per-call")
    if args.overhead_check:
        bench_json["gate"]["trace_overhead_ratio"] = \
            overhead_check(fast=args.fast)
    if args.fault_check:
        fault_overhead_check(fast=args.fast)
        fault_drill_check()
    if args.shadow_check:
        bench_json["gate"]["shadow_overhead_ratio"] = \
            shadow_overhead_check(fast=args.fast)
        shadow_alert_check()
        if args.trace:
            # refresh the metrics snapshots so the exported artifacts
            # (and the CI quality report rendered from them) include the
            # shadow-quality families the checks just populated
            from repro.obs import default_registry
            path = pathlib.Path(args.trace)
            metrics = default_registry()
            path.with_suffix(".metrics.json").write_text(
                json.dumps(metrics.collect(), indent=1))
            path.with_suffix(".prom").write_text(metrics.dump())
    write_bench_json("serve", bench_json)


if __name__ == "__main__":
    main()
