"""Serving-throughput benchmark: per-call vs coalesced mesh-wide batching.

Models the paper-at-scale regime: many independent callers (solver
instances / ensemble members / sweep chunks), each invoking the same
surrogate region with a small row block per sweep step.

  * per-call   — every caller runs ``MLRegion._infer`` synchronously:
                 one bridge + placement + jit dispatch per caller;
  * coalesced  — callers enqueue on a ``ServeQueue``; one flush serves
                 the whole sweep as a single padded mega-batch placed
                 over the mesh ``data`` axis.

Standalone (the CI smoke) forces an 8-device host platform so placement
really spans a mesh:

  PYTHONPATH=src python -m benchmarks.serve_bench --check

``--check`` exits non-zero unless coalesced achieves >= CHECK_SPEEDUP x
the per-call rows/s — the serving-regression gate.
"""
import os

if __name__ == "__main__":  # must precede the first jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import pathlib
import time

import jax
import numpy as np

CHECK_SPEEDUP = 3.0
#: instrumentation gate: tracing ON must keep >= this fraction of the
#: tracing-OFF rows/s (interleaved-pair median ratio, drift-immune)
OVERHEAD_MIN_RATIO = 0.98
#: a sampled request's spans must cover >= this much of its measured
#: enqueue->resolve window (no unaccounted gaps)
TRACE_MIN_COVERAGE = 0.95


def _bundle(path):
    """A NAS-shaped MLP surrogate bundle (weights need not be trained:
    throughput is architecture- and batch-shaped, not accuracy-shaped)."""
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 5), [128, 128], 1)
    params = net.init(jax.random.PRNGKey(0))
    return save_model(path, net, params)


def _measure(fn, reps=5, warmup=2):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def serving_throughput(fast=False, *, n_callers=None, rows_per_call=8):
    """benchmarks.run entry: CSV rows only (drops the latency table)."""
    rows, _ = serving_throughput_full(fast=fast, n_callers=n_callers,
                                      rows_per_call=rows_per_call)
    return rows


def serving_throughput_full(fast=False, *, n_callers=None, rows_per_call=8):
    """CSV rows comparing per-call vs coalesced serving on the host mesh,
    plus the per-bucket measured-vs-roofline latency table."""
    import pathlib
    import tempfile

    import jax.numpy as jnp

    from repro.apps import binomial
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = n_callers or (16 if fast else 64)
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_bench_"))
    mp = _bundle(tmp / "surrogate")

    ndev = len(jax.devices())
    mesh_shape = (ndev, 1)
    mesh = make_local_mesh(mesh_shape)
    opts = binomial.make_inputs(total, seed=7)
    chunks = [opts[i:i + rows_per_call] for i in range(0, total,
                                                      rows_per_call)]

    from repro.tune import AdaptiveFlushController
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    ad_policy = FlushPolicy(max_batch_rows=total, max_pending_rows=4 * total,
                            max_delay_s=0.05)
    ad_queue = ServeQueue(ad_policy,
                          controller=AdaptiveFlushController(ad_policy))
    r_sync = binomial.make_region(rows_per_call, mode="infer", model=mp)
    r_async = binomial.make_region(rows_per_call, mode="infer_async",
                                   model=mp, serving=queue)
    r_adapt = binomial.make_region(rows_per_call, mode="infer_async",
                                   model=mp, serving=ad_queue)

    with use_mesh(mesh):
        def per_call():
            outs = [r_sync(opts=c)["out"] for c in chunks]
            jax.block_until_ready(outs)
            return outs

        def coalesced():
            handles = [r_async(opts=c) for c in chunks]
            queue.flush(mp, reason="sweep_step")
            outs = [h.result()["out"] for h in handles]
            jax.block_until_ready(outs)
            return outs

        def adaptive():
            # no explicit flush: the controller's deadline/batch trigger
            # decides when the mega-batches go out
            handles = [r_adapt(opts=c) for c in chunks]
            outs = [h.result(30)["out"] for h in handles]
            jax.block_until_ready(outs)
            return outs

        t_call = _measure(per_call)
        t_coal = _measure(coalesced)
        with ad_queue:  # dispatcher thread enforces the adaptive deadline
            t_adapt = _measure(adaptive)
        # exactness: coalesced rows must match per-call rows bit-for-bit
        same = all(
            bool((np.asarray(a) == np.asarray(b)).all())
            for a, b in zip(per_call(), coalesced()))

    st = queue.stats(mp).snapshot()
    ast = ad_queue.stats(mp).snapshot()
    pool = ad_queue._batcher.scratch.stats()
    rows_s_call = total / t_call
    rows_s_coal = total / t_coal
    rows_s_adapt = total / t_adapt
    speedup = rows_s_coal / rows_s_call
    model_err = latency_model_rows(ad_queue, mp)
    worst_err = max((abs(r["err_pct"]) for r in model_err), default=0.0)
    derived = (f"devices={ndev};callers={n_callers};"
               f"rows_per_call={rows_per_call};"
               f"percall_rows_s={rows_s_call:.0f};"
               f"coalesced_rows_s={rows_s_coal:.0f};"
               f"speedup_x={speedup:.2f};bitwise_equal={same};"
               f"occupancy={st['batch_occupancy']:.2f};"
               f"p50_ms={st['latency_p50_ms']:.2f};"
               f"p99_ms={st['latency_p99_ms']:.2f};"
               f"adaptive_rows_s={rows_s_adapt:.0f};"
               f"adaptive_p50_ms={ast['latency_p50_ms']:.2f};"
               f"adaptive_p99_ms={ast['latency_p99_ms']:.2f};"
               f"scratch_hit_rate={pool['hits'] / max(1, pool['hits'] + pool['misses']):.2f};"
               f"roofline_worst_err_pct={worst_err:.0f}")
    return ([("serve_throughput/binomial", t_coal / n_callers * 1e6,
              derived)], model_err)


def latency_model_rows(ad_queue, mp):
    """Per-bucket measured-vs-roofline batch latency error.

    The adaptive controller's deadline model starts from the roofline
    prediction and converges on measured ``ServeStats`` latencies; this
    table makes the model's drift visible (a large error means the
    open-loop prior was badly miscalibrated for this backend — exactly
    what the measured loop corrects, and what EXPERIMENTS.md should
    show).
    """
    ctrl = ad_queue.controller
    st = ad_queue.stats(mp)
    widths = ctrl._widths_cached(mp) if ctrl is not None else None
    rows = []
    if not widths:
        return rows
    for bucket, (ewma_s, n) in sorted(st.batch_latencies().items()):
        pred_s = ctrl.predict_latency_s(widths, bucket)
        err = (pred_s - ewma_s) / ewma_s * 100.0 if ewma_s > 0 else 0.0
        rows.append({"bucket": bucket, "batches": n,
                     "measured_ms": ewma_s * 1e3,
                     "roofline_ms": pred_s * 1e3, "err_pct": err})
    return rows


def export_trace(path) -> None:
    """Write the Chrome trace + metrics artifacts and gate span coverage.

    The trace must account for each sampled request's whole
    enqueue->resolve window: queue.submit + serve.request tile it by
    construction, so any request whose union coverage drops below
    :data:`TRACE_MIN_COVERAGE` means an instrumentation gap crept into
    the serve path.
    """
    from repro.obs import TRACER, default_registry, request_coverage
    path = pathlib.Path(path)
    events = TRACER.export_chrome_trace(path)
    # sampled = requests whose span set is complete in the ring (the ring
    # evicts oldest-first, so early-warmup requests may be partial)
    full = {t for t in
            ( (e.get("args") or {}).get("trace") for e in events
              if e["name"] == "queue.submit" )
            if t is not None}
    cov = {t: c for t, c in request_coverage(events).items()
           if t in full and c["spans"] >= 2}
    if not cov:
        raise SystemExit("--trace: no fully-sampled request in the trace "
                         "(ring too small for this workload?)")
    worst = min(cov.values(), key=lambda c: c["coverage"])
    metrics = default_registry()
    path.with_suffix(".metrics.json").write_text(
        json.dumps(metrics.collect(), indent=1))
    path.with_suffix(".prom").write_text(metrics.dump())
    print(f"[serve trace] {len(events)} events -> {path}; "
          f"{len(cov)} sampled requests, worst coverage "
          f"{worst['coverage']:.3f} over {worst['window_us']:.0f}us",
          flush=True)
    if worst["coverage"] < TRACE_MIN_COVERAGE:
        raise SystemExit(
            f"--trace FAILED: worst request coverage {worst['coverage']:.3f}"
            f" < {TRACE_MIN_COVERAGE} (unaccounted gap in the serve path)")


def overhead_check(fast=False, pairs=50):
    """Gate instrumentation cost: tracing on vs off, interleaved pairs.

    Runs the coalesced serve path (the instrumented hot path) with the
    tracer toggled every other run; the gate compares the *minimum* off
    time against the minimum on time.  Scheduler noise only ever adds
    time, so each minimum estimates that path's true cost; the tight
    interleave guarantees both sets sample the same machine conditions
    (a sequential off-block/on-block comparison is dominated by drift —
    measured, the drift between two such blocks exceeds the effect being
    gated); and the within-pair order alternates each pair because the
    second run of a pair measures systematically slower than the first
    (also larger than the effect under test).  GC is paused during
    timing, as ``timeit`` does.  Fails below :data:`OVERHEAD_MIN_RATIO`.
    """
    import gc
    import tempfile

    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.obs import TRACER, disable_tracing, enable_tracing
    from repro.serve import FlushPolicy, ServeQueue

    n_callers = 16 if fast else 32
    rows_per_call = 8
    total = n_callers * rows_per_call
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="serve_obs_bench_"))
    mp = _bundle(tmp / "surrogate")
    mesh = make_local_mesh((len(jax.devices()), 1))
    queue = ServeQueue(FlushPolicy(max_batch_rows=total,
                                   max_pending_rows=4 * total))
    rng = np.random.default_rng(3)
    chunks = [rng.standard_normal((rows_per_call, 5)).astype(np.float32)
              for _ in range(n_callers)]

    def run_once():
        futs = [queue.submit(mp, c) for c in chunks]
        queue.flush(mp, reason="bench")
        for f in futs:
            f.result(30)

    was_enabled = TRACER.enabled
    offs, ons = [], []
    try:
        with use_mesh(mesh):
            disable_tracing()
            _measure(run_once, reps=1, warmup=3)  # compile outside timing
            gc.disable()
            try:
                for i in range(pairs):
                    halves = [(False, offs), (True, ons)]
                    if i % 2:
                        halves.reverse()
                    for on, times in halves:
                        enable_tracing() if on else disable_tracing()
                        t0 = time.perf_counter()
                        run_once()
                        times.append(time.perf_counter() - t0)
                    if i % 10 == 9:  # bound ring/heap growth, untimed
                        TRACER.clear()
                        gc.collect()
            finally:
                gc.enable()
            TRACER.clear()
    finally:
        TRACER.enabled = was_enabled
    ratio = min(offs) / min(ons)
    print(f"[serve obs overhead] traced serving retains "
          f"{ratio * 100:.1f}% of untraced rows/s over {pairs} "
          f"interleaved pairs (off {min(offs) * 1e3:.3f}ms / on "
          f"{min(ons) * 1e3:.3f}ms)", flush=True)
    if ratio < OVERHEAD_MIN_RATIO:
        raise SystemExit(
            f"obs overhead gate FAILED: traced/untraced rows/s "
            f"ratio {ratio:.3f} < {OVERHEAD_MIN_RATIO} (instrumentation "
            f"costs more than {100 * (1 - OVERHEAD_MIN_RATIO):.0f}%)")
    return ratio


def _markdown(rows, model_err):
    kv = dict(item.split("=", 1) for item in rows[0][2].split(";"))
    out = ["### Serving throughput (8-device host mesh)", "",
           "| path | rows/s |", "|---|---:|",
           f"| per-call `MLRegion._infer` | {kv['percall_rows_s']} |",
           f"| coalesced `ServeQueue` | {kv['coalesced_rows_s']} |",
           f"| adaptive controller | {kv['adaptive_rows_s']} |",
           "", "### Measured vs roofline batch latency (adaptive queue)",
           "",
           "| bucket | batches | measured ms | roofline ms | error |",
           "|---:|---:|---:|---:|---:|"]
    for r in model_err:
        out.append(f"| {r['bucket']} | {r['batches']} | "
                   f"{r['measured_ms']:.3f} | {r['roofline_ms']:.3f} | "
                   f"{r['err_pct']:+.0f}% |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help=f"fail unless coalesced >= {CHECK_SPEEDUP}x per-call"
                         " rows/s and outputs are bitwise equal")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--markdown", action="store_true",
                    help="print markdown tables incl. the per-bucket "
                         "measured-vs-roofline latency error "
                         "(for EXPERIMENTS.md)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run with tracing on, write the Chrome trace + "
                         "metrics snapshots to PATH(.metrics.json/.prom) "
                         "and fail unless every sampled request's spans "
                         f"cover >= {TRACE_MIN_COVERAGE:.0%} of its "
                         "enqueue->resolve latency")
    ap.add_argument("--overhead-check", action="store_true",
                    help="gate instrumentation cost: tracing on must "
                         f"retain >= {OVERHEAD_MIN_RATIO:.0%} of untraced "
                         "rows/s (interleaved-pair median ratio)")
    args = ap.parse_args()
    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()
    rows, model_err = serving_throughput_full(fast=args.fast)
    if args.trace:
        export_trace(args.trace)
    if args.markdown:
        print(_markdown(rows, model_err))
    else:
        print("name,us_per_call,derived")
        for n, us, derived in rows:
            print(f"{n},{us:.2f},{derived}", flush=True)
    if args.check:
        kv = dict(item.split("=") for item in rows[0][2].split(";"))
        speedup = float(kv["speedup_x"])
        same = kv["bitwise_equal"] == "True"
        if speedup < CHECK_SPEEDUP or not same:
            raise SystemExit(
                f"serving smoke FAILED: speedup_x={speedup:.2f} "
                f"(need >= {CHECK_SPEEDUP}) bitwise_equal={same}")
        print(f"[serve smoke] OK: {speedup:.2f}x coalesced over per-call")
    if args.overhead_check:
        overhead_check(fast=args.fast)


if __name__ == "__main__":
    main()
