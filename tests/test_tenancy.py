"""Multi-tenant control plane: admission, fair share, QoS, residency.

The fairness and refill guarantees are *properties* (hypothesis-shim
driven): DRR must never starve a positive-weight tenant even when
capacity admits one key per round, and a token bucket's level between
takes must never decrease — whatever the clock does.  The residency
tests drive the eviction->reload path under real thread races: an
evicted bundle's next request must trigger exactly one reload, and no
reader may ever observe a torn (half-loaded) engine.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.engine import InferenceEngine
from repro.nn import MLP
from repro.nn.serialize import save_model
from repro.serve import (RESIDENCY, FlushPolicy, ResidencyManager,
                         ServeQueue, TenantBoard, TenantSpec,
                         TenantThrottled)
from repro.serve.tenancy import DEFAULT_TENANT, DeficitRoundRobin, TokenBucket
from repro.tune import AdaptiveFlushController


@pytest.fixture(autouse=True)
def _clean_engine_state():
    InferenceEngine.invalidate()
    RESIDENCY.set_budget(None)
    yield
    InferenceEngine.invalidate()
    RESIDENCY.set_budget(None)
    RESIDENCY.reset_stats()


def _bundle(tmp, name="m"):
    net = MLP((1, 2), [8], 1)
    return save_model(tmp / name, net, net.init(jax.random.PRNGKey(0)))


def _rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, 2)).astype(np.float32)


# ------------------------------------------------------- token bucket ------
class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@settings(max_examples=30)
@given(seed=st.integers(0, 10**6), rate=st.floats(0.5, 200.0),
       burst=st.floats(1.0, 100.0))
def test_token_bucket_refill_monotone(seed, rate, burst):
    """Between takes the level never decreases — even when the clock
    jitters backwards — and never exceeds the burst."""
    rng = np.random.default_rng(seed)
    clock = _FakeClock()
    b = TokenBucket(rate, burst, clock)
    b.take(burst)  # drain to 0 so refill has room to move
    prev = b.level()
    for _ in range(50):
        clock.t += float(rng.uniform(-0.05, 0.2))  # may step backwards
        lvl = b.level()
        assert lvl >= prev - 1e-9, "refill drained the bucket"
        assert lvl <= burst + 1e-9
        prev = lvl


def test_token_bucket_oversized_debt():
    """A request larger than the burst admits against a FULL bucket
    (driving the level negative) — otherwise it could never serve."""
    clock = _FakeClock()
    b = TokenBucket(10.0, 16.0, clock)
    assert b.take(64)          # full bucket: oversized admitted as debt
    assert b.level() < 0
    assert not b.take(1)       # in debt: nothing else admits
    clock.t += 1e9
    assert b.take(16)          # fully refilled (and capped at burst)


def test_token_bucket_throttles_then_refills():
    clock = _FakeClock()
    board = TenantBoard([TenantSpec("t", rate_rows_per_s=10.0,
                                    burst_rows=8)], clock=clock)
    board.admit("t", 8, block=False)
    with pytest.raises(TenantThrottled):
        board.admit("t", 8, block=False)
    clock.t += 0.8  # 8 rows of refill at 10 rows/s
    board.admit("t", 8, block=False)


# ---------------------------------------------------------- fair share -----
@settings(max_examples=25)
@given(nw=st.integers(2, 4), seed=st.integers(0, 10**6))
def test_drr_never_starves_positive_weight(nw, seed):
    """Worst case for fairness: every tenant permanently backlogged,
    capacity admits ONE key per round.  Every positive-weight tenant —
    however light — must keep getting served, at roughly its weight
    share."""
    rng = np.random.default_rng(seed)
    weights = {f"t{i}": float(rng.uniform(0.25, 4.0)) for i in range(nw)}
    rows = 64
    drr = DeficitRoundRobin(quantum_rows=float(rows))
    items = [(f"k{i}", t, rows) for i, t in enumerate(sorted(weights))]
    key_tenant = {k: t for k, t, _ in items}
    served = {t: 0 for t in weights}
    rounds = 400
    for _ in range(rounds):
        first = drr.order(items, weights)[0]
        drr.charge(key_tenant[first], rows)
        served[key_tenant[first]] += 1
    total_w = sum(weights.values())
    for t, w in weights.items():
        floor = max(1, int(rounds * w / total_w / 4))
        assert served[t] >= floor, (
            f"tenant {t} (weight {w:.2f}) served {served[t]}/{rounds} "
            f"rounds, below the {floor} fair-share floor: starved")


def test_drr_order_prefers_uncharged_tenant():
    drr = DeficitRoundRobin(quantum_rows=64.0)
    items = [("kh", "heavy", 48), ("kl", "light", 8)]
    weights = {"heavy": 1.0, "light": 1.0}
    drr.order(items, weights)
    drr.charge("heavy", 48)
    drr.charge("light", 8)
    assert drr.order(items, weights) == ["kl", "kh"]


def test_queue_flush_order_uses_drr_under_overload(tmp_path):
    board = TenantBoard([TenantSpec("heavy", weight=1.0),
                         TenantSpec("light", weight=1.0)])
    # max_batch_rows=48: each key stays below the inline-flush trigger,
    # but the 52 pending rows across >= 2 keys engage the DRR order
    queue = ServeQueue(FlushPolicy(max_batch_rows=48,
                                   max_pending_rows=1 << 16),
                       tenancy=board)
    kh, kl = _bundle(tmp_path, "h"), _bundle(tmp_path, "l")
    futs = [queue.submit(kh, _rows(22), tenant="heavy"),
            queue.submit(kh, _rows(22), tenant="heavy"),
            queue.submit(kl, _rows(8), tenant="light")]
    queue.flush()
    for f in futs:
        f.result(30)
    # round 1 charged heavy 44 vs light 8: round 2 must put light first
    queue.submit(kh, _rows(22), tenant="heavy")
    queue.submit(kh, _rows(22), tenant="heavy")
    f = queue.submit(kl, _rows(8), tenant="light")
    assert queue._flush_order() == [str(kl), str(kh)]
    queue.flush()
    f.result(30)
    snap = queue.snapshot()
    assert snap["tenants"]["light"]["served_rows"] == 16
    assert snap["tenants"]["heavy"]["served_rows"] == 88
    assert snap["tenants"]["light"]["dropped_rows"] == 0
    assert "residency" in snap
    queue.close()


# ----------------------------------------------------- board accounting ----
def test_board_backpressure_and_offenders():
    clock = _FakeClock()
    board = TenantBoard([TenantSpec("t", max_pending_rows=16)], clock=clock)
    board.on_enqueue("t", "k", 16)
    assert not board.has_room("t", 1)
    board.on_dispatch("t", 16)
    assert board.has_room("t", 16)
    # a tenant with nothing pending always admits (oversized batches
    # flush alone — same no-deadlock rule as the queue's global gate)
    assert board.has_room("t", 64)

    assert board.offenders() == []
    board.on_dropped("t", 1, 8)
    assert board.offenders() == ["t"]
    clock.t += TenantBoard.OFFENDER_WINDOW_S + 1
    assert board.offenders() == []  # old drops age out


def test_queue_tenant_offenders_surface(tmp_path):
    board = TenantBoard()
    queue = ServeQueue(FlushPolicy(max_batch_rows=64), tenancy=board)
    board.on_dropped("noisy", 1, 8)
    assert queue.tenant_offenders() == ["noisy"]
    queue.close()


def test_unknown_tenant_inherits_default_spec():
    board = TenantBoard(default_spec=TenantSpec(max_pending_rows=32))
    assert board.spec_for("newcomer").max_pending_rows == 32
    assert board.spec_for("newcomer").tenant == "newcomer"
    board.on_enqueue("newcomer", "k", 8)
    assert board.tenant_for_key("k") == "newcomer"
    assert board.tenant_for_key("unbound") == DEFAULT_TENANT


# ------------------------------------------------------------- QoS tiers ---
def test_controller_qos_tier_bounds():
    """A latency tenant's target CAPS the deadline; a throughput
    tenant's target RAISES the ceiling past the static policy."""
    board = TenantBoard([
        TenantSpec("rt", tier="latency", deadline_target_s=5e-4),
        TenantSpec("batch", tier="throughput", deadline_target_s=0.5),
    ])
    board.on_enqueue("rt", "k_rt", 8)
    board.on_enqueue("batch", "k_batch", 8)
    policy = FlushPolicy(max_delay_s=0.02)
    # huge widths: the unbounded service cap lands well above both the
    # static deadline and the latency target, so the tier bound is what
    # decides in each direction
    ctrl = AdaptiveFlushController(
        policy, widths_for=lambda key: [8, 8192, 8192, 8192, 4],
        service_factor=1e6, tenancy=board)
    d_rt = ctrl.delay_for("k_rt", None)
    d_batch = ctrl.delay_for("k_batch", None)
    assert d_rt <= 5e-4 + 1e-9
    assert d_batch > policy.max_delay_s  # raised past the static cap
    assert d_batch <= 0.5 + 1e-9
    assert ctrl.last_decision["k_rt"]["qos_tier"] == "latency"
    assert ctrl.last_decision["k_batch"]["qos_tier"] == "throughput"
    # unbound key: tier-free decision clamps to the static policy
    assert ctrl.delay_for("k_free", None) <= policy.max_delay_s + 1e-9
    assert ctrl.last_decision["k_free"]["qos_tier"] is None


def test_queue_wires_tenancy_into_controller(tmp_path):
    board = TenantBoard()
    ctrl = AdaptiveFlushController(widths_for=lambda key: [2, 8, 1])
    queue = ServeQueue(FlushPolicy(max_batch_rows=64), controller=ctrl,
                       tenancy=board)
    assert ctrl.tenancy is board
    assert queue._batcher.tenancy is board
    queue.close()


# ------------------------------------------------------------- residency ---
def test_residency_budget_evicts_lru():
    r = ResidencyManager(budget_bytes=100)
    assert r.note_load("a", 60) == []
    assert r.note_load("b", 60) == ["a"]          # LRU out, never self
    assert r.resident_bytes() == 60
    assert r.peak_bytes <= 100                     # never over budget
    assert r.note_load("huge", 500) == ["b"]       # oversized still loads
    assert r.resident() == {"huge": 500}
    r.drop("huge")
    r.drop("huge")                                 # idempotent
    assert r.resident_bytes() == 0
    assert r.snapshot()["evictions"] == 2


def test_residency_touch_refreshes_lru():
    r = ResidencyManager(budget_bytes=120)
    r.note_load("a", 50)
    r.note_load("b", 50)
    r.touch("a")                                   # a is now MRU
    assert r.note_load("c", 50) == ["b"]


def test_evicted_bundle_reloads_exactly_once(tmp_path, monkeypatch):
    """3 threads race the first request after an eviction: the engine
    cache lock must admit exactly ONE reload, and every thread must see
    the fully-loaded engine (outputs identical to pre-eviction)."""
    mp = _bundle(tmp_path)
    x = jnp.asarray(_rows(16))
    y_ref = np.asarray(InferenceEngine.get(mp).apply_batched(x))

    loads = []
    lock = threading.Lock()
    orig = InferenceEngine._load

    def counted(self):
        with lock:
            loads.append(self.path)
        return orig(self)

    monkeypatch.setattr(InferenceEngine, "_load", counted)
    InferenceEngine.invalidate(mp)  # the eviction (same path the
    loads.clear()                   # residency manager's victims take)

    barrier = threading.Barrier(3)
    outs, errs = [], []

    def request():
        try:
            barrier.wait(10)
            outs.append(np.asarray(InferenceEngine.get(mp)
                                   .apply_batched(x)))
        except Exception as exc:  # pragma: no cover - diagnostic
            errs.append(exc)

    threads = [threading.Thread(target=request) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errs
    assert len(outs) == 3
    assert loads.count(str(mp)) == 1, (
        f"{loads.count(str(mp))} reloads for one eviction")
    for y in outs:
        np.testing.assert_array_equal(y, y_ref)


def test_no_torn_reads_under_concurrent_submit_and_evict(tmp_path):
    """3 request threads hammer get()+apply while the main thread keeps
    evicting: every single response must be bit-identical to the
    reference — a torn (half-loaded) engine read would differ or raise."""
    mp = _bundle(tmp_path)
    x = jnp.asarray(_rows(16))
    y_ref = np.asarray(InferenceEngine.get(mp).apply_batched(x))
    stop = threading.Event()
    errs = []

    def hammer():
        try:
            while not stop.is_set():
                y = np.asarray(InferenceEngine.get(mp).apply_batched(x))
                if not np.array_equal(y, y_ref):
                    errs.append("torn read: output mismatch")
                    return
        except Exception as exc:
            errs.append(repr(exc))

    threads = [threading.Thread(target=hammer) for _ in range(3)]
    for t in threads:
        t.start()
    for _ in range(20):
        InferenceEngine.invalidate(mp)
    stop.set()
    for t in threads:
        t.join(30)
    assert not errs, errs[:3]


def test_residency_prefetch_warms_bundle(tmp_path):
    mp = _bundle(tmp_path)
    t = RESIDENCY.prefetch(mp)
    assert t is not None
    t.join(30)
    assert str(mp) in RESIDENCY.resident()
    assert RESIDENCY.prefetch(mp) is None  # already resident: no-op


# ----------------------------------------------------- end-to-end submit ---
def test_tenant_submit_roundtrip_and_latency_accounting(tmp_path):
    board = TenantBoard([TenantSpec("a", tier="latency", weight=2.0),
                         TenantSpec("b")])
    queue = ServeQueue(FlushPolicy(max_batch_rows=256), tenancy=board)
    mp = _bundle(tmp_path)
    fa = queue.submit(mp, _rows(8, seed=1), tenant="a")
    fb = queue.submit(mp, _rows(8, seed=2), tenant="b")
    queue.flush()
    fa.result(30), fb.result(30)
    snap = board.snapshot()
    # the second submit rebinds the shared key, so request->tenant
    # attribution (not key binding) must drive the served accounting
    assert snap["a"]["served_rows"] == 8
    assert snap["b"]["served_rows"] == 8
    assert snap["a"]["pending_rows"] == 0
    assert snap["a"]["latency_p99_ms"] > 0.0
    assert abs(sum(s["occupancy"] for s in snap.values()) - 1.0) < 1e-9
    queue.close()
