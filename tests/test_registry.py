"""Cross-kernel registry dispatch suite.

One parameterized parity contract for every registered kernel (replacing
per-kernel ad-hoc dispatch tests): the kernel path in interpret mode
must match the *jitted* ref oracle — bit-for-bit where the spec declares
``tol=None`` (fused_mlp, stencil_gather), to the spec tolerance where
the block structure legitimately changes rounding (flash attention's
online softmax, rwkv6's in-kernel recurrence) — and the off-TPU default
dispatch must route to the oracle itself.  Plus the dispatch plumbing:
override precedence, tuned-cache consultation, VMEM-overflow fallback,
and the device-budget query.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import registry
from repro.tune.cache import TuneCache

# small interpret-friendly problems, one per registered kernel
PROBLEMS = {
    "fused_mlp": {"widths": (4, 16, 2), "acts": ("relu", "identity"),
                  "batch": 32, "dtype": "float32"},
    "flash_attention": {"b": 1, "sq": 32, "skv": 32, "h": 2, "kv": 1,
                        "hd": 16, "causal": True, "q_offset": 0,
                        "dtype": "float32"},
    "flash_attention_int8": {"b": 1, "sq": 16, "skv": 64, "h": 2, "kv": 1,
                             "hd": 16, "causal": True, "q_offset": 48,
                             "dtype": "float32"},
    "fused_mlp_int8": {"widths": (4, 16, 2), "acts": ("relu", "identity"),
                       "batch": 32, "dtype": "float32"},
    "stencil_gather": {"h": 24, "w": 24, "out_h": 20, "out_w": 20,
                       "offsets": ((0, 1), (1, 0), (0, 0), (1, 2)),
                       "origin": (1, 1), "dtype": "float32"},
    "rwkv6_chunk": {"b": 1, "t": 16, "h": 2, "hd": 8, "dtype": "float32"},
}

KERNELS = sorted(PROBLEMS)


def _assert_matches(spec, out, ref):
    a_leaves, b_leaves = jax.tree.leaves(out), jax.tree.leaves(ref)
    assert len(a_leaves) == len(b_leaves)
    for a, b in zip(a_leaves, b_leaves):
        if spec.tol is None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            rtol, atol = spec.tol
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=rtol, atol=atol)


def test_all_builtin_kernels_registered():
    assert [s.name for s in registry.all_specs()] == KERNELS


@pytest.mark.parametrize("name", KERNELS)
def test_force_kernel_interpret_matches_jitted_oracle(name):
    """force_kernel off-TPU runs the Pallas kernel in interpret mode;
    its output must match the jitted ref oracle per the spec's declared
    comparison (bit-for-bit unless a tolerance is declared)."""
    spec = registry.get_spec(name)
    problem = PROBLEMS[name]
    arrays = spec.make_call(problem, np.random.default_rng(0))
    out = jax.jit(lambda *a: registry.dispatch(
        spec, problem, a, force_kernel=True))(*arrays)
    ref = jax.jit(lambda *a: spec.ref_call(problem, a))(*arrays)
    _assert_matches(spec, out, ref)


@pytest.mark.parametrize("name", KERNELS)
def test_off_tpu_dispatch_falls_back_to_oracle(name):
    """Without force_kernel on a non-TPU backend the dispatch must take
    the oracle path — identical output by construction."""
    assert jax.default_backend() != "tpu"  # test env invariant
    spec = registry.get_spec(name)
    problem = PROBLEMS[name]
    arrays = spec.make_call(problem, np.random.default_rng(1))
    out = registry.dispatch(spec, problem, arrays)
    ref = spec.ref_call(problem, arrays)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(out)[0]),
        np.asarray(jax.tree.leaves(ref)[0]))


@pytest.mark.parametrize("name", [n for n in KERNELS
                                  if registry.get_spec(n).params])
def test_candidates_defaults_first_and_fit(name):
    spec = registry.get_spec(name)
    cands = spec.candidates(PROBLEMS[name])
    assert cands[0] == spec.defaults()
    if spec.fits is not None:
        assert all(spec.fits(PROBLEMS[name], c) for c in cands)


def test_dispatch_override_beats_tuned_and_default(monkeypatch):
    spec = registry.get_spec("fused_mlp")
    problem = PROBLEMS["fused_mlp"]
    arrays = spec.make_call(problem, np.random.default_rng(2))
    seen = {}
    orig = spec.run_call

    def spy(problem, arrays, params, *, interpret):
        seen.update(params)
        return orig(problem, arrays, params, interpret=interpret)

    monkeypatch.setattr(spec, "run_call", spy)
    registry.dispatch(spec, problem, arrays, force_kernel=True,
                      overrides={"batch_tile": 16})
    assert seen["batch_tile"] == 16


def test_dispatch_consults_namespaced_tune_cache(tmp_path, monkeypatch):
    """A validated winner stored under the kernel's namespaced cache is
    what the dispatch applies — across kernels, not just fused_mlp."""
    import repro.tune.cache as cache_mod
    spec = registry.get_spec("flash_attention")
    problem = PROBLEMS["flash_attention"]
    c = TuneCache("flash_attention", tmp_path / "flash_attention.json")
    key = spec.cache_key(problem, jax.default_backend())
    c.put(key, {"params": {"block_q": 16, "block_kv": 16}, "exact": True})
    monkeypatch.setattr(cache_mod, "_default", {"flash_attention": c})
    seen = {}
    orig = spec.run_call

    def spy(problem, arrays, params, *, interpret):
        seen.update(params)
        return orig(problem, arrays, params, interpret=interpret)

    monkeypatch.setattr(spec, "run_call", spy)
    arrays = spec.make_call(problem, np.random.default_rng(3))
    registry.dispatch(spec, problem, arrays, force_kernel=True)
    assert seen == {"block_q": 16, "block_kv": 16}
    # unvalidated entries are refused: defaults apply
    c.put(key, {"params": {"block_q": 32, "block_kv": 32}, "exact": False})
    seen.clear()
    registry.dispatch(spec, problem, arrays, force_kernel=True)
    assert seen == spec.defaults()


def test_resolve_params_rejects_vmem_overflow():
    """A tuned/override config that would overflow this device's VMEM
    budget falls back to the defaults (a cache written on a roomier
    machine must not push this one over)."""
    spec = registry.get_spec("fused_mlp")
    problem = PROBLEMS["fused_mlp"]
    params = registry.resolve_params(spec, problem,
                                     overrides={"batch_tile": 1 << 20})
    assert params == spec.defaults()


def test_fused_mlp_unsupported_net_takes_oracle_even_forced(monkeypatch):
    """A net too big for VMEM must take the oracle path even under
    force_kernel — `supports` gates the kernel path entirely."""
    spec = registry.get_spec("fused_mlp")
    problem = {"widths": (4096, 4096, 4096), "acts": ("relu", "identity"),
               "batch": 8, "dtype": "float32"}
    called = {}
    orig = spec.ref_call

    def spy(problem, arrays):
        called["ref"] = True
        return orig(problem, arrays)

    monkeypatch.setattr(spec, "ref_call", spy)
    arrays = spec.make_call(problem, np.random.default_rng(4))
    registry.dispatch(spec, problem, arrays, force_kernel=True)
    assert called.get("ref")


# ------------------------------------------------------- op-level shims ----
def test_flash_attention_op_block_overrides():
    from repro.kernels.flash_attention.ops import flash_attention_op
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 32, 1, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 32, 1, 16)).astype(np.float32))
    a = flash_attention_op(q, k, v, force_kernel=True, block_q=16,
                           block_kv=16)
    r = flash_attention_op(q, k, v)  # oracle path off-TPU
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_stencil_gather_op_block_overrides():
    from repro.kernels.stencil_gather.ops import stencil_gather_op
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(24, 24)).astype(np.float32))
    offs = ((0, 1), (1, 0), (0, 0))
    a = stencil_gather_op(x, offsets=offs, out_h=20, out_w=20,
                          origin=(1, 1), force_kernel=True, block_h=16,
                          block_w=128)
    r = stencil_gather_op(x, offsets=offs, out_h=20, out_w=20,
                          origin=(1, 1))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_rwkv6_chunk_op_dispatch_parity():
    from repro.kernels.rwkv6_chunk.ops import rwkv6_chunk_op
    rng = np.random.default_rng(7)
    B, T, H, hd = 1, 16, 2, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.7, 0.99, (B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32))
    s0 = jnp.zeros((B, H, hd, hd), np.float32)
    ok, sk = rwkv6_chunk_op(r, k, v, w, u, s0, force_kernel=True)
    orf, srf = rwkv6_chunk_op(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(ok), np.asarray(orf), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(srf), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------- VMEM budget ----
def test_device_vmem_budget_off_tpu_keeps_old_constant():
    assert jax.default_backend() != "tpu"
    assert registry.device_vmem_budget() == 12 * 2 ** 20


@pytest.mark.parametrize("kind,budget_mib", [
    ("TPU v4", 12), ("TPU v5 lite", 12), ("TPU v5p", 12),
    ("TPU v3", 12), ("TPU v99-future", 12),
])
def test_vmem_budget_table(kind, budget_mib):
    # every known 16 MiB part yields physical minus the 4 MiB compiler
    # reserve; unknown kinds get the conservative default
    assert registry._vmem_budget_for_kind(kind) == budget_mib * 2 ** 20


def test_fits_vmem_default_budget_queries_device():
    from repro.kernels.fused_mlp.fused_mlp import fits_vmem
    widths = (64, 64)
    assert fits_vmem(widths, 8) == \
        fits_vmem(widths, 8, budget=registry.device_vmem_budget())


def test_ladder_candidates_defaults_first_and_clipped():
    params = (registry.TunableParam("a", 8, (4, 8, 16, 32)),
              registry.TunableParam("b", 128, (64, 128, 256)))
    cands = registry.ladder_candidates(params, clip={"a": 16, "b": 128})
    assert cands[0] == {"a": 8, "b": 128}
    assert all(c["a"] <= 16 for c in cands)
    assert all(c["b"] <= 128 for c in cands)
    # a fits filter prunes but never drops the defaults-first ordering
    fit = registry.ladder_candidates(params, fits=lambda c: c["a"] != 4)
    assert fit[0] == {"a": 8, "b": 128}
    assert all(c["a"] != 4 for c in fit)
