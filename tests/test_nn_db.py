"""NN module system + serialization + database + engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.database import SurrogateDB
from repro.core.engine import InferenceEngine
from repro.nn import CNN, MLP, from_spec
from repro.nn.serialize import load_model, save_model


def test_mlp_shapes_and_grads():
    net = MLP((1, 8), [32, 16], 2, act="gelu")
    p = net.init(jax.random.PRNGKey(0))
    x = jnp.ones((5, 8))
    y = net.apply(p, x)
    assert y.shape == (5, 2)
    g = jax.grad(lambda p: net.apply(p, x).sum())(p)
    assert max(float(jnp.abs(l).max()) for l in jax.tree.leaves(g)) > 0


def test_cnn_shapes():
    net = CNN((1, 24, 24, 1), [(8, 5, 2)], [32], 2, pool=2)
    p = net.init(jax.random.PRNGKey(0))
    y = net.apply(p, jnp.ones((3, 24, 24, 1)))
    assert y.shape == (3, 2)


def test_serialize_roundtrip(tmp_path):
    net = MLP((1, 4), [16], 1)
    p = net.init(jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(7, 4)).astype(np.float32))
    y0 = net.apply(p, x)
    save_model(tmp_path / "m", net, p, extra={"note": "hi"})
    net2, p2, spec = load_model(tmp_path / "m")
    np.testing.assert_array_equal(np.asarray(net2.apply(p2, x)),
                                  np.asarray(y0))
    assert spec["extra"]["note"] == "hi"


def test_from_spec_rebuild():
    net = CNN((1, 8, 8, 2), [(4, 3, 1)], [], 3)
    net2 = from_spec(net.spec())
    assert net2.out_shape() == net.out_shape()


def test_engine_caches_and_normalizes(tmp_path):
    from repro.nas.train_surrogate import fit
    rng = np.random.default_rng(0)
    X = (rng.normal(size=(1024, 3)) * 10 + 5).astype(np.float32)
    Y = (X.sum(1, keepdims=True) * 3).astype(np.float32)
    net = MLP((1, 3), [32], 1)
    p, rmse, stats = fit(net, X, Y, epochs=60, lr=1e-2)
    path = save_model(tmp_path / "m", net, p, extra=stats)
    e1 = InferenceEngine.get(path)
    e2 = InferenceEngine.get(path)
    assert e1 is e2  # loaded once (paper §IV-B)
    pred = np.asarray(e1(jnp.asarray(X[:64])))
    denorm_rmse = float(np.sqrt(np.mean((pred - Y[:64]) ** 2)))
    # the deployed engine (with norm stats from the bundle) must reproduce
    # training-quality predictions — deploy error tracks validation error
    assert denorm_rmse < max(2.5 * rmse, 0.5 * float(np.abs(Y).mean()))


def test_engine_reload_after_retrain(tmp_path):
    """A bundle rewritten on disk (NAS retraining) is never served stale."""
    import os
    net = MLP((1, 2), [8], 1)
    p0 = net.init(jax.random.PRNGKey(0))
    path = save_model(tmp_path / "m", net, p0)
    x = jnp.ones((4, 2))
    e1 = InferenceEngine.get(path)
    y0 = np.asarray(e1(x))
    # retrain: overwrite the bundle with scaled params, bump mtime past
    # filesystem timestamp granularity
    p1 = jax.tree.map(lambda w: w * 3.0, p0)
    save_model(tmp_path / "m", net, p1)
    future = os.path.getmtime(tmp_path / "m" / "params.npz") + 5
    for f in ("spec.json", "params.npz"):
        os.utime(tmp_path / "m" / f, (future, future))
    e2 = InferenceEngine.get(path)
    assert e2 is e1  # same serving object, refreshed in place
    y1 = np.asarray(e2(x))
    assert float(np.abs(y1 - y0).max()) > 1e-6
    # explicit invalidation drops the process-wide entry entirely
    InferenceEngine.invalidate(path)
    e3 = InferenceEngine.get(path)
    assert e3 is not e1


def test_database_atexit_flush_and_full_store_meta(tmp_path):
    import json
    from repro.core import database as db_mod
    db = SurrogateDB(tmp_path / "db")
    g = db.group("r")
    g.append(np.ones((6, 3)), np.ones((6, 2)), 0.1)  # below chunk_rows
    db_mod._flush_all_at_exit()  # what interpreter shutdown runs
    meta = json.loads((g.dir / "meta.json").read_text())
    assert meta["rows"] == 6
    # meta accounts the FULL store across flushes, not the last one
    g.append(np.ones((4, 3)), np.ones((4, 2)), 0.2)
    g.flush()
    meta = json.loads((g.dir / "meta.json").read_text())
    assert meta["rows"] == 10 and meta["chunks"] == 2
    assert meta["input_shape"] == [3] and meta["output_shape"] == [2]
    assert g.load()["inputs"].shape == (10, 3)
    # schema drift is refused BEFORE touching disk: no bad chunk is
    # written, the offending buffer is dropped, and the store stays usable
    g.append(np.ones((2, 5)), np.ones((2, 2)), 0.3)
    import pytest
    with pytest.raises(ValueError):
        g.flush()
    assert len(sorted(g.dir.glob("chunk_*.npz"))) == 2
    g.flush()  # retry (and the atexit hook) must not duplicate anything
    assert len(sorted(g.dir.glob("chunk_*.npz"))) == 2
    assert g.load()["inputs"].shape == (10, 3)


def test_database_groups_and_split(tmp_path):
    db = SurrogateDB(tmp_path / "db")
    g = db.group("r1")
    for i in range(3):
        g.append(np.ones((10, 4)) * i, np.ones((10, 2)) * i, 0.1 * (i + 1))
    g.flush()
    d = g.load()
    assert d["inputs"].shape == (30, 4)
    assert d["runtime"].tolist() == [0.1, 0.2, 0.30000000000000004]
    tr, te = g.train_test_split(0.25, seed=1)
    assert tr["inputs"].shape[0] == 22 and te["inputs"].shape[0] == 8
    assert "r1" in db.groups()
