"""repro.dist subsystem: sharding contexts, spec derivation, HLO analysis."""
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from repro.dist.hlo_analysis import Roofline, collective_stats
from repro.dist.sharding import (ShardCtx, cache_spec_tree, constrain,
                                 current_ctx, param_spec_tree, use_mesh)
from repro.launch.mesh import make_local_mesh
from repro.models import lm

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
                   pattern=(LayerSpec(),))

# spec_for/axis_size only read mesh.shape, so resolution logic is testable
# against any axis->size mapping without allocating devices
FAKE_MESH = types.SimpleNamespace(shape={"data": 4, "model": 2})
FAKE_POD = types.SimpleNamespace(shape={"pod": 2, "data": 4, "model": 2})


# ------------------------------------------------------------- context -----
def test_use_mesh_stack_and_current_ctx():
    assert current_ctx() is None
    mesh = make_local_mesh()
    with use_mesh(mesh) as ctx:
        assert current_ctx() is ctx
        assert ctx.mesh is mesh
        with use_mesh(mesh, multi_pod=True) as inner:
            assert current_ctx() is inner
            assert inner.multi_pod
        assert current_ctx() is ctx
    assert current_ctx() is None


def test_constrain_noop_without_mesh_and_eager():
    x = jnp.ones((4, 8))
    assert constrain(x, "batch", None) is x  # no ctx at all
    with use_mesh(make_local_mesh()):
        assert constrain(x, "batch", None) is x  # eager array: no-op


def test_constrain_lowers_under_jit():
    mesh = make_local_mesh()
    x = jnp.ones((4, 8))
    with use_mesh(mesh):
        f = jax.jit(lambda x: constrain(x * 2, "batch", None))
        assert "harding" in f.lower(x).as_text()  # @Sharding custom call
        assert float(f(x).sum()) == 64.0


# ------------------------------------------------------------ spec_for -----
def test_spec_for_resolution_rules():
    ctx = ShardCtx(FAKE_MESH)
    # plain mapping + divisibility
    assert ctx.spec_for((16, 64), ("batch", "ffn")) == P("data", "model")
    # non-divisible dim replicates instead of crashing
    assert ctx.spec_for((3, 64), ("batch", "ffn")) == P(None, "model")
    # seq and ffn both want "model": left-to-right claim, ffn falls through
    assert ctx.spec_for((16, 64, 64), ("batch", "seq", "ffn")) == \
        P("data", "model", None)
    # decode: seq dim of 1 is never divisible -> ffn gets the axis
    assert ctx.spec_for((16, 1, 64), ("batch", "seq", "ffn")) == \
        P("data", None, "model")
    # longseq combines data+model when batch can't use them
    assert ctx.spec_for((1, 512, 8), ("batch", "longseq", None)) == \
        P(None, ("data", "model"), None)
    assert ctx.axis_size("batch") == 4
    assert ctx.axis_size("ffn") == 2


def test_spec_for_multi_pod_batch():
    ctx = ShardCtx(FAKE_POD, multi_pod=True)
    assert ctx.spec_for((16, 8), ("batch", None)) == P(("pod", "data"), None)
    # multi_pod=False ignores the pod axis even if the mesh has one
    assert ShardCtx(FAKE_POD).spec_for((16, 8), ("batch", None)) == \
        P("data", None)


# ----------------------------------------------------------- spec trees ----
def test_param_spec_tree_matches_init_params():
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), TINY))
    specs = param_spec_tree(shapes, TINY, FAKE_MESH)
    # same tree structure, every leaf a rank-matched PartitionSpec
    checked = jax.tree.map(
        lambda s, sp: isinstance(sp, P) and len(sp) == len(s.shape),
        shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert all(jax.tree.leaves(checked))
    # vmapped stack leaves are right-aligned past the repeat axis
    assert specs["stack"][0]["mlp"]["w1"] == P(None, "data", "model")
    assert specs["stack"][0]["mlp"]["w2"] == P(None, "model", "data")
    assert specs["tok_embed"] == P("model", "data")
    # norm scales replicate
    assert specs["final_norm"]["scale"] == P(None)


def test_cache_spec_tree_decode_and_long_ctx():
    shapes = jax.eval_shape(lambda: lm.init_caches(TINY, 8, 64))
    specs = cache_spec_tree(shapes, TINY, FAKE_MESH)
    # stacked kv: (R, B, S, KV, hd) -> batch on data, kv seq on model
    assert specs["stack"][0]["mixer"]["k"] == P(None, "data", "model", None, None)
    long_shapes = jax.eval_shape(lambda: lm.init_caches(TINY, 1, 512))
    long_specs = cache_spec_tree(long_shapes, TINY, FAKE_MESH, long_ctx=True)
    # batch 1 replicates; the sequence dim takes data+model
    assert long_specs["stack"][0]["mixer"]["k"] == \
        P(None, None, ("data", "model"), None, None)


# -------------------------------------------------------- hlo analysis -----
def test_collective_stats_on_jitted_all_reduce(tmp_path):
    """Compile a real sharded reduction on 8 forced host devices."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.dist.hlo_analysis import collective_stats
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
x = jax.device_put(jnp.ones((64, 16)), NamedSharding(mesh, P("data", None)))
st = collective_stats(jax.jit(lambda x: x.sum()).lower(x).compile())
assert st.per_kind_count.get("all-reduce", 0) >= 1, st.per_kind_count
assert st.total_bytes > 0
assert st.corrected_bytes <= st.total_bytes  # f32 repriced as bf16
print("ALLREDUCE_OK")
"""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=str(root))
    assert "ALLREDUCE_OK" in out.stdout, out.stderr[-2000:]


def test_collective_parser_async_pairs_counted_once():
    hlo = """
  %s = (f32[128,64]{1,0}, f32[128,64]{1,0}) all-reduce-start(f32[128,64]{1,0} %p0), to_apply=%add
  %d = f32[128,64]{1,0} all-reduce-done((f32[128,64]{1,0}, f32[128,64]{1,0}) %s)
"""
    st = collective_stats(hlo)
    assert st.per_kind_count == {"all-reduce": 1}
    assert st.per_kind_bytes["all-reduce"] == 128 * 64 * 4 * 2


def test_collective_parser_async_all_gather_full_size():
    """Async all-gather must price the gathered result, not the shard."""
    hlo = """
  %ags = (f32[8,256]{1,0}, f32[64,256]{1,0}) all-gather-start(f32[8,256]{1,0} %p0), dimensions={0}
  %agd = f32[64,256]{1,0} all-gather-done((f32[8,256]{1,0}, f32[64,256]{1,0}) %ags)
  %sync = f32[64,256]{1,0} all-gather(f32[8,256]{1,0} %p1), dimensions={0}
"""
    st = collective_stats(hlo)
    assert st.per_kind_count == {"all-gather": 2}
    # start-op and sync form price identically: 64*256*4 each
    assert st.per_kind_bytes["all-gather"] == 2 * 64 * 256 * 4


def test_roofline_mfu_bound_and_dict():
    r = Roofline(flops_global=197e12 * 256, hbm_bytes_global=819e9 * 128,
                 coll_bytes_global=50e9 * 64, chips=256,
                 model_flops=197e12 * 128)
    d = r.to_dict()
    assert d["dominant"] == "compute"
    assert abs(d["mfu_bound"] - 0.5) < 1e-9
    assert abs(d["step_time_s"] - 1.0) < 1e-9
