"""Failure-path coverage: fault injection, retry/split, breaker,
dead-dispatcher, close(), and pod dropout (single-process harness).

The spawned 2-process host-drop drill lives at the bottom under the
``slow`` marker (the multihost/chaos CI lanes run it).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import approx_ml, tensor_functor
from repro.launch import multihost
from repro.nn import MLP
from repro.nn.serialize import save_model
from repro.obs.quality import SHADOW
from repro.resilience import (BREAKERS, FAULTS, BreakerPolicy,
                              CircuitBreaker, FaultInjector, InjectedFault,
                              RetryPolicy, parse_plan)
from repro.resilience.breaker import CLOSED, HALF_OPEN, OPEN
from repro.serve import FlushPolicy, ServeQueue
from repro.serve.batcher import Batcher, NonFiniteOutput
from repro.serve.queue import ServeFuture, _StatsGate


@pytest.fixture(autouse=True)
def _clean_globals():
    """Every test starts from quiet process-wide resilience state."""
    FAULTS.clear()
    BREAKERS.reset()
    BREAKERS.enabled = True
    SHADOW.reset()
    multihost.POD_HEALTH.reset()
    yield
    FAULTS.clear()
    BREAKERS.reset()
    BREAKERS.enabled = True
    SHADOW.reset()
    multihost.POD_HEALTH.reset()


# ------------------------------------------------------------- helpers -----
_ifn = tensor_functor("rin: [i, 0:2] = ([i, 0:2])")
_ofn = tensor_functor("rout: [i, 0:1] = ([i, 0:1])")


def _bundle(tmp, name="m"):
    net = MLP((1, 2), [8], 1)
    return save_model(tmp / name, net, net.init(jax.random.PRNGKey(0)))


def _region(n, mode, model, serving=None):
    rngs = {"i": (0, n)}
    return approx_ml(lambda x: {"out": x[:, :1] * 2 + x[:, 1:] * 0.5},
                     name="res", inputs={"x": (_ifn, rngs)},
                     outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, serving=serving)


def _rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, 2)).astype(np.float32)


class _StubEngine:
    """Row-wise fake engine: y = 2x (first feature), with scriptable
    failures so dispatch retry/split paths can be driven exactly."""

    def __init__(self, fail_first=0, poison_value=None):
        self.fail_first = fail_first
        self.poison_value = poison_value
        self.calls = 0

    def apply_batched(self, x, **kw):
        self.calls += 1
        xh = np.asarray(x)
        if self.calls <= self.fail_first:
            raise RuntimeError("transient stub failure")
        if self.poison_value is not None and \
                np.any(xh == self.poison_value):
            raise RuntimeError("poisoned row in batch")
        return jnp.asarray(xh[:, :1] * 2.0)


def _queue(engine, *, attempts=1, **pol):
    pol.setdefault("max_batch_rows", 1 << 30)
    b = Batcher(engine_for=lambda key: engine,
                retry=RetryPolicy(max_attempts=attempts, base_delay_s=0.0,
                                  max_delay_s=0.0, jitter=0.0))
    return ServeQueue(FlushPolicy(**pol), batcher=b)


# ---------------------------------------------------------- fault plans ----
def test_fault_plan_parse_and_validation():
    rules = parse_plan("engine.apply:raise:after=2,n=1;"
                       "pod.flush:drop:pid=1,stall=9")
    assert len(rules) == 2
    assert rules[0].site == "engine.apply" and rules[0].after == 2
    assert rules[1].mode == "drop" and rules[1].stall_s == 9.0
    with pytest.raises(ValueError):
        parse_plan("nosite:raise")
    with pytest.raises(ValueError):
        parse_plan("engine.apply:nomode")
    with pytest.raises(ValueError):
        parse_plan("engine.apply")
    with pytest.raises(ValueError):
        parse_plan("engine.apply:raise:badparam")


def test_fault_triggers_after_every_n():
    f = FaultInjector("engine.apply:raise:after=2,every=2,n=2")
    fired = []
    for i in range(10):
        try:
            f.fire("engine.apply")
        except InjectedFault:
            fired.append(i)
    # calls 0,1 skipped (after=2); then every 2nd matching call, max 2
    assert fired == [2, 4]


def test_fault_probability_is_seed_deterministic():
    def pattern():
        f = FaultInjector("engine.apply:raise:p=0.5,seed=7")
        out = []
        for _ in range(32):
            try:
                f.fire("engine.apply")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b = pattern(), pattern()
    assert a == b and 0 < sum(a) < 32


def test_fault_pid_and_key_scoping(monkeypatch):
    f = FaultInjector("engine.apply:raise:pid=1;batcher.scatter:nan:key=abc")
    # no REPRO_PROCESS_ID in env: pid-scoped rule never matches
    monkeypatch.delenv("REPRO_PROCESS_ID", raising=False)
    assert f.fire("engine.apply") is None
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    with pytest.raises(InjectedFault):
        f.fire("engine.apply")
    assert f.fire("batcher.scatter", key="zzz") is None
    rule = f.fire("batcher.scatter", key="x/abc/y")
    assert rule is not None and rule.mode == "nan"


def test_fault_stall_sleeps():
    f = FaultInjector("engine.apply:stall:stall=0.05,n=1")
    t0 = time.monotonic()
    rule = f.fire("engine.apply")
    assert rule is not None and time.monotonic() - t0 >= 0.05


def test_retry_policy_backoff_caps():
    p = RetryPolicy(max_attempts=5, base_delay_s=0.01, max_delay_s=0.04,
                    jitter=0.0)
    assert p.delay_for(0) == 0.01
    assert p.delay_for(1) == 0.02
    assert p.delay_for(10) == 0.04  # capped
    j = RetryPolicy(jitter=0.5, seed=1)
    d = [j.delay_for(0) for _ in range(8)]
    assert all(0.005 <= x <= 0.01 for x in d)


# -------------------------------------------------------- dispatch paths ---
def test_retry_resolves_transient_failure():
    eng = _StubEngine(fail_first=2)
    q = _queue(eng, attempts=3)
    x = _rows(4)
    fut = q.submit("k", x)
    q.flush("k")
    np.testing.assert_allclose(np.asarray(fut.result(5)), x[:, :1] * 2.0,
                               rtol=1e-6)
    assert eng.calls == 3  # two transient failures, one success
    snap = q.stats("k").snapshot()
    assert snap["batches"] == 1 and snap["batches_failed"] == 0


def test_split_retry_isolates_poisoned_request():
    eng = _StubEngine(poison_value=np.float32(666.0))
    q = _queue(eng, attempts=1)
    good_a, good_b = _rows(3, seed=1), _rows(2, seed=2)
    poison = _rows(3, seed=3)
    poison[1, 0] = 666.0
    fa = q.submit("k", good_a)
    fp = q.submit("k", poison)
    fb = q.submit("k", good_b)
    q.flush("k")
    # siblings of the poisoned request still get exact results
    np.testing.assert_allclose(np.asarray(fa.result(5)),
                               good_a[:, :1] * 2.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(fb.result(5)),
                               good_b[:, :1] * 2.0, rtol=1e-6)
    with pytest.raises(RuntimeError, match="poisoned"):
        fp.result(5)
    snap = q.stats("k").snapshot()
    assert snap["requests_failed"] == 1 and snap["rows_failed"] == 3
    assert q.depth("k") == 0


def test_engine_load_failure_fails_batch_once_no_retry():
    calls = []

    def engine_for(key):
        calls.append(key)
        raise FileNotFoundError("no bundle")

    b = Batcher(engine_for=engine_for,
                retry=RetryPolicy(max_attempts=3, base_delay_s=0.0))
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30), batcher=b)
    f1, f2 = q.submit("k", _rows(2)), q.submit("k", _rows(2, 1))
    q.flush("k")
    for f in (f1, f2):
        with pytest.raises(FileNotFoundError):
            f.result(5)
    # deterministic load failure: exactly one engine resolve, one failed
    # batch — no retry, no split
    assert len(calls) == 1
    assert q.stats("k").snapshot()["batches_failed"] == 1


def test_nonfinite_screening_isolates_poisoned_request():
    eng = _StubEngine()
    q = _queue(eng)
    FAULTS.configure("batcher.scatter:nan:n=1")
    xa, xb = _rows(3, seed=4), _rows(2, seed=5)
    fa = q.submit("k", xa)
    fb = q.submit("k", xb)
    q.flush("k")
    # the injected NaN lands on the first request's rows only
    with pytest.raises(NonFiniteOutput):
        fa.result(5)
    np.testing.assert_allclose(np.asarray(fb.result(5)), xb[:, :1] * 2.0,
                               rtol=1e-6)
    snap = q.stats("k").snapshot()
    assert snap["requests_failed"] == 1 and snap["rows_failed"] == 3
    assert snap["batches"] == 1  # the clean remainder still counts


def test_nonfinite_never_silently_returned():
    class _NaNEngine(_StubEngine):
        def apply_batched(self, x, **kw):
            return jnp.full((np.asarray(x).shape[0], 1), np.nan,
                            jnp.float32)

    q = _queue(_NaNEngine())
    f = q.submit("k", _rows(2))
    q.flush("k")
    with pytest.raises(NonFiniteOutput):
        f.result(5)


# ------------------------------------------------------- dead dispatcher ---
def test_dispatcher_crash_fails_pending_futures_fast(monkeypatch):
    # max_delay_s set: result() trusts the dispatcher thread instead of
    # flushing on demand, so the crash handler resolves the future
    q = ServeQueue(FlushPolicy(max_batch_rows=4, max_delay_s=60.0,
                               block_timeout_s=60.0))

    def boom():
        raise RuntimeError("boom")

    q.start()
    assert q.healthy()
    time.sleep(0.2)  # let the thread reach its idle cv.wait first
    monkeypatch.setattr(q, "_due_locked", boom)
    # the dying thread re-raises on purpose (traceback to stderr); keep
    # pytest's thread-exception reporter from flagging the expected one
    monkeypatch.setattr(threading, "excepthook", lambda _a: None)
    t0 = time.monotonic()
    fut = q.submit("k", _rows(4))  # max-batch notify wakes the thread
    with pytest.raises(RuntimeError, match="dispatcher thread died"):
        fut.result(10)
    # failed immediately by the crash handler, not by block_timeout_s
    assert time.monotonic() - t0 < 5.0
    assert not q.healthy()
    assert q.liveness()["crashed"] is not None
    with pytest.raises(RuntimeError, match="dispatcher thread died"):
        q.submit("k", _rows(1))
    assert q.depth() == 0
    assert q.stats("k").snapshot()["requests_failed"] == 1


# ------------------------------------------------------------- close() -----
def test_close_drain_serves_pending_then_refuses():
    eng = _StubEngine()
    q = _queue(eng)
    x = _rows(3)
    fut = q.submit("k", x)
    q.close(drain=True)
    np.testing.assert_allclose(np.asarray(fut.result(5)), x[:, :1] * 2.0,
                               rtol=1e-6)
    with pytest.raises(RuntimeError, match="closed"):
        q.submit("k", _rows(1))
    q.close(drain=True)  # idempotent
    # shadow worker is stopped (it restarts lazily if re-enabled later)
    t = SHADOW._thread
    assert t is None or not t.is_alive()


def test_close_no_drain_fails_pending():
    q = _queue(_StubEngine())
    fut = q.submit("k", _rows(2))
    q.close(drain=False)
    with pytest.raises(RuntimeError, match="closed"):
        fut.result(5)
    assert q.depth() == 0


# ----------------------------------------------------------- the breaker ---
def _breaker(clock, **kw):
    kw.setdefault("min_samples", 4)
    kw.setdefault("failure_threshold", 0.5)
    kw.setdefault("open_cooldown_s", 1.0)
    kw.setdefault("probe_n", 2)
    kw.setdefault("probe_every", 2)
    return CircuitBreaker("b", BreakerPolicy(**kw), clock=clock)


def test_breaker_full_cycle():
    now = [0.0]
    b = _breaker(lambda: now[0])
    assert b.state == CLOSED and b.allow()
    for _ in range(6):
        b.record_failure()
    assert b.state == OPEN
    assert not b.allow()  # cooldown not elapsed
    now[0] += 1.5
    assert b.allow()  # first probe admits
    assert b.state == HALF_OPEN
    b.record_success()
    b.record_success()
    assert b.state == CLOSED
    # hysteresis: the EWMA was reset — one failure cannot re-trip
    b.record_failure()
    assert b.state == CLOSED


def test_breaker_probe_failure_reopens_and_restamps():
    now = [0.0]
    b = _breaker(lambda: now[0])
    for _ in range(6):
        b.record_failure()
    now[0] += 1.5
    assert b.allow() and b.state == HALF_OPEN
    b.record_failure()
    assert b.state == OPEN
    # re-stamped: the cooldown starts over from the probe failure
    now[0] += 0.5
    assert not b.allow()
    now[0] += 1.0
    assert b.allow() and b.state == HALF_OPEN


def test_breaker_half_open_throttles_traffic():
    now = [0.0]
    b = _breaker(lambda: now[0], probe_every=4)
    for _ in range(6):
        b.record_failure()
    now[0] += 1.5
    admitted = [b.allow() for _ in range(9)]
    # first probe + every 4th thereafter; the rest is turned away
    assert sum(admitted) == 3


def test_breaker_quality_critical_trips_closed_breaker():
    key = "qkey"
    b = BREAKERS.configure(key, BreakerPolicy(open_cooldown_s=60.0))
    SHADOW.set_budget(key, 0.01)
    for _ in range(5):  # hysteresis needs breach_n consecutive breaches
        SHADOW.observe(key, rmse=1.0)
    assert SHADOW.state(key) == "CRITICAL"
    assert not BREAKERS.allow(key)
    assert b.state == OPEN


def test_breaker_board_disabled_is_transparent():
    BREAKERS.enabled = False
    for _ in range(32):
        BREAKERS.record_failure("x")
    assert BREAKERS.allow("x")
    assert BREAKERS.snapshot() == {}


@settings(max_examples=30)
@given(stream=st.integers(min_value=0, max_value=2 ** 20 - 1),
       threshold=st.floats(min_value=0.3, max_value=0.7))
def test_breaker_never_flaps_at_trip_threshold(stream, threshold):
    """Property: with a frozen clock, any outcome stream trips at most
    once (OPEN is absorbing until the cooldown elapses), the breaker
    never jumps OPEN->CLOSED directly, and consecutive closes/trips are
    separated by >= min_samples fresh observations."""
    policy = BreakerPolicy(failure_threshold=threshold, min_samples=4,
                           open_cooldown_s=1.0, probe_n=2, probe_every=2)
    b = CircuitBreaker("p", policy, clock=lambda: 0.0)
    trips, prev = 0, b.state
    obs_since_closed = 0
    for i in range(20):
        bit = (stream >> i) & 1
        b.allow()
        if bit:
            b.record_failure()
        else:
            b.record_success()
        cur = b.state
        if prev == CLOSED:
            obs_since_closed += 1
        assert not (prev == OPEN and cur == CLOSED)
        if prev == CLOSED and cur == OPEN:
            trips += 1
            assert obs_since_closed >= policy.min_samples
        prev = cur
    assert trips <= 1  # frozen clock: OPEN can never even reach HALF_OPEN


@settings(max_examples=20)
@given(steps=st.integers(min_value=1, max_value=40),
       dt=st.floats(min_value=0.01, max_value=0.5))
def test_breaker_reopen_rate_bounded_by_cooldown(steps, dt):
    """Advancing clock: OPEN->HALF_OPEN transitions are bounded by
    elapsed/cooldown + 1 — the breaker cannot probe-flap faster than its
    cooldown no matter how adversarial the traffic."""
    now = [0.0]
    b = _breaker(lambda: now[0], open_cooldown_s=1.0)
    for _ in range(6):
        b.record_failure()
    half_opens = 0
    for _ in range(steps):
        now[0] += dt
        prev = b.state
        b.allow()
        if prev == OPEN and b.state == HALF_OPEN:
            half_opens += 1
        b.record_failure()  # worst case: every probe fails, re-opens
    assert half_opens <= now[0] / 1.0 + 1


# ------------------------------------------------------ region fallback ----
def test_region_infer_falls_back_when_breaker_open(tmp_path):
    bundle = str(_bundle(tmp_path))
    b = BREAKERS.configure(bundle, BreakerPolicy(min_samples=2,
                                                 open_cooldown_s=60.0))
    n = 4
    region = _region(n, "infer", bundle)
    x = _rows(n, seed=7)
    surrogate = np.asarray(region(x=x)["out"])
    b.record_failure()
    b.record_failure()
    assert b.state == OPEN
    out = np.asarray(region(x=x)["out"])
    accurate = x[:, :1] * 2 + x[:, 1:] * 0.5
    np.testing.assert_allclose(out, accurate, rtol=1e-6)
    assert not np.allclose(out, surrogate)  # it really switched paths


def test_region_infer_async_falls_back_when_breaker_open(tmp_path):
    bundle = str(_bundle(tmp_path))
    b = BREAKERS.configure(bundle, BreakerPolicy(min_samples=2,
                                                 open_cooldown_s=60.0))
    b.record_failure()
    b.record_failure()
    n = 3
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    region = _region(n, "infer_async", bundle, serving=q)
    x = _rows(n, seed=8)
    res = region(x=x)
    assert not res.deferred()  # resolved through the accurate path
    np.testing.assert_allclose(np.asarray(res.result()["out"]),
                               x[:, :1] * 2 + x[:, 1:] * 0.5, rtol=1e-6)
    assert q.depth() == 0  # nothing ever hit the queue


def test_async_result_falls_back_on_dispatch_failure(tmp_path):
    bundle = str(_bundle(tmp_path))
    b = Batcher(engine_for=lambda key: (_ for _ in ()).throw(
                    RuntimeError("engine down")),
                retry=RetryPolicy(max_attempts=1, base_delay_s=0.0))
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30), batcher=b)
    n = 3
    region = _region(n, "infer_async", bundle, serving=q)
    x = _rows(n, seed=9)
    res = region(x=x)
    assert res.deferred()
    q.flush()  # dispatch fails; the future carries the exception
    out = np.asarray(res.result(5)["out"])  # ...but result() degrades
    np.testing.assert_allclose(out, x[:, :1] * 2 + x[:, 1:] * 0.5,
                               rtol=1e-6)


# ------------------------------------------------------- future / gates ----
def test_serve_future_first_resolution_wins():
    q = ServeQueue(FlushPolicy())
    f = ServeFuture(q, "k")
    assert f.set_result(np.ones(2))
    assert not f.set_exception(RuntimeError("late loser"))
    np.testing.assert_array_equal(f.result(1), np.ones(2))
    g = ServeFuture(q, "k")
    assert g.set_exception(RuntimeError("first"))
    assert not g.set_result(np.ones(2))
    with pytest.raises(RuntimeError, match="first"):
        g.result(1)


def test_stats_gate_kill_suppresses_zombie_delivery():
    class _Rec:
        def __init__(self):
            self.batches, self.failures = [], []

        def on_batch(self, **kw):
            self.batches.append(kw)

        def on_failure(self, **kw):
            self.failures.append(kw)

    rec = _Rec()
    gate = _StatsGate(rec)
    assert gate.kill()  # nothing delivered yet: watchdog takes over
    gate.on_batch(rows=4)
    gate.on_failure(rows=4)
    assert rec.batches == [] and rec.failures == []
    live = _StatsGate(rec)
    live.on_batch(rows=2)
    assert not live.kill()  # delivered: the round completed
    assert len(rec.batches) == 1


# ------------------------------------------------------------ pod health ---
def test_pod_health_rounds_and_degrade():
    h = multihost.PodHealth()
    assert h.beat() == 1 and h.beat() == 2
    assert h.check_round(1) == ()  # no KV client solo: name nobody
    h.mark_degraded([2, 1])
    h.mark_degraded([1])
    snap = h.snapshot()
    assert snap["degraded"] and snap["offenders"] == [1, 2]


def test_pod_health_rejoin_with_stub_barrier():
    h = multihost.PodHealth()
    h.mark_degraded([1])
    assert not h.try_rejoin(timeout_s=0.2,
                            barrier_fn=lambda: time.sleep(5))  # hangs
    assert h.degraded
    fails = lambda: (_ for _ in ()).throw(RuntimeError("peer gone"))
    assert not h.try_rejoin(timeout_s=1.0, barrier_fn=fails)
    assert h.degraded
    assert h.try_rejoin(timeout_s=1.0, barrier_fn=lambda: None)
    assert not h.degraded and h.offenders == ()


def test_healthz_names_pod_offenders():
    from repro.obs.server import ObsServer
    multihost.POD_HEALTH.mark_degraded([1])
    ready, detail = ObsServer().health()
    assert not ready
    assert "pod:host-1" in detail["critical"]
    multihost.POD_HEALTH.reset()
    ready, detail = ObsServer().health()
    assert "pod:host-1" not in detail["critical"]


def test_pod_flush_watchdog_degrades_instead_of_hanging(monkeypatch):
    """Single-process harness for the watchdog: dispatch_pod hangs (a
    'dropped peer'), the flush must degrade within the timeout and still
    serve every request locally."""
    eng = _StubEngine()

    class _HangingBatcher(Batcher):
        def dispatch_pod(self, key, requests, stats, *, ctx=None,
                         reason="pod"):
            time.sleep(30.0)

    b = _HangingBatcher(engine_for=lambda key: eng,
                        retry=RetryPolicy(max_attempts=1))
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30), batcher=b)
    monkeypatch.setattr(multihost, "is_multiprocess", lambda: True)
    monkeypatch.setenv(multihost.ENV_POD_WATCHDOG, "0.3")
    x = _rows(4, seed=11)
    fut = q.submit("k", x)
    t0 = time.monotonic()
    q.pod_flush("k")
    assert time.monotonic() - t0 < 5.0  # degraded, did not wait 30s
    np.testing.assert_allclose(np.asarray(fut.result(5)), x[:, :1] * 2.0,
                               rtol=1e-6)
    assert multihost.POD_HEALTH.degraded
    # while degraded, later flushes skip the collective entirely
    fut2 = q.submit("k", x)
    t0 = time.monotonic()
    q.pod_flush("k")
    assert time.monotonic() - t0 < 1.0
    assert fut2.done()


# ---------------------------------------------------- spawned pod drill ----
@pytest.mark.slow
def test_host_drop_drill_two_processes():
    multihost.run_host_drop_drill(processes=2, devices_per_host=2,
                                  stall_s=15.0, watchdog_s=2.0)
