"""repro.launch.multihost: pod bootstrap, the local-pod spawn harness,
cross-host mega-batch serving, _to_host addressability enforcement,
device-resident gather, and multi-process tune-cache write races.

The spawn-based tests fork real ``jax.distributed`` process groups on
CPU (Gloo collectives) — they are the tier-1-adjacent coverage the
``multihost`` CI lane runs; everything else here is cheap single-process
coverage of the same code paths.
"""
import json
import multiprocessing
import os
import time

import jax
import numpy as np
import pytest

from repro.launch import multihost
from repro.serve import FlushPolicy, ServeQueue
from repro.serve.batcher import Batcher


# ----------------------------------------------------------- bootstrap -----

def test_bootstrap_single_process_noop(monkeypatch):
    for var in (multihost.ENV_COORDINATOR, multihost.ENV_NUM_PROCESSES,
                multihost.ENV_PROCESS_ID, multihost.ENV_LOCAL_DEVICES):
        monkeypatch.delenv(var, raising=False)
    info = multihost.bootstrap()
    assert info == multihost.PodInfo(0, 1, None)
    assert not info.is_multiprocess


def test_bootstrap_multiprocess_requires_coordinator(monkeypatch):
    monkeypatch.delenv(multihost.ENV_COORDINATOR, raising=False)
    with pytest.raises(RuntimeError, match="coordinator"):
        multihost.bootstrap(num_processes=2, process_id=0)


def test_allgather_counts_single_process():
    counts = multihost.allgather_counts(7)
    assert counts.tolist() == [7]
    multihost.barrier("noop")  # single-process barrier must not collective


def test_spawn_local_pod_rejects_bad_n():
    with pytest.raises(ValueError):
        multihost.spawn_local_pod(0, "os:getcwd")


def _raising_worker():
    raise ValueError("worker boom")


def _exiting_worker():
    os._exit(3)


def test_spawn_local_pod_worker_exception_not_a_timeout():
    # a worker that raises must surface as PodWorkerError carrying the
    # traceback — before the classification fix a dead child was
    # reported as a 300s timeout
    with pytest.raises(multihost.PodWorkerError, match="worker boom"):
        multihost.spawn_local_pod(1, "test_multihost:_raising_worker",
                                  timeout_s=120.0)


def test_spawn_local_pod_crashed_child_reports_exit_code():
    with pytest.raises(multihost.PodWorkerError, match="exited 3"):
        multihost.spawn_local_pod(1, "test_multihost:_exiting_worker",
                                  timeout_s=120.0)


def _fail_while_peer_hangs_worker():
    import time as _time
    if os.environ.get(multihost.ENV_PROCESS_ID) == "1":
        raise ValueError("early boom")
    _time.sleep(120)  # a peer hung in a now-peerless collective


@pytest.mark.slow
def test_spawn_local_pod_fast_failure_not_masked_by_hung_peer():
    """A worker error must surface within the failure grace window, as a
    PodWorkerError naming the real exception — not after the full pod
    timeout as a TimeoutError blaming the consequently-hung peer."""
    t0 = time.monotonic()
    with pytest.raises(multihost.PodWorkerError, match="early boom"):
        multihost.spawn_local_pod(
            2, "test_multihost:_fail_while_peer_hangs_worker",
            timeout_s=110.0)
    assert time.monotonic() - t0 < 90.0  # grace, not the 110s budget


# -------------------------------------------------- _to_host enforcement ---

class _Shard:
    def __init__(self, index, data, replica_id=0):
        self.index = index
        self.data = data
        self.replica_id = replica_id


class _FakeGlobal:
    """Duck-typed global array: only some rows are addressable."""

    def __init__(self, shape, shards, dtype=np.float32):
        self.shape = shape
        self.dtype = np.dtype(dtype)
        self.addressable_shards = shards


def _row_shard(full, lo, hi):
    return _Shard((slice(lo, hi), slice(None)), full[lo:hi])


def test_to_host_full_addressability_roundtrips():
    full = np.arange(32, dtype=np.float32).reshape(8, 4)
    y = _FakeGlobal((8, 4), [_row_shard(full, 0, 4), _row_shard(full, 4, 8)])
    out = Batcher()._to_host(y)
    np.testing.assert_array_equal(out, full)


def test_to_host_partial_addressability_raises():
    # rows 4:8 live on another process: reading them silently returned
    # uninitialized pool memory before — now it must fail loudly
    full = np.arange(32, dtype=np.float32).reshape(8, 4)
    y = _FakeGlobal((8, 4), [_row_shard(full, 0, 4)])
    with pytest.raises(RuntimeError, match="addressable"):
        Batcher()._to_host(y)


def test_to_host_rows_slice_reads_only_local_slab():
    # the pod path asks for exactly this host's slab: addressable by
    # construction even though the rest of the global array is not
    full = np.arange(32, dtype=np.float32).reshape(8, 4)
    y = _FakeGlobal((8, 4), [_row_shard(full, 4, 8)])
    out = Batcher()._to_host(y, rows=(4, 8))
    np.testing.assert_array_equal(out, full[4:8])
    with pytest.raises(RuntimeError, match="addressable"):
        Batcher()._to_host(y, rows=(2, 8))  # 2:4 is remote


def test_to_host_replicated_shards_counted_once():
    full = np.arange(32, dtype=np.float32).reshape(8, 4)
    y = _FakeGlobal((8, 4), [_row_shard(full, 0, 8),
                             _Shard((slice(0, 8), slice(None)),
                                    full, replica_id=1)])
    out = Batcher()._to_host(y)
    np.testing.assert_array_equal(out, full)


def test_to_host_real_array_unchanged():
    y = jax.numpy.asarray(np.arange(12, dtype=np.float32).reshape(4, 3))
    out = Batcher()._to_host(y)
    np.testing.assert_array_equal(out, np.asarray(y))


# ------------------------------------------------- device-resident gather --

class _Req:
    def __init__(self, x):
        self.x = x
        self.n = int(x.shape[0])


def test_gather_host_path_uses_scratch_on_cpu():
    b = Batcher()
    reqs = [_Req(jax.numpy.ones((3, 2))), _Req(jax.numpy.zeros((2, 2)))]
    x, owned = b._gather(reqs, 5, 8)
    assert owned and x.shape == (8, 2)
    assert b.scratch.misses > 0  # assembled in the pooled host buffer
    np.testing.assert_array_equal(
        np.asarray(x),
        np.concatenate([np.ones((3, 2)), np.zeros((2, 2)),
                        np.zeros((3, 2))]).astype(np.float32))


def test_gather_device_resident_concats_on_device(monkeypatch):
    # no accelerator in CI: force the device-resident branch and check it
    # produces the same padded batch without touching the host pool
    monkeypatch.setattr(Batcher, "_device_resident",
                        staticmethod(lambda x: True))
    b = Batcher()
    reqs = [_Req(jax.numpy.ones((3, 2))), _Req(jax.numpy.zeros((2, 2)))]
    x, owned = b._gather(reqs, 5, 8)
    assert owned and x.shape == (8, 2)
    assert b.scratch.misses == 0 and b.scratch.hits == 0
    np.testing.assert_array_equal(
        np.asarray(x),
        np.concatenate([np.ones((3, 2)), np.zeros((2, 2)),
                        np.zeros((3, 2))]).astype(np.float32))


def test_device_resident_false_for_numpy_and_cpu():
    assert not Batcher._device_resident(np.ones((2, 2)))
    assert not Batcher._device_resident(jax.numpy.ones((2, 2)))  # cpu array


# ------------------------------------------- pod_flush (single process) ----

def _bundle(tmp, seed=0):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 2), [16], 1)
    return save_model(tmp / "m", net, net.init(jax.random.PRNGKey(seed)))


def _rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, 2)).astype(np.float32)


def test_pod_flush_single_process_matches_sync(tmp_path):
    from repro.core.engine import InferenceEngine
    mp_path = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    xs = [_rows(5, s) for s in range(3)]
    futs = [q.submit(mp_path, x) for x in xs]
    assert q.pod_flush(mp_path) == 15
    eng = InferenceEngine.get(mp_path)
    for f, x in zip(futs, xs):
        np.testing.assert_array_equal(np.asarray(f.result(timeout=30)),
                                      np.asarray(eng(x)))
    snap = q.stats(mp_path).snapshot()
    assert snap["pod_batches"] == 1 and snap["remote_rows"] == 0
    assert snap["queue_depth_rows"] == 0


def test_pod_flush_empty_is_noop(tmp_path):
    q = ServeQueue(FlushPolicy())
    assert q.pod_flush(str(tmp_path / "missing")) == 0


def test_pod_flush_rejects_started_queue(tmp_path):
    q = ServeQueue(FlushPolicy(max_delay_s=10.0)).start()
    try:
        with pytest.raises(RuntimeError, match="thread"):
            q.pod_flush("anything")
    finally:
        q.stop()


# ------------------------------------------------ spawned pod substrate ----

def _substrate_worker():
    """Runs inside a spawned pod process: collective + ShardCtx checks."""
    import jax
    import numpy as np

    from repro.dist.sharding import ShardCtx
    from repro.launch import multihost
    from repro.launch.mesh import make_pod_mesh

    pid, nproc = jax.process_index(), jax.process_count()
    mesh = make_pod_mesh()
    ctx = ShardCtx(mesh, multi_pod=True)
    counts = multihost.allgather_counts(pid + 3)
    # per-host feeding: each host contributes 2 distinct rows
    local = (np.full((2, 3), pid, np.float32)
             + np.arange(2, dtype=np.float32)[:, None] * 0.5)
    g = ctx.make_global(local, ("data", None),
                        global_shape=(2 * nproc, 3))
    y = jax.block_until_ready(jax.jit(lambda v: v + 1.0)(g))
    mine = {int(s.index[0].start): np.asarray(s.data)[:, 0].tolist()
            for s in y.addressable_shards
            if getattr(s, "replica_id", 0) == 0}
    multihost.barrier("substrate-done")
    return {
        "pid": pid, "nproc": nproc,
        "global_devices": jax.device_count(),
        "local_devices": jax.local_device_count(),
        "counts": counts.tolist(),
        "data_size": ctx.axis_size("data"),
        "local_data_size": ctx.local_axis_size("data"),
        "spec": str(ctx.spec_for((8, 4), ("data", None))),
        "fully_addressable": bool(g.is_fully_addressable),
        "mine": mine,
    }


@pytest.mark.slow
def test_spawn_local_pod_substrate():
    res = multihost.spawn_local_pod(
        2, "test_multihost:_substrate_worker", devices_per_host=2,
        timeout_s=300.0)
    assert [r["pid"] for r in res] == [0, 1]
    for r in res:
        assert r["nproc"] == 2
        assert r["global_devices"] == 4 and r["local_devices"] == 2
        assert r["counts"] == [3, 4]  # pid 0 sent 3, pid 1 sent 4
        # "data" resolves across the pod: pod(2) x data(2) shards
        assert r["data_size"] == 4 and r["local_data_size"] == 2
        assert r["spec"] == str(
            jax.sharding.PartitionSpec(("pod", "data"), None))
        assert not r["fully_addressable"]  # a real cross-process array
    # each host's addressable shards are exactly its own contributed rows
    assert sorted(res[0]["mine"]) == [0, 1]
    assert sorted(res[1]["mine"]) == [2, 3]
    assert res[0]["mine"][0][0] == pytest.approx(1.0)   # 0 + 1.0
    assert res[1]["mine"][2][0] == pytest.approx(2.0)   # 1 + 1.0


@pytest.mark.slow
def test_cross_host_serve_round_trip(tmp_path):
    """The CI acceptance smoke: two processes feed one queue key, the
    flushed mega-batch spans the pod axis, per-caller results are
    bit-identical to single-process serving."""
    res = multihost.run_smoke(processes=2, devices_per_host=2,
                              tmpdir=str(tmp_path))
    for r in res:
        assert r["equal"]
        assert r["remote_rows"] == 15      # the other host's 3x5 rows
        assert r["bucket"] == 32           # per-slab 16 x 2 hosts
        assert r["pod_batches"] == 1


# ----------------------------------------- tune-cache concurrent writes ----

def _cache_writer(path, wid, n_puts):
    """Plain-multiprocessing worker (no jax): hammer one cache file."""
    from repro.tune.cache import TuneCache
    c = TuneCache("fused_mlp", path=path)
    for i in range(n_puts):
        c.put(f"w{wid}-k{i % 5}",
              {"params": {"batch_tile": 32 + wid}, "us": float(i),
               "default_us": 1.0, "speedup_x": 1.0, "exact": True,
               "swept": []})


def test_tune_cache_concurrent_writes_never_corrupt(tmp_path):
    """Two processes racing puts on one artifacts/tune/<kernel>.json:
    every intermediate and the final file must be a valid schema-2
    cache (the atomic tmp+rename write), never a torn JSON."""
    path = str(tmp_path / "fused_mlp.json")
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_cache_writer, args=(path, w, 30))
             for w in range(2)]
    for p in procs:
        p.start()
    seen_valid = 0
    # poll the file while the race runs: a torn write would surface as a
    # JSON parse error here
    while any(p.is_alive() for p in procs):
        if os.path.exists(path):
            try:
                data = json.loads(open(path).read())
            except ValueError as e:  # pragma: no cover - the regression
                for p in procs:
                    p.terminate()
                raise AssertionError(f"torn tune-cache file: {e}")
            assert data.get("schema") == 2
            seen_valid += 1
    for p in procs:
        p.join(timeout=30)
        assert p.exitcode == 0
    data = json.loads(open(path).read())
    assert data["schema"] == 2 and data["kernel"] == "fused_mlp"
    # last-writer-wins per file is acceptable; corruption is not — every
    # surviving record must be a well-formed winner
    assert data["entries"]
    from repro.tune.cache import TuneCache
    c = TuneCache("fused_mlp", path=path)
    for key, rec in c.entries().items():
        assert rec["exact"] and rec["params"]["batch_tile"] in (32, 33)
    assert seen_valid > 0
