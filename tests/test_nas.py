"""NAS: GP regression quality, BO vs random, Pareto front correctness."""
import numpy as np

from repro.nas.gp import GP
from repro.nas.nested import bo_minimize, expected_improvement, pareto_front
from repro.nas.space import Dim, Space


def test_gp_fits_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (40, 2))
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    gp = GP().fit(X, y)
    Xs = rng.uniform(0.1, 0.9, (64, 2))
    mu, sd = gp.predict(Xs)
    ys = np.sin(3 * Xs[:, 0]) + Xs[:, 1] ** 2
    assert np.sqrt(np.mean((mu - ys) ** 2)) < 0.15
    assert (sd > 0).all()


def test_bo_beats_random_on_branin_like():
    def f(cfg):
        x, y = cfg["x"], cfg["y"]
        return (x - 0.3) ** 2 + 2 * (y - 0.7) ** 2

    space = Space([Dim("x", 0, 1), Dim("y", 0, 1)])
    _, best_bo, hist = bo_minimize(f, space, iters=20, init=5, seed=0,
                                   stall=20)
    rng = np.random.default_rng(0)
    best_rand = min(f(space.decode(u)) for u in space.sample(rng, 20))
    assert best_bo <= best_rand * 1.5
    assert best_bo < 0.05


def test_ei_positive_where_uncertain():
    ei = expected_improvement(np.array([0.5, 1.5]), np.array([0.5, 0.01]),
                              best=1.0)
    assert ei[0] > ei[1]


def test_pareto_front():
    pts = [(1, 5), (2, 2), (5, 1), (3, 3), (6, 6)]
    front = pareto_front(pts)
    assert set(front) == {0, 1, 2}


def test_space_decode_kinds():
    s = Space([Dim("a", 2, 12, "int"), Dim("b", 64, 4096, "log2"),
               Dim("c", 0.1, 0.8)])
    cfg = s.decode([0.0, 1.0, 0.5])
    assert cfg["a"] == 2 and cfg["b"] == 4096 and 0.4 < cfg["c"] < 0.5
