"""repro.serve: queue/batcher flush policies, bucket padding round-trip,
multiplexed regions, deadline determinism, stats, backpressure — plus the
engine's bucketed apply + sharding-resolution cache it rides on."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import binomial, miniweather
from repro.core import approx_ml, tensor_functor
from repro.core.engine import InferenceEngine
from repro.dist.sharding import ShardCtx, use_mesh
from repro.launch.mesh import make_local_mesh
from repro.nn import MLP
from repro.nn.layers import Activation, Conv2D, Sequential
from repro.nn.serialize import save_model
from repro.serve import (Backpressure, FlushPolicy, ServeQueue, bucket_size)

_ifn = tensor_functor("sin: [i, 0:2] = ([i, 0:2])")
_ofn = tensor_functor("sout: [i, 0:1] = ([i, 0:1])")


def _lin_bundle(tmp, name="m", seed=0, hidden=16):
    """Untrained MLP bundle: serving semantics don't need accuracy."""
    net = MLP((1, 2), [hidden], 1)
    params = net.init(jax.random.PRNGKey(seed))
    return save_model(tmp / name, net, params)


def _region(n, mode, model, serving=None):
    rngs = {"i": (0, n)}
    return approx_ml(lambda x: {"out": x[:, :1] * 2 + x[:, 1:] * 0.5},
                     name="lin", inputs={"x": (_ifn, rngs)},
                     outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, serving=serving)


def _rows(n, seed=0):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=(n, 2)).astype(np.float32))


# ------------------------------------------------------------- buckets -----
def test_bucket_size_pow2_and_min():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(100) == 128
    assert bucket_size(3, min_bucket=2) == 4
    assert bucket_size(0, min_bucket=1) == 1


def test_bucket_for_respects_data_shard_count():
    from repro.serve import bucket_for
    # no mesh: plain power-of-two behavior
    assert bucket_for(6, 8, 1) == 8
    # 16 data shards: a small batch must not shrink below the shard
    # count or spec_for drops the data axis and the batch replicates
    assert bucket_for(6, 8, 16) == 16
    assert bucket_for(20, 8, 16) == 32
    # non-power-of-two shard counts still divide the bucket
    assert bucket_for(6, 8, 6) == 12
    assert bucket_for(13, 8, 6) == 18
    assert all(bucket_for(n, 8, 6) % 6 == 0 for n in range(1, 50))


def test_bucket_size_edges():
    from repro.serve import bucket_for
    # n=0: the floor governs (a zero-row dispatch never happens, but the
    # controller's target math must not blow up on it)
    assert bucket_size(0) == 8
    assert bucket_for(0, 8, 16) == 16
    # n just past a power of two: next bucket, not the same one
    assert bucket_size(9) == 16
    assert bucket_size(129) == 256
    assert bucket_size(1025) == 2048
    assert bucket_for(257, 8, 8) == 512


def test_bucket_for_more_shards_than_rows():
    from repro.serve import bucket_for
    # n_shards > n: the bucket must still cover every shard, or the
    # data axis silently drops to replication
    assert bucket_for(3, 8, 16) == 16
    assert bucket_for(1, 2, 6) == 6
    assert bucket_for(5, 2, 6) == 6
    for n in range(1, 8):
        b = bucket_for(n, 2, 12)
        assert b >= 12 and b % 12 == 0


def test_deadline_flush_under_concurrent_submitters(tmp_path):
    """Many threads race the dispatcher's deadline: every future must
    resolve exactly once, with totals consistent and rows bit-identical
    to a synchronous engine call (the corner the adaptive controller
    leans on — per-key deadlines recomputed while submits keep landing).
    """
    import threading
    mp = _lin_bundle(tmp_path, "conc")
    eng = InferenceEngine.get(mp)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=0.01,
                               max_pending_rows=10 ** 6))
    results, errors = {}, []

    def submitter(tid):
        try:
            for i in range(4):
                x = _rows(3, seed=100 * tid + i)
                f = q.submit(mp, x)
                results[(tid, i)] = (x, f)
                time.sleep(0.003)
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    with q:
        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        outs = {k: (x, np.asarray(f.result(timeout=10)))
                for k, (x, f) in results.items()}
    assert not errors
    assert len(outs) == 32
    for x, y in outs.values():
        np.testing.assert_array_equal(y, np.asarray(eng(x)))
    st = q.stats(mp).snapshot()
    assert st["rows_completed"] == st["rows_enqueued"] == 96
    assert st["requests_completed"] == 32 and st["requests_failed"] == 0
    assert st["queue_depth_rows"] == 0 and st["queue_depth_requests"] == 0
    assert st["flush_reasons"].get("deadline", 0) >= 1
    assert st["arrival_rate_rows_s"] > 0


def test_apply_batched_matches_call_and_pads(tmp_path):
    mp = _lin_bundle(tmp_path)
    eng = InferenceEngine.get(mp)
    x = _rows(13)
    direct = np.asarray(eng(x))
    batched = np.asarray(eng.apply_batched(x))  # padded to 16, sliced to 13
    assert batched.shape[0] == 13
    np.testing.assert_array_equal(batched, direct)


# ------------------------------------------------- flush: explicit/size ----
def test_explicit_flush_and_bucket_padding_roundtrip(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024, min_bucket=8))
    xa, xb = _rows(3, seed=1), _rows(2, seed=2)
    fa, fb = q.submit(mp, xa), q.submit(mp, xb)
    assert not fa.done() and q.depth(mp) == 5
    assert q.flush() == 5
    # padded rows never leak: each caller gets exactly its rows back,
    # bit-identical to a synchronous engine call on its own inputs
    eng = InferenceEngine.get(mp)
    ya, yb = np.asarray(fa.result(1)), np.asarray(fb.result(1))
    assert ya.shape[0] == 3 and yb.shape[0] == 2
    np.testing.assert_array_equal(ya, np.asarray(eng(xa)))
    np.testing.assert_array_equal(yb, np.asarray(eng(xb)))
    st = q.stats(mp).snapshot()
    assert st["batches"] == 1
    assert st["bucket_rows"] == 8 and st["padded_rows"] == 3
    assert st["batch_occupancy"] == pytest.approx(5 / 8)
    assert st["queue_depth_rows"] == 0 and st["queue_depth_requests"] == 0


def test_max_batch_rows_flushes_inline(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=8))
    futs = [q.submit(mp, _rows(4, seed=i)) for i in range(2)]
    # 4+4 rows hit max_batch_rows: flushed by the second submit itself
    assert all(f.done() for f in futs)
    assert q.stats(mp).snapshot()["flush_reasons"] == {"max_batch": 1}


def test_future_result_flushes_on_demand(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024))
    f = q.submit(mp, _rows(4))
    assert not f.done()
    out = f.result(timeout=5)  # thread-free queue: result() makes progress
    assert out.shape == (4, 1)
    assert q.stats(mp).snapshot()["flush_reasons"] == {"demand": 1}


def test_submit_shape_mismatch_rejected(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue()
    q.submit(mp, _rows(2))
    with pytest.raises(ValueError, match="feature-shape mismatch"):
        q.submit(mp, jnp.zeros((2, 3)))
    q.flush()


# -------------------------------------------------------- multiplexing -----
def test_multiplexed_bundles_one_queue(tmp_path):
    mp1 = _lin_bundle(tmp_path, "m1", seed=1)
    mp2 = _lin_bundle(tmp_path, "m2", seed=2)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024))
    xs = [_rows(4, seed=i) for i in range(4)]
    # interleave submissions across the two bundles
    f1a, f2a = q.submit(mp1, xs[0]), q.submit(mp2, xs[1])
    f1b, f2b = q.submit(mp1, xs[2]), q.submit(mp2, xs[3])
    q.flush()
    e1, e2 = InferenceEngine.get(mp1), InferenceEngine.get(mp2)
    np.testing.assert_array_equal(np.asarray(f1a.result(1)),
                                  np.asarray(e1(xs[0])))
    np.testing.assert_array_equal(np.asarray(f2a.result(1)),
                                  np.asarray(e2(xs[1])))
    np.testing.assert_array_equal(np.asarray(f1b.result(1)),
                                  np.asarray(e1(xs[2])))
    np.testing.assert_array_equal(np.asarray(f2b.result(1)),
                                  np.asarray(e2(xs[3])))
    # each key got exactly one coalesced batch with its own stats
    assert q.stats(mp1).snapshot()["batches"] == 1
    assert q.stats(mp2).snapshot()["batches"] == 1
    assert q.stats(mp1).snapshot()["rows_completed"] == 8


# ------------------------------------------------------ deadline flush -----
def test_deadline_flush_thread_bit_identical_to_sync(tmp_path):
    mp = _lin_bundle(tmp_path)
    x = _rows(6, seed=3)
    sync_region = _region(6, "infer", mp)
    ref = np.asarray(sync_region(x=x)["out"])
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=0.05))
    with q:  # dispatcher thread enforces the deadline
        region = _region(6, "infer_async", mp, serving=q)
        h = region(x=x)
        out = np.asarray(h.result(timeout=10)["out"])
    np.testing.assert_array_equal(out, ref)  # bit-identical, incl. padding
    assert q.stats(mp).snapshot()["flush_reasons"].get("deadline", 0) >= 1


def test_deadline_flush_poll_deterministic(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=0.02))
    f = q.submit(mp, _rows(4))
    assert q.poll() == 0  # deadline not reached yet
    time.sleep(0.03)
    assert q.poll() == 4
    assert f.done()
    assert q.stats(mp).snapshot()["flush_reasons"] == {"deadline": 1}


# -------------------------------------------------------- backpressure -----
def test_backpressure_raises_when_not_blocking(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_pending_rows=8,
                               block=False))
    q.submit(mp, _rows(8))
    with pytest.raises(Backpressure):
        q.submit(mp, _rows(4))
    q.flush()
    q.submit(mp, _rows(4))  # space again after the flush
    q.flush()


def test_backpressure_oversized_request_admitted_when_empty(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_pending_rows=4,
                               block=False))
    f = q.submit(mp, _rows(16))  # larger than the cap: must not deadlock
    q.flush()
    assert f.result(1).shape == (16, 1)


def test_backpressure_thread_free_drains_inline(tmp_path):
    """Single-threaded driver: a full queue flushes itself to make space
    rather than waiting on a drain nobody else can perform."""
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_pending_rows=8,
                               block=True, block_timeout_s=5.0))
    f1 = q.submit(mp, _rows(8))
    f2 = q.submit(mp, _rows(8))  # full: inline backpressure drain, admit
    assert f1.done()  # the drain dispatched the first request
    assert q.stats(mp).snapshot()["flush_reasons"]["backpressure"] == 1
    q.flush()
    assert f2.result(1).shape == (8, 1)


def test_backpressure_block_timeout_with_idle_thread(tmp_path):
    """Threaded queue whose policy never flushes (no deadline, huge batch):
    a blocked submit must give up after block_timeout_s."""
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_pending_rows=8,
                               block=True, block_timeout_s=0.05))
    q.start()
    try:
        q.submit(mp, _rows(8))
        t0 = time.monotonic()
        with pytest.raises(Backpressure, match="blocked"):
            q.submit(mp, _rows(8))
        assert time.monotonic() - t0 >= 0.04
    finally:
        q.stop()


def test_backpressure_unblocks_on_dispatcher_drain(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=8, max_pending_rows=8,
                               block=True, block_timeout_s=10.0))
    with q:
        q.submit(mp, _rows(8))  # fills the queue; thread flushes (max_batch)
        f = q.submit(mp, _rows(8))  # blocks until the drain, then enqueues
        out = f.result(timeout=10)
    assert out.shape == (8, 1)


# ---------------------------------------------------------- statistics -----
def test_stats_counters_and_latency(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024, min_bucket=8))
    for i in range(3):
        q.submit(mp, _rows(2, seed=i))
    q.flush()
    st = q.stats(mp).snapshot()
    assert st["requests_enqueued"] == 3 and st["rows_enqueued"] == 6
    assert st["requests_completed"] == 3 and st["rows_completed"] == 6
    assert st["bucket_rows"] == 8 and st["padded_rows"] == 2
    assert st["latency_p50_ms"] > 0
    assert st["latency_p99_ms"] >= st["latency_p50_ms"]
    assert st["rows_per_s"] > 0
    assert st["queue_depth_rows"] == 0


def test_batch_failure_propagates_to_all_futures(tmp_path):
    q = ServeQueue()
    key = str(tmp_path / "no_such_bundle")
    f1 = q.submit(key, _rows(2))
    f2 = q.submit(key, _rows(2))
    q.flush()
    with pytest.raises(Exception):
        f1.result(1)
    with pytest.raises(Exception):
        f2.result(1)
    # failed work never counts as served: completed/rows_per_s stay zero
    st = q.stats(key).snapshot()
    assert st["batches"] == 0 and st["batches_failed"] == 1
    assert st["requests_completed"] == 0 and st["requests_failed"] == 2
    assert st["rows_completed"] == 0 and st["rows_failed"] == 4
    assert st["rows_per_s"] == 0.0
    assert st["queue_depth_rows"] == 0 and st["queue_depth_requests"] == 0


# ----------------------------------------------------- region async API ----
def test_region_infer_async_bit_identical_to_infer(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024))
    r_async = _region(8, "infer_async", mp, serving=q)
    r_sync = _region(8, "infer", mp)
    x = _rows(8, seed=4)
    h = r_async(x=x)
    assert h.deferred() and not h.done()
    q.flush()
    np.testing.assert_array_equal(np.asarray(h.result(1)["out"]),
                                  np.asarray(r_sync(x=x)["out"]))


def test_region_infer_async_requires_queue(tmp_path):
    mp = _lin_bundle(tmp_path)
    with pytest.raises(AssertionError, match="serving"):
        _region(8, "infer_async", mp)


def test_region_infer_async_inside_trace_degrades_sync(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue()
    r = _region(8, "infer_async", mp, serving=q)
    x = _rows(8, seed=5)

    @jax.jit
    def step(x):
        return r(x=x).result()["out"]  # resolved synchronously in-trace

    np.testing.assert_allclose(np.asarray(step(x)),
                               np.asarray(_region(8, "infer", mp)(x=x)["out"]),
                               rtol=1e-6, atol=1e-6)
    assert q.depth() == 0  # nothing parked on the host queue


def test_predicated_region_serving_defers(tmp_path):
    mp = _lin_bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1024))
    r = _region(8, "predicated", mp, serving=q)
    x = _rows(8, seed=6)
    # accurate branch: resolved immediately, same handle interface
    h_acc = r(predicate=False, x=x)
    assert not h_acc.deferred() and h_acc.done()
    np.testing.assert_allclose(np.asarray(h_acc.result()["out"]),
                               np.asarray(x[:, :1] * 2 + x[:, 1:] * 0.5),
                               rtol=1e-6)
    # ML branch: defers through the queue
    h_ml = r(predicate=True, x=x)
    assert h_ml.deferred() and not h_ml.done()
    q.flush()
    np.testing.assert_array_equal(
        np.asarray(h_ml.result(1)["out"]),
        np.asarray(_region(8, "infer", mp)(x=x)["out"]))


# ----------------------------------------------------------- app drivers ---
def test_binomial_chunked_async_driver(tmp_path):
    net = MLP((1, 5), [16], 1)
    mp = save_model(tmp_path / "bin", net, net.init(jax.random.PRNGKey(0)))
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6))
    region = binomial.make_region(8, mode="infer_async", model=mp, serving=q)
    opts = binomial.make_inputs(32, seed=9)
    out = binomial.price_chunks_async(opts, region, q, chunk=8)
    r_sync = binomial.make_region(32, mode="infer", model=mp)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(r_sync(opts=opts)["out"]))
    st = q.stats(mp).snapshot()
    assert st["batches"] == 1 and st["rows_completed"] == 32


def test_miniweather_ensemble_async_driver(tmp_path):
    # conv-only surrogate: grid -> grid, matches the stencil bridge shapes
    ny, nx = miniweather.NY - 2, miniweather.NX - 2
    net = Sequential([Conv2D(8, 3), Activation("relu"), Conv2D(4, 3)],
                     (1, ny, nx, 20))
    mp = save_model(tmp_path / "mw", net, net.init(jax.random.PRNGKey(0)))
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6))
    region = miniweather.make_region(mode="infer_async", model=mp, serving=q)
    states = [miniweather.init_state(seed=s) for s in range(3)]
    outs = miniweather.run_ensemble_async(states, steps=2, region=region,
                                          queue=q)
    # reference: each member advanced with synchronous inference
    r_sync = miniweather.make_region(mode="infer", model=mp)
    for s0, got in zip(states, outs):
        ref = s0
        for _ in range(2):
            ref = r_sync(state=ref)["state"]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    st = q.stats(mp).snapshot()
    assert st["batches"] == 2  # one coalesced batch per sweep step
    assert st["rows_completed"] == 6  # 3 members x 2 steps


# ------------------------------------------- engine placement/caching -----
def test_engine_sharding_resolution_cached(tmp_path, monkeypatch):
    mp = _lin_bundle(tmp_path, "cache")
    eng = InferenceEngine(mp)  # private instance: isolate the cache
    calls = {"n": 0}
    orig = ShardCtx.sharding_for

    def counting(self, shape, axes):
        calls["n"] += 1
        return orig(self, shape, axes)

    monkeypatch.setattr(ShardCtx, "sharding_for", counting)
    x = _rows(8)
    with use_mesh(make_local_mesh()):
        for _ in range(4):
            eng(x)
        assert calls["n"] == 1  # resolved once, cached per (shape, mesh)
        eng(_rows(16))
        assert calls["n"] == 2  # new shape resolves once more
        for _ in range(3):
            eng(_rows(16, seed=7))
        assert calls["n"] == 2


def test_engine_place_skips_redundant_device_put(tmp_path):
    mp = _lin_bundle(tmp_path, "skip")
    eng = InferenceEngine(mp)
    x = _rows(8)
    with use_mesh(make_local_mesh()) as ctx:
        placed = eng._place(x, ctx)
        assert eng._place(placed, ctx) is placed  # already there: no-op


def test_dispatcher_thread_serves_under_submitters_mesh(tmp_path):
    """ShardCtx is thread-local: a deadline flush on the dispatcher thread
    must re-install the submitter's mesh or the batch serves unsharded."""
    mp = _lin_bundle(tmp_path, "threadmesh")
    eng = InferenceEngine.get(mp)
    eng._applies.clear()
    eng._shardings.clear()
    mesh = make_local_mesh()
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=0.02))
    with q:
        with use_mesh(mesh):
            f = q.submit(mp, _rows(8))
        out = f.result(timeout=10)
    assert out.shape == (8, 1)
    assert q.stats(mp).snapshot()["flush_reasons"].get("deadline", 0) >= 1
    # the apply compiled for (mesh, False), not for the no-mesh key None
    assert (mesh, False) in eng._applies
    assert any(k[1] == mesh for k in eng._shardings)


def test_engine_reload_drops_sharding_cache(tmp_path):
    mp = _lin_bundle(tmp_path, "reload")
    eng = InferenceEngine(mp)
    with use_mesh(make_local_mesh()) as ctx:
        eng._place(_rows(8), ctx)
        assert len(eng._shardings) == 1
        eng.reload()
        assert len(eng._shardings) == 0
