"""repro.obs quality/SLO/endpoint: shadow scoring against the accurate
function, the hysteretic drift-alert machine, multi-window SLO burn
rates over ServeStats, and the scrapeable HTTP endpoint.

The region tests exercise the real sampling hooks: an ``approx_ml``
region whose accurate function is the surrogate's own original forward,
so the shadow replay's RMSE is ~0 on clean weights and the async path's
``quality.shadow`` span rides the request's serve trace id.
"""
import json
import math
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.obs import (CRITICAL, MONITOR, OK, SHADOW, SLO, WARN,
                       AlertMachine, ObsServer, TRACER, ShadowScorer,
                       enable_tracing, validate_exposition)
from repro.serve import FlushPolicy, ServeQueue
from repro.serve.stats import ServeStats


@pytest.fixture(autouse=True)
def _obs_reset():
    """SHADOW/MONITOR/TRACER are process-global: leave them as these
    tests found them (off, empty) so tier-1 neighbors see no stray
    alert state."""
    yield
    SHADOW.disable()
    SHADOW.rate = 0.0
    SHADOW.flush(10)
    SHADOW.reset()
    MONITOR.untrack()
    TRACER.enabled = False
    TRACER.clear()


def _bundle(tmp, seed=0):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 2), [16], 1)
    return save_model(tmp / "m", net, net.init(jax.random.PRNGKey(seed)))


def _self_region(tmp, mode, serving=None, n=4):
    """A region whose accurate fn is the bundle's own forward: shadow
    scoring must find (near-)zero error on clean weights."""
    from repro.core import approx_ml, tensor_functor
    from repro.nn.serialize import load_model
    mp = _bundle(tmp)
    net, params, _ = load_model(mp)
    apply = jax.jit(net.apply)

    def fn(x):
        return {"out": apply(params, x)}

    rngs = {"i": (0, n)}
    region = approx_ml(
        fn, name="quality_probe",
        inputs={"x": (tensor_functor("qx: [i, 0:2] = ([i, 0:2])"), rngs)},
        outputs={"out": (tensor_functor("qy: [i, 0:1] = ([i, 0:1])"),
                         rngs)},
        mode=mode, model=mp, serving=serving)
    return mp, region


def _rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, 2)).astype(
        np.float32)


# ---------------------------------------------------------- alert machine ---

def test_alert_machine_needs_consecutive_breaches():
    m = AlertMachine(breach_n=3, clear_n=5)
    assert m.step(2.0, 0.5, 1.0) == OK
    assert m.step(2.0, 0.5, 1.0) == OK
    assert m.step(2.0, 0.5, 1.0) == CRITICAL  # third consecutive breach
    assert m.transitions == 1


def test_alert_machine_breach_counter_resets_on_ok():
    m = AlertMachine(breach_n=3, clear_n=5)
    m.step(2.0, 0.5, 1.0)
    m.step(2.0, 0.5, 1.0)
    m.step(0.1, 0.5, 1.0)  # dips below: streak broken
    m.step(2.0, 0.5, 1.0)
    assert m.step(2.0, 0.5, 1.0) == OK  # only 2 consecutive again


def test_alert_machine_hysteresis_and_clear():
    m = AlertMachine(breach_n=1, clear_n=3, hysteresis=0.8)
    assert m.step(1.5, 0.5, 1.0) == CRITICAL
    # latched CRITICAL shrinks its threshold to 0.8: 0.9 is still
    # critical, so the clear streak never starts
    for _ in range(5):
        assert m.step(0.9, 0.5, 1.0) == CRITICAL
    # truly below: clear_n consecutive evaluations de-escalate (to the
    # candidate level, here WARN since 0.6 >= 0.5)
    m.step(0.6, 0.5, 1.0)
    m.step(0.6, 0.5, 1.0)
    assert m.step(0.6, 0.5, 1.0) == WARN


def test_alert_machine_without_budget_never_alerts():
    m = AlertMachine(breach_n=1)
    for _ in range(10):
        assert m.step(1e9, None, None) == OK


# ---------------------------------------------------------- shadow scorer ---

def test_observe_folds_ewma_and_drives_alert():
    s = ShadowScorer()
    s.set_budget("k", 0.1)  # warn at 0.05, critical at 0.1
    assert s.observe("k", rmse=0.01) == OK
    # EWMA: 0.01 + 0.25 * (0.09 - 0.01) = 0.03
    s.observe("k", rmse=0.09)
    snap = s.snapshot()["keys"]["k"]
    assert snap["rmse_ewma"] == pytest.approx(0.03)
    assert snap["samples"] == 2
    for _ in range(20):
        state = s.observe("k", rmse=5.0)
    assert state == CRITICAL and s.worst_state() == CRITICAL
    assert s.state("other") == OK  # unseen keys are OK


def test_submit_scores_thunks_on_worker():
    s = ShadowScorer(rate=1.0)
    yp = np.ones((4, 1), np.float32)
    yr = np.zeros((4, 1), np.float32)
    assert s.submit("k", pred=lambda: yp, ref=lambda: yr, rows=4)
    assert s.flush(30)
    snap = s.snapshot()["keys"]["k"]
    assert snap["rmse_ewma"] == pytest.approx(1.0)
    assert snap["max_abs_ewma"] == pytest.approx(1.0)
    assert snap["rows"] == 4
    s.stop()


def test_submit_backlog_drops_are_counted():
    from repro.obs import default_registry
    s = ShadowScorer(rate=1.0, max_backlog=0)  # every submit overflows
    dropped = default_registry().counter(
        "repro_quality_dropped_total", "", ("key", "reason"))
    before = dropped.value(key="kb", reason="backlog")
    assert not s.submit("kb", pred=lambda: 0, ref=lambda: 0)
    assert dropped.value(key="kb", reason="backlog") == before + 1


def test_submit_ref_error_drops_not_kills_worker():
    from repro.obs import default_registry
    s = ShadowScorer(rate=1.0)

    def boom():
        raise RuntimeError("replay failed")

    dropped = default_registry().counter(
        "repro_quality_dropped_total", "", ("key", "reason"))
    before = dropped.value(key="ke", reason="error")
    s.submit("ke", pred=lambda: np.zeros(2), ref=boom)
    assert s.flush(30)
    assert dropped.value(key="ke", reason="error") == before + 1
    # the worker survived: a good sample still scores
    s.submit("ke", pred=lambda: np.zeros(2), ref=lambda: np.zeros(2))
    assert s.flush(30)
    assert s.snapshot()["keys"]["ke"]["samples"] == 1
    s.stop()


def test_sample_rate_zero_and_one():
    s = ShadowScorer()
    assert not s.enabled and not s.sample()
    s.enable(rate=1.0)
    assert all(s.sample() for _ in range(32))
    s.disable()
    assert not s.sample()


# ------------------------------------------------------------ region hooks ---

def test_sync_region_shadow_scores_near_zero(tmp_path):
    mp, region = _self_region(tmp_path, "infer")
    SHADOW.enable(rate=1.0)
    SHADOW.set_budget(mp, 0.05)
    region(x=_rows(4))
    assert SHADOW.flush(60)
    snap = SHADOW.snapshot()["keys"][mp]
    assert snap["samples"] == 1 and snap["rows"] == 4
    assert snap["rmse_ewma"] < 1e-5  # surrogate == accurate fn
    assert snap["state"] == OK


def test_async_region_shadow_span_rides_serve_trace(tmp_path):
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    mp, region = _self_region(tmp_path, "infer_async", serving=q)
    enable_tracing()
    TRACER.clear()
    SHADOW.enable(rate=1.0)
    h = region(x=_rows(4, seed=1))
    q.flush(mp)
    h.result(30)
    assert SHADOW.flush(60)
    spans = TRACER.events()
    sub = next(s for s in spans if s.name == "queue.submit")
    shadow = next(s for s in spans if s.name == "quality.shadow")
    assert sub.trace is not None and shadow.trace == sub.trace
    assert shadow.thread == "repro-shadow-score"
    assert SHADOW.snapshot()["keys"][mp]["rmse_ewma"] < 1e-5


def test_disabled_shadow_never_samples_regions(tmp_path):
    mp, region = _self_region(tmp_path, "infer")
    SHADOW.disable()
    region(x=_rows(4))
    assert mp not in SHADOW.snapshot()["keys"]


# ------------------------------------------------------- stats event ring ---

def test_request_events_window_and_failures():
    st = ServeStats("k")
    st.on_batch(requests=2, rows=4, bucket=8, reason="t", busy_s=0.0,
                latencies_s=[0.1, 0.2])
    st.on_failure(requests=1, rows=2, reason="engine-error", busy_s=0.0)
    evs = st.request_events()
    assert len(evs) == 3
    oks = [e for e in evs if e[2]]
    bad = [e for e in evs if not e[2]]
    assert sorted(e[1] for e in oks) == [0.1, 0.2]
    assert len(bad) == 1 and math.isnan(bad[0][1])
    # window filter: nothing is newer than now - 0 seconds ago
    t_latest = max(e[0] for e in evs)
    assert st.request_events(window_s=1e-9, now=t_latest + 10) == []
    assert len(st.request_events(window_s=1e9, now=t_latest)) == 3


# ------------------------------------------------------------ SLO monitor ---

class _StubStats:
    """request_events-shaped stub: (t, latency_s, ok) tuples."""

    def __init__(self, events):
        self._events = events

    def request_events(self, window_s=None, now=None):
        if window_s is None:
            return list(self._events)
        return [e for e in self._events if e[0] >= now - window_s]


def test_slo_burn_rates_and_critical():
    now = 1000.0
    slo = SLO(latency_threshold_s=0.1, latency_target=0.9,
              availability_target=0.9, windows_s=(10.0, 100.0),
              warn_burn=1.0, crit_burn=5.0, min_events=4)
    # 20 requests in the last 10s, half too slow: err 0.5 / budget 0.1
    events = [(now - 0.1 * i, 0.2 if i % 2 else 0.01, True)
              for i in range(20)]
    MONITOR.track("kslo", _StubStats(events), slo)
    r = MONITOR.evaluate(now=now)["kslo"]["latency"]
    assert r["burn"]["10s"] == pytest.approx(5.0)
    assert r["burn"]["100s"] == pytest.approx(5.0)
    assert r["value"] == pytest.approx(5.0)
    # breach_n=2 on the SLO machines: second evaluation latches CRITICAL
    MONITOR.evaluate(now=now)
    assert MONITOR.states()["kslo"]["latency"] == CRITICAL
    # availability untouched: every request succeeded
    assert MONITOR.states()["kslo"]["availability"] == OK


def test_slo_min_events_guard_and_both_windows_must_burn():
    now = 1000.0
    slo = SLO(latency_threshold_s=0.1, latency_target=0.9,
              windows_s=(10.0, 100.0), min_events=10)
    # all 8 requests slow AND recent: short window has too few events
    # (burn 0), long window has too few events (burn 0) -> value 0
    events = [(now - 0.1 * i, 9.9, True) for i in range(8)]
    MONITOR.track("kmin", _StubStats(events), slo)
    r = MONITOR.evaluate(now=now)["kmin"]["latency"]
    assert r["value"] == 0.0
    # 30 slow requests, but all older than the short window: the long
    # window burns, the short window is empty -> min is 0 (no alert)
    events = [(now - 50.0 - 0.1 * i, 9.9, True) for i in range(30)]
    MONITOR.track("kold", _StubStats(events), slo)
    r = MONITOR.evaluate(now=now)["kold"]["latency"]
    assert r["burn"]["100s"] > 1.0 and r["burn"]["10s"] == 0.0
    assert r["value"] == 0.0


def test_slo_failed_requests_burn_availability():
    now = 1000.0
    slo = SLO(availability_target=0.9, windows_s=(10.0, 100.0),
              min_events=4)
    events = [(now - 0.1 * i, float("nan"), False) for i in range(10)]
    MONITOR.track("kav", _StubStats(events), slo)
    r = MONITOR.evaluate(now=now)["kav"]
    assert r["availability"]["value"] == pytest.approx(10.0)  # 1.0 / 0.1
    assert r["availability"]["budget_remaining"] == 0.0
    # NaN latency counts against the latency objective too
    assert r["latency"]["value"] > 0.0


# ------------------------------------------------------------ obs server ----

def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=10) as r:
            return r.status, r.read().decode("utf-8")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode("utf-8")


def test_obs_server_routes_and_healthz_flip():
    server = ObsServer().start()
    try:
        for route in ("/", "/metrics", "/varz", "/tracez"):
            code, _ = _get(server.url(route))
            assert code == 200, route
        code, _ = _get(server.url("/nope"))
        assert code == 404
        code, body = _get(server.url("/healthz"))
        assert code == 200 and json.loads(body)["status"] == "ok"
        # a CRITICAL drift alert turns readiness into 503
        SHADOW.set_budget("kbad", 0.01)
        for _ in range(5):
            SHADOW.observe("kbad", rmse=9.0)
        assert SHADOW.state("kbad") == CRITICAL
        code, body = _get(server.url("/healthz"))
        detail = json.loads(body)
        assert code == 503 and "quality:kbad" in detail["critical"]
        # and /metrics stays scrapeable + valid while unhealthy
        code, text = _get(server.url("/metrics"))
        assert code == 200
        assert validate_exposition(text)["samples"] > 0
        assert 'repro_quality_rmse{key="kbad"}' in text
    finally:
        server.stop()


def test_obs_server_dead_queue_unready():
    class DeadQueue:
        def healthy(self):
            return False

        def snapshot(self):
            return {}

    server = ObsServer().start().watch_queue("dead", DeadQueue())
    try:
        code, body = _get(server.url("/healthz"))
        assert code == 503
        assert "queue:dead" in json.loads(body)["critical"]
    finally:
        server.stop()


def test_queue_healthy_and_snapshot(tmp_path):
    mp = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    assert q.healthy()  # thread-free queues are always healthy
    q.submit(mp, _rows(2)).result(30)
    snap = q.snapshot()
    assert mp in snap["keys"] and snap["liveness"]["mode"] == "thread-free"
    q2 = ServeQueue(FlushPolicy(max_batch_rows=1 << 30,
                                max_delay_s=0.005)).start()
    try:
        assert q2.healthy() and q2.liveness()["dispatcher_alive"]
    finally:
        q2.stop()
    # a cleanly-stopped queue reverts to thread-free (callers flush
    # inline), which is healthy again
    assert q2.healthy() and q2.liveness()["mode"] == "thread-free"


def test_varz_carries_quality_and_slo():
    SHADOW.set_budget("kv", 1.0)
    SHADOW.observe("kv", rmse=0.5)
    MONITOR.track("kv", _StubStats([]), SLO())
    server = ObsServer().start()
    try:
        _, body = _get(server.url("/varz"))
        doc = json.loads(body)
        assert doc["quality"]["keys"]["kv"]["rmse_ewma"] == 0.5
        assert "kv" in doc["slo"]["keys"]
        assert "repro_quality_rmse" in doc["metrics"]
    finally:
        server.stop()


# ----------------------------------------------------- exposition parsing ---

def test_validate_exposition_rejects_malformed():
    with pytest.raises(ValueError, match="unparseable"):
        validate_exposition("no value here\n")
    with pytest.raises(ValueError, match="invalid sample value"):
        validate_exposition("m 12x\n")
    with pytest.raises(ValueError, match="malformed label"):
        validate_exposition('m{k=unquoted} 1\n')
    with pytest.raises(ValueError, match="duplicate"):
        validate_exposition('m{k="a"} 1\nm{k="a"} 2\n')


def test_validate_exposition_histogram_contract():
    ok = ('# TYPE h histogram\n'
          'h_bucket{le="1"} 1\nh_bucket{le="+Inf"} 2\n'
          'h_sum 3.5\nh_count 2\n')
    assert validate_exposition(ok)["families"] == {"h": "histogram"}
    with pytest.raises(ValueError, match="missing _sum"):
        validate_exposition('# TYPE h histogram\n'
                            'h_bucket{le="+Inf"} 1\nh_count 1\n')
    with pytest.raises(ValueError, match="!= _count"):
        validate_exposition('# TYPE h histogram\n'
                            'h_bucket{le="+Inf"} 1\nh_sum 1\nh_count 2\n')
    with pytest.raises(ValueError, match="not cumulative"):
        validate_exposition('# TYPE h histogram\n'
                            'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 2\n'
                            'h_sum 1\nh_count 2\n')
    with pytest.raises(ValueError, match=r"missing le=.\+Inf"):
        validate_exposition('# TYPE h histogram\n'
                            'h_bucket{le="1"} 1\nh_sum 1\nh_count 1\n')
