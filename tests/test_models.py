"""Per-arch smoke tests (reduced configs): forward/train-step shapes + no
NaNs, and prefill+decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCH_NAMES, reduced
from repro.configs.base import SHAPES, cell_supported, get_config
from repro.models import lm
from repro.train import trainer

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.needs_position_ids:
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S), (3, B, S)).astype(jnp.int32)
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            RNG, (B, cfg.enc_ctx, cfg.d_model), cfg.jdtype)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_forward_and_trainstep(name):
    cfg = reduced(get_config(name))
    params = lm.init_params(RNG, cfg)
    batch = _batch(cfg)
    logits = lm.forward(cfg, params, batch["tokens"],
                        position_ids=batch.get("position_ids"),
                        enc_embeds=batch.get("enc_embeds"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    state = trainer.make_train_state(RNG, cfg)
    # step=500 -> post-warmup lr; warmup lr (3e-6) is below bf16 resolution
    state2, metrics = trainer.train_step(cfg, state, batch,
                                         step=jnp.asarray(500),
                                         peak_lr=3e-2)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) < 2 * np.log(cfg.vocab_size)
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         state["params"], state2["params"])
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_decode_matches_forward_fp32(name):
    cfg = reduced(get_config(name)).replace(dtype="float32")
    params = lm.init_params(RNG, cfg)
    S = 12
    batch = _batch(cfg, S=S)
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("position_ids", "enc_embeds") if k in batch}
    full = lm.forward(cfg, params, toks, **kw)
    half = S // 2
    kw_pre = dict(kw)
    if "position_ids" in kw_pre:
        kw_pre["position_ids"] = kw["position_ids"][:, :, :half]
    lg, caches = lm.prefill(cfg, params, toks[:, :half], cache_len=S, **kw_pre)
    errs = [float(jnp.abs(lg - full[:, half - 1]).max())]
    for t in range(half, S):
        pid = kw["position_ids"][:, :, t:t + 1] if "position_ids" in kw else None
        lg, caches = lm.serve_step(cfg, params, caches, toks[:, t:t + 1], t,
                                   position_ids=pid)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-3, errs


def test_param_counts_match_init():
    for name in ARCH_NAMES:
        cfg = reduced(get_config(name))
        params = lm.init_params(RNG, cfg)
        n_real = sum(x.size for x in jax.tree.leaves(params))
        n_analytic = cfg.param_counts()["total"]
        # analytic count excludes pos tables / tiny norms drift; 15% slack
        assert abs(n_real - n_analytic) / n_real < 0.35, (
            name, n_real, n_analytic)


def test_full_config_param_counts():
    """Analytic totals are in the advertised ballpark for the real configs."""
    expect = {"qwen1.5-110b": 111e9, "grok-1-314b": 314e9,
              "jamba-v0.1-52b": 52e9, "deepseek-v2-lite-16b": 16e9,
              "llama3.2-3b": 3.2e9, "qwen3-4b": 4e9}
    for name, target in expect.items():
        n = get_config(name).param_counts()["total"]
        assert 0.6 * target < n < 1.45 * target, (name, n, target)


def test_cell_support_rules():
    assert not cell_supported(get_config("qwen3-4b"), SHAPES["long_500k"])[0]
    assert cell_supported(get_config("rwkv6-1.6b"), SHAPES["long_500k"])[0]
    assert cell_supported(get_config("jamba-v0.1-52b"), SHAPES["long_500k"])[0]
