"""repro.obs: tracing (span taxonomy, trace-id propagation across
threads and pod processes), the metrics registry (Prometheus text
contract, warn-once fallback visibility), coverage analysis, and the
pod flight recorder.

The spawned test mirrors tests/test_multihost.py's idiom: a module-level
worker referenced as ``"test_obs:<fn>"`` runs inside a real 2-process
``jax.distributed`` pod with ``REPRO_TRACE=1``, so the obs layer is
exercised exactly as ``dryrun --pod-smoke --obs`` runs it.
"""
import json
import logging

import jax
import numpy as np
import pytest

from repro.obs import (TRACER, MetricsRegistry, default_registry,
                       disable_tracing, enable_tracing, merge_chrome_traces,
                       request_coverage, warn_once)
from repro.obs.metrics import note_static_fallback
from repro.serve import FlushPolicy, ServeQueue


@pytest.fixture(autouse=True)
def _tracer_reset():
    """Tracing is process-global state: leave it as these tests found it
    (off, empty rings) so tier-1 neighbors never see stray spans."""
    yield
    TRACER.enabled = False
    TRACER.annotate = False
    TRACER.clear()


def _bundle(tmp, seed=0):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 2), [16], 1)
    return save_model(tmp / "m", net, net.init(jax.random.PRNGKey(seed)))


def _rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, 2)).astype(np.float32)


# ------------------------------------------------------------ tracer unit ---

def test_disabled_tracer_records_nothing():
    disable_tracing()
    TRACER.record("x", 0.0, 1.0)
    TRACER.instant("y")
    with TRACER.span("z"):
        pass
    assert TRACER.events() == [] or all(
        s.name not in ("x", "y", "z") for s in TRACER.events())


def test_span_context_and_record_land_in_ring():
    enable_tracing()
    TRACER.clear()
    with TRACER.span("work", cat="test", trace="t1", args={"k": 1}):
        pass
    TRACER.record("past", 1.0, 2.0, cat="test", trace="t1")
    TRACER.instant("mark", cat="test")
    by_name = {s.name: s for s in TRACER.events()}
    assert by_name["work"].trace == "t1" and by_name["work"].args == {"k": 1}
    assert by_name["work"].dur_s >= 0.0
    assert by_name["past"].dur_s == pytest.approx(1.0)
    assert by_name["mark"].t0 == by_name["mark"].t1  # instant


def test_ring_evicts_oldest_per_thread():
    t = type(TRACER)(ring_size=4)
    t.enable()
    for i in range(10):
        t.record(f"s{i}", 0.0, 1.0)
    names = [s.name for s in t.events()]
    assert names == ["s6", "s7", "s8", "s9"]


def test_trace_ids_are_unique_and_pid_prefixed():
    import os
    ids = {TRACER.new_trace_id() for _ in range(100)}
    assert len(ids) == 100
    assert all(i.startswith(f"{os.getpid():x}.") for i in ids)


def test_chrome_events_format_and_export(tmp_path):
    enable_tracing()
    TRACER.clear()
    with TRACER.span("dur", cat="c", trace="tr.1", args={"a": 2}):
        pass
    TRACER.instant("pt", cat="c")
    evs = TRACER.chrome_events()
    dur = next(e for e in evs if e["name"] == "dur")
    pt = next(e for e in evs if e["name"] == "pt")
    # trace id merges into args; ph X carries dur, instants carry scope
    assert dur["ph"] == "X" and dur["args"] == {"a": 2, "trace": "tr.1"}
    assert "dur" in dur and dur["cat"] == "c"
    assert pt["ph"] == "i" and pt["s"] == "t"
    out = tmp_path / "trace.json"
    TRACER.export_chrome_trace(out)
    doc = json.loads(out.read_text())
    assert {e["name"] for e in doc["traceEvents"]} >= {"dur", "pt"}
    # timestamps are wall-clock microseconds (mergeable across processes)
    import time
    assert abs(dur["ts"] / 1e6 - time.time()) < 60.0


def test_merge_chrome_traces_sorts_by_ts(tmp_path):
    a = [{"name": "b", "ts": 2.0}, {"name": "a", "ts": 1.0}]
    b = [{"name": "c", "ts": 1.5}]
    out = tmp_path / "merged.json"
    merged = merge_chrome_traces([a, b], out)
    assert [e["name"] for e in merged] == ["a", "c", "b"]
    assert json.loads(out.read_text())["traceEvents"] == merged


def test_request_coverage_union_and_gaps():
    def ev(trace, ts, dur):
        return {"name": "s", "ph": "X", "ts": ts, "dur": dur,
                "args": {"trace": trace}}
    events = [
        ev("full", 0.0, 50.0), ev("full", 50.0, 50.0),     # tiles [0,100]
        ev("gappy", 0.0, 25.0), ev("gappy", 75.0, 25.0),   # hole [25,75]
        ev("overlap", 0.0, 80.0), ev("overlap", 40.0, 60.0),
        {"name": "noise", "ph": "i", "ts": 1.0, "args": {"trace": "full"}},
        {"name": "untagged", "ph": "X", "ts": 0.0, "dur": 9.0, "args": {}},
    ]
    cov = request_coverage(events)
    assert set(cov) == {"full", "gappy", "overlap"}
    assert cov["full"]["coverage"] == pytest.approx(1.0)
    assert cov["full"]["spans"] == 2          # the instant does not count
    assert cov["gappy"]["coverage"] == pytest.approx(0.5)
    assert cov["overlap"]["coverage"] == pytest.approx(1.0)
    assert cov["overlap"]["window_us"] == pytest.approx(100.0)


# --------------------------------------------------------------- metrics ----

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", ("k",))
    c.inc(2, k="a")
    c.inc(k="a")
    assert c.value(k="a") == 3.0 and c.value(k="b") == 0.0
    g = reg.gauge("g", "help", ("k",))
    g.set(5, k="x")
    g.inc(-2, k="x")
    assert g.value(k="x") == 3.0
    h = reg.histogram("h_seconds", "help", ("k",), buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v, k="q")
    snap = h.snapshot(k="q")
    assert snap["count"] == 3 and snap["sum"] == pytest.approx(5.55)
    assert snap["buckets"] == {0.1: 1, 1.0: 2}  # cumulative


def test_metric_label_mismatch_raises():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "", ("k",))
    with pytest.raises(ValueError, match="labels"):
        c.inc(1, wrong="a")
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("c_total", "", ("k",))


def test_prometheus_dump_contract():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests served", ("key",)).inc(
        4, key='p"ath\nx')
    h = reg.histogram("lat_seconds", "latency", ("key",), buckets=(0.5,))
    h.observe(0.25, key="a")
    h.observe(2.0, key="a")
    text = reg.dump()
    assert "# HELP req_total requests served" in text
    assert "# TYPE req_total counter" in text
    # label values escape quotes and newlines per the exposition format
    assert 'req_total{key="p\\"ath\\nx"} 4' in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{key="a",le="0.5"} 1' in text
    assert 'lat_seconds_bucket{key="a",le="+Inf"} 2' in text
    assert 'lat_seconds_sum{key="a"} 2.25' in text
    assert 'lat_seconds_count{key="a"} 2' in text


def test_collect_is_json_roundtrippable():
    reg = MetricsRegistry()
    reg.counter("c_total", "h", ("k",)).inc(1, k="v")
    reg.histogram("h_s", "h", (), buckets=(1.0,)).observe(0.5)
    data = json.loads(json.dumps(reg.collect()))
    assert data["c_total"]["type"] == "counter"
    assert data["c_total"]["values"][0] == {"labels": {"k": "v"},
                                            "value": 1.0}
    assert data["h_s"]["values"][0]["count"] == 1


def test_warn_once_logs_once_counts_every(caplog):
    tag = "test-warn-once-unique-tag"
    c = default_registry().counter("repro_obs_warnings_total",
                                   "warn_once firings by tag", ("tag",))
    before = c.value(tag=tag)
    with caplog.at_level(logging.WARNING, logger="repro.obs"):
        warn_once(tag, "the message")
        warn_once(tag, "the message")
    assert c.value(tag=tag) == before + 2
    assert sum("the message" in r.message for r in caplog.records) == 1


# ------------------------------------------- serve-path instrumentation ----

def test_trace_id_rides_submit_to_dispatcher_thread(tmp_path):
    """Satellite contract: the id minted at submit appears in spans from
    the submitter thread (queue.submit) and the dispatcher thread
    (serve.request), and together they tile enqueue->resolve."""
    mp_path = _bundle(tmp_path)
    enable_tracing()
    TRACER.clear()
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30,
                               max_delay_s=0.005)).start()
    try:
        fut = q.submit(mp_path, _rows(3))
        fut.result(30)
    finally:
        q.stop()
    spans = TRACER.events()
    sub = next(s for s in spans if s.name == "queue.submit")
    assert sub.trace is not None
    req = next(s for s in spans if s.name == "serve.request"
               and s.trace == sub.trace)
    # recorded from different threads, same request id
    assert req.thread == "repro-serve-dispatch"
    assert sub.thread != req.thread
    # the engine span rode the same dispatch
    assert any(s.name == "engine.apply" for s in spans)
    cov = request_coverage(TRACER.chrome_events())
    assert cov[sub.trace]["coverage"] >= 0.95


def test_inline_flush_spans_single_thread(tmp_path):
    """Thread-free queues flush inline: both spans come from the
    submitting thread but still share the request's trace id."""
    mp_path = _bundle(tmp_path)
    enable_tracing()
    TRACER.clear()
    q = ServeQueue(FlushPolicy(max_batch_rows=2))  # 3 rows > 2: inline
    q.submit(mp_path, _rows(3)).result(30)
    spans = TRACER.events()
    sub = next(s for s in spans if s.name == "queue.submit")
    req = next(s for s in spans if s.name == "serve.request")
    assert sub.trace == req.trace and sub.thread == req.thread


def test_pod_flush_single_process_traced(tmp_path):
    mp_path = _bundle(tmp_path)
    enable_tracing()
    TRACER.clear()
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    fut = q.submit(mp_path, _rows(4))
    q.pod_flush(mp_path)
    fut.result(30)
    spans = TRACER.events()
    sub = next(s for s in spans if s.name == "queue.submit")
    req = next(s for s in spans if s.name == "serve.request")
    assert sub.trace == req.trace
    agree = next(s for s in spans if s.name == "pod.agree")
    assert agree.cat == "pod"


def test_untraced_requests_have_no_trace_id(tmp_path):
    mp_path = _bundle(tmp_path)
    disable_tracing()
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    fut = q.submit(mp_path, _rows(2))
    q.flush(mp_path)
    fut.result(30)
    assert all(s.name != "queue.submit" for s in TRACER.events())


def test_serve_metrics_published(tmp_path):
    mp_path = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    reg = default_registry()
    rows_done = reg.counter("repro_serve_rows_completed_total",
                            "rows completed", ("key",))
    before = rows_done.value(key=mp_path)
    q.submit(mp_path, _rows(6)).result(30)
    assert rows_done.value(key=mp_path) == before + 6
    assert reg.gauge("repro_serve_queue_depth_rows", "pending rows",
                     ("key",)).value(key=mp_path) == 0
    text = reg.dump()
    for family in ("repro_serve_queue_depth_rows",
                   "repro_serve_batch_occupancy",
                   "repro_serve_batch_latency_seconds_bucket",
                   "repro_serve_request_latency_seconds_bucket"):
        assert family in text


def test_latency_window_knob(tmp_path):
    """Satellite contract: the stats latency window is a ServeQueue
    constructor knob, and snapshot percentiles honor it."""
    mp_path = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30), latency_window=4)
    st = q.stats(mp_path)
    assert st.latency_window == 4 and st._lat.maxlen == 4
    for lat in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        st.on_batch(requests=1, rows=1, bucket=8, reason="t",
                    busy_s=0.0, latencies_s=[lat])
    snap = st.snapshot()
    # only the newest 4 latencies (3..6s) survive the window
    assert snap["latency_p50_ms"] == pytest.approx(4500.0)
    assert ServeQueue(FlushPolicy()).latency_window == 2048  # default


def test_controller_error_degrades_with_warning(tmp_path):
    """Satellite contract: a controller failure serves the static policy
    and surfaces through the metrics layer with the offending key."""
    class BoomController:
        def delay_for(self, key, stats):
            raise RuntimeError("boom")

        def batch_rows_for(self, key, stats):
            raise RuntimeError("boom")

    mp_path = _bundle(tmp_path)
    fallback = default_registry().counter(
        "repro_controller_static_fallback_total",
        "adaptive-controller decisions degraded to the static policy",
        ("key", "reason"))
    before = fallback.value(key=mp_path, reason="controller-error")
    q = ServeQueue(FlushPolicy(max_batch_rows=4),
                   controller=BoomController())
    q.submit(mp_path, _rows(6)).result(30)  # 6 > 4: static trigger fires
    assert fallback.value(key=mp_path,
                          reason="controller-error") > before


def test_snapshot_sorts_outside_lock(tmp_path):
    """Satellite regression guard: snapshot() must not sort the window
    while holding the stats lock (on_batch from the dispatcher must not
    contend with a monitor thread's percentile scan).  Structural check:
    the full window sort happens on a copy, leaving the deque order
    untouched."""
    from repro.serve.stats import ServeStats
    st = ServeStats("k", latency_window=8)
    st.on_batch(requests=1, rows=1, bucket=8, reason="t", busy_s=0.0,
                latencies_s=[3.0, 1.0, 2.0])
    snap = st.snapshot()
    assert snap["latency_p50_ms"] == pytest.approx(2000.0)
    assert list(st._lat) == [3.0, 1.0, 2.0]  # insertion order preserved


# ------------------------------------------------------ kernel provenance ---

def test_resolve_params_info_provenance(monkeypatch):
    from repro.kernels import registry as kreg
    spec = kreg.get_spec("fused_mlp")
    problem = {"widths": (2, 16, 1), "acts": ("relu", "identity"),
               "dtype": "float32", "batch": 64}
    monkeypatch.setattr(kreg, "tuned_params", lambda s, p: {})  # untuned
    params, prov = kreg.resolve_params_info(spec, problem, None)
    assert prov == "default" and params == spec.defaults()
    params, prov = kreg.resolve_params_info(spec, problem,
                                            {"batch_tile": 16})
    assert prov == "explicit" and params["batch_tile"] == 16
    # a tuned winner flips provenance to tuned
    monkeypatch.setattr(kreg, "tuned_params",
                        lambda s, p: {"batch_tile": 32})
    params, prov = kreg.resolve_params_info(spec, problem, None)
    assert prov == "tuned" and params["batch_tile"] == 32


def test_resolve_params_vmem_fallback(monkeypatch):
    from repro.kernels import registry as kreg
    spec = kreg.get_spec("fused_mlp")
    problem = {"widths": (2, 16, 1), "acts": ("relu", "identity"),
               "dtype": "float32", "batch": 64}
    monkeypatch.setattr(spec, "fits", lambda p, params, budget=None: False)
    params, prov = kreg.resolve_params_info(spec, problem,
                                            {"batch_tile": 4096})
    assert prov == "default:vmem-fallback" and params == spec.defaults()


# ------------------------------------------------------- flight recorder ----

def test_local_and_pod_snapshot_single_process():
    from repro.obs import local_snapshot, pod_snapshot
    enable_tracing()
    TRACER.clear()
    TRACER.instant("snap.mark", cat="test")
    local = local_snapshot()
    assert any(e["name"] == "snap.mark" for e in local["events"])
    assert isinstance(local["metrics"], dict) and "pid" in local
    snaps = pod_snapshot()
    assert len(snaps) == 1 and snaps[0]["process"] == local["process"]


def test_allgather_bytes_single_process():
    from repro.launch import multihost
    out = multihost.allgather_bytes(b"payload \x00\xff")
    assert out == [b"payload \x00\xff"]
    assert multihost.allgather_bytes(b"") == [b""]


def test_metrics_report_renders_markdown(tmp_path, capsys):
    from repro.obs import metrics_report
    reg = MetricsRegistry()
    reg.counter("c_total", "a counter", ("k",)).inc(3, k="x")
    reg.histogram("lat_seconds", "latency", ("k",),
                  buckets=(0.1, 1.0)).observe(0.5, k="x")
    mpath = tmp_path / "metrics.json"
    mpath.write_text(json.dumps(reg.collect()))
    enable_tracing()
    TRACER.clear()
    TRACER.record("batch.apply", 0.0, 0.010, cat="batch")
    tpath = tmp_path / "trace.json"
    TRACER.export_chrome_trace(tpath)
    rc = metrics_report.main(["--metrics", str(mpath), "--trace",
                              str(tpath), "--markdown"])
    assert rc in (0, None)
    out = capsys.readouterr().out
    assert "c_total" in out and "lat_seconds" in out
    assert "batch.apply" in out


# --------------------------------------------- exposition edge cases (7) ----

def test_dump_escapes_backslash_quote_newline():
    from repro.obs.server import validate_exposition
    reg = MetricsRegistry()
    raw = 'a\\b"c\nd'
    reg.counter("esc_total", "h", ("k",)).inc(1, k=raw)
    text = reg.dump()
    assert 'esc_total{k="a\\\\b\\"c\\nd"} 1' in text
    # the validator's unescape recovers the original value exactly
    # (backslash first, so \\n is a backslash + n, not a newline)
    samples = [ln for ln in text.splitlines()
               if ln.startswith("esc_total{")]
    assert len(samples) == 1
    validate_exposition(text)


def test_dump_renders_nan_and_infinities():
    import math
    from repro.obs.server import validate_exposition
    reg = MetricsRegistry()
    g = reg.gauge("weird", "h", ("k",))
    g.set(float("nan"), k="n")
    g.set(float("inf"), k="p")
    g.set(float("-inf"), k="m")
    text = reg.dump()
    assert 'weird{k="n"} NaN' in text
    assert 'weird{k="p"} +Inf' in text
    assert 'weird{k="m"} -Inf' in text
    info = validate_exposition(text)
    assert info["samples"] == 3
    # %g would have emitted 'nan'/'inf', which Prometheus rejects
    assert "} nan" not in text and "} inf" not in text


def test_empty_registry_dumps_and_validates():
    from repro.obs.server import validate_exposition
    reg = MetricsRegistry()
    assert validate_exposition(reg.dump()) == {"samples": 0,
                                               "families": {}}
    # registered-but-never-observed families still emit HELP/TYPE only
    reg.counter("quiet_total", "h", ("k",))
    info = validate_exposition(reg.dump())
    assert info == {"samples": 0, "families": {"quiet_total": "counter"}}


def test_histogram_dump_satisfies_exposition_contract():
    from repro.obs.server import validate_exposition
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", "h", ("k",), buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 7.0):
        h.observe(v, k="a")
    h.observe(2.5, k="b")
    text = reg.dump()
    # +Inf bucket == _count per labelset, buckets cumulative
    assert 'lat_s_bucket{k="a",le="+Inf"} 4' in text
    assert 'lat_s_count{k="a"} 4' in text
    assert 'lat_s_bucket{k="b",le="0.1"} 0' in text
    info = validate_exposition(text)
    assert info["families"]["lat_s"] == "histogram"


# ----------------------------------------------- ring drop counter (sat 1) ---

def test_ring_eviction_counts_drops():
    t = type(TRACER)(ring_size=4)
    t.enable()
    for i in range(10):
        t.record(f"s{i}", 0.0, 1.0)
    counts = t.drop_counts()
    assert sum(counts.values()) == 6  # 10 recorded - 4 retained
    t.clear()  # clear keeps the drop totals (they are cumulative)
    assert sum(t.drop_counts().values()) == 6


def test_publish_drop_counts_is_delta_based():
    enable_tracing()
    TRACER.clear()
    c = default_registry().counter(
        "repro_trace_dropped_total",
        "spans evicted from a full per-thread trace ring", ("thread",))
    import threading
    label = threading.current_thread().name
    TRACER.publish_drop_counts()   # flush any prior sessions' deltas
    before = c.value(thread=label)
    overflow = TRACER.ring_size + 5
    for i in range(overflow):
        TRACER.record(f"d{i}", 0.0, 1.0)
    assert TRACER.publish_drop_counts() >= 5
    assert c.value(thread=label) == before + 5
    # publishing again without new evictions adds nothing (delta, not
    # cumulative re-add)
    TRACER.publish_drop_counts()
    assert c.value(thread=label) == before + 5


# ------------------------------------- report quantiles + --json (sat 2) ----

def test_quantile_interpolation_from_buckets():
    from repro.obs.metrics_report import quantile_from_buckets
    # 10 obs uniform in (0,1], 10 in (1,2]: p50 = 1.0, p75 = 1.5
    buckets = {1.0: 10, 2.0: 20, float("inf"): 20}
    assert quantile_from_buckets(buckets, 20, 0.50) == pytest.approx(1.0)
    assert quantile_from_buckets(buckets, 20, 0.75) == pytest.approx(1.5)
    # first bucket interpolates from lower bound 0
    assert quantile_from_buckets(buckets, 20, 0.25) == pytest.approx(0.5)
    # quantile in the +Inf bucket clamps to the largest finite bound
    buckets = {1.0: 10, float("inf"): 40}
    assert quantile_from_buckets(buckets, 40, 0.99) == 1.0
    assert quantile_from_buckets({}, 0, 0.5) is None


def test_metrics_report_json_mode(tmp_path, capsys):
    from repro.obs import metrics_report
    reg = MetricsRegistry()
    reg.counter("c_total", "h", ("k",)).inc(3, k="x")
    h = reg.histogram("lat_seconds", "h", ("k",), buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.7, 0.9):
        h.observe(v, k="x")
    mpath = tmp_path / "metrics.json"
    mpath.write_text(json.dumps(reg.collect()))
    rc = metrics_report.main(["--metrics", str(mpath), "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    snap = doc["snapshots"][0]
    assert snap["metrics"]["c_total"]["values"][0]["value"] == 3.0
    hq = snap["histogram_quantiles"]["lat_seconds"][0]
    assert hq["count"] == 4
    assert 0.0 < hq["p50"] <= hq["p90"] <= hq["p99"] <= 1.0


def test_metrics_report_renders_quality_section(tmp_path, capsys):
    from repro.obs import metrics_report
    reg = MetricsRegistry()
    reg.gauge("repro_quality_rmse", "h", ("key",)).set(0.02, key="b1")
    reg.gauge("repro_quality_alert_state", "h", ("key",)).set(2, key="b1")
    reg.counter("repro_quality_samples_total", "h",
                ("key", "region")).inc(7, key="b1", region="r")
    mpath = tmp_path / "metrics.json"
    mpath.write_text(json.dumps(reg.collect()))
    metrics_report.main(["--metrics", str(mpath), "--markdown"])
    out = capsys.readouterr().out
    assert "Surrogate quality (shadow-scored)" in out
    assert "| b1 | 0.02 |" in out and "CRITICAL" in out


# ------------------------------------------------- spawned 2-process pod ----

def _traced_pod_worker():
    """Runs inside a spawned pod process with REPRO_TRACE=1: submit,
    collective pod_flush, then report this host's spans + snapshot."""
    import numpy as np

    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_pod_mesh
    from repro.obs import TRACER, pod_snapshot
    from repro.serve import FlushPolicy, ServeQueue

    import jax
    pid = jax.process_index()
    import pathlib
    import tempfile
    tmp = pathlib.Path(tempfile.mkdtemp(prefix=f"obs_pod_{pid}_"))
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 2), [16], 1)
    # every host loads identical weights (seed 0): one shared bundle key
    mp_path = save_model(tmp / "m", net, net.init(jax.random.PRNGKey(0)))

    q = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))
    x = np.full((3, 2), float(pid), np.float32)
    with use_mesh(make_pod_mesh(), multi_pod=True):
        fut = q.submit(mp_path, x)
        q.pod_flush(mp_path)
        fut.result(60)
    spans = [{"name": s.name, "trace": s.trace, "cat": s.cat}
             for s in TRACER.events()]
    snaps = pod_snapshot()  # collective: every host must reach this
    return {"pid": pid, "enabled": TRACER.enabled, "spans": spans,
            "snap_processes": sorted(s["process"] for s in snaps),
            "snap_events": sum(len(s["events"]) for s in snaps)}


@pytest.mark.slow
def test_pod_flush_trace_ids_across_two_processes():
    """Satellite contract, collective leg: each host's request id rides
    its pod_flush dispatch, and pod_snapshot all-gathers both hosts'
    rings (REPRO_TRACE=1 injected by the harness, as dryrun --obs
    does)."""
    from repro.launch import multihost
    res = multihost.spawn_local_pod(
        2, "test_obs:_traced_pod_worker", devices_per_host=2,
        timeout_s=300.0, extra_env={"REPRO_TRACE": "1"})
    assert [r["pid"] for r in res] == [0, 1]
    for r in res:
        assert r["enabled"]
        sub = next(s for s in r["spans"] if s["name"] == "queue.submit")
        req = next(s for s in r["spans"] if s["name"] == "serve.request")
        assert sub["trace"] is not None and sub["trace"] == req["trace"]
        assert any(s["name"] == "pod.agree" for s in r["spans"])
        # the flight recorder gathered both hosts' rings on every host
        assert r["snap_processes"] == [0, 1]
        assert r["snap_events"] > 0
    # ids minted on different processes never collide in a merged trace
    t0 = next(s["trace"] for s in res[0]["spans"]
              if s["name"] == "queue.submit")
    t1 = next(s["trace"] for s in res[1]["spans"]
              if s["name"] == "queue.submit")
    assert t0 != t1
