import os
import sys
import pathlib

# tests run on the single real CPU device (the 512-device forcing is
# exclusively dryrun.py's); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
if str(ROOT / "tests") not in sys.path:
    sys.path.insert(0, str(ROOT / "tests"))

def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: spawns real multi-process jax pods (the multihost CI lane "
        "runs these; deselect with -m 'not slow' for quick iteration)")


try:  # offline image has no hypothesis wheel; shim keeps the suite runnable
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_shim
    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies
