import os
import sys
import pathlib

# tests run on the single real CPU device (the 512-device forcing is
# exclusively dryrun.py's); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
