"""Tensor functor DSL + memory concretization: unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TensorMap, sym, tensor_functor
from repro.core.functor import SSlice, SymExpr


def test_parse_paper_example():
    f = tensor_functor("ifnctr: [i, j, 0:5] = ([i-1,j],[i+1,j],[i,j-1:j+2])")
    assert f.name == "ifnctr"
    assert f.sweep_symbols == ("i", "j")
    assert f.n_features == 5


def test_symexpr_arithmetic():
    i = sym("i")
    e = 2 * i + 3 - i
    assert e.evaluate({"i": 10}) == 13
    assert (i - 1).evaluate({"i": 5}) == 4


def test_slice_extent_must_be_constant():
    i, j = sym("i"), sym("j")
    s = SSlice(i, i + 4)
    assert s.n_elements() == 4
    with pytest.raises(ValueError):
        SSlice(i, j).n_elements()


def test_paper_stencil_gather_matches_numpy():
    f = tensor_functor("s: [i, j, 0:5] = ([i-1,j],[i+1,j],[i,j-1:j+2])")
    N, M = 7, 9
    t = np.arange(N * M, dtype=np.float32).reshape(N, M)
    X = np.asarray(TensorMap(f, jnp.asarray(t),
                             {"i": (1, N - 1), "j": (1, M - 1)}).to_tensor())
    for i in range(1, N - 1):
        for j in range(1, M - 1):
            exp = [t[i - 1, j], t[i + 1, j], t[i, j - 1], t[i, j], t[i, j + 1]]
            np.testing.assert_allclose(X[i - 1, j - 1], exp)


@settings(max_examples=25, deadline=None)
@given(
    dy=st.integers(-2, 2), dx=st.integers(-2, 2),
    w=st.integers(1, 3),
    n=st.integers(8, 14), m=st.integers(8, 14),
)
def test_functor_gather_property(dy, dx, w, n, m):
    """Random offset + window functor == naive numpy gather."""
    i, j = sym("i"), sym("j")
    f = tensor_functor(name="g", lhs=[i, j, slice(0, w + 1)],
                       rhs=[[i + dy, j + dx], [i, SSlice(j, j + w)]])
    t = np.random.default_rng(0).normal(size=(n, m)).astype(np.float32)
    lo_i, hi_i = 2, n - 3
    lo_j, hi_j = 2, m - 4
    X = np.asarray(TensorMap(f, jnp.asarray(t),
                             {"i": (lo_i, hi_i), "j": (lo_j, hi_j)}).to_tensor())
    assert X.shape == (hi_i - lo_i, hi_j - lo_j, w + 1)
    for ii in range(hi_i - lo_i):
        for jj in range(hi_j - lo_j):
            ai, aj = lo_i + ii, lo_j + jj
            exp = [t[ai + dy, aj + dx]] + [t[ai, aj + e] for e in range(w)]
            np.testing.assert_allclose(X[ii, jj], exp)


def test_from_tensor_roundtrip():
    f = tensor_functor("p: [i, j] = ([i,j])")
    N = 8
    t = jnp.zeros((N, N))
    tm = TensorMap(f, t, {"i": (1, N - 1), "j": (1, N - 1)}, "from")
    y = jnp.arange(36.0).reshape(6, 6)
    t2 = tm.from_tensor(y)
    np.testing.assert_allclose(np.asarray(t2[1:-1, 1:-1]), np.asarray(y))
    assert float(t2[0].sum()) == 0.0


def test_gather_scatter_inverse():
    """to_tensor then from_tensor restores the covered region."""
    f = tensor_functor("p: [i, j] = ([i,j])")
    N = 10
    t = jnp.asarray(np.random.default_rng(1).normal(size=(N, N)).astype(np.float32))
    rngs = {"i": (2, N - 2), "j": (3, N - 1)}
    X = TensorMap(f, t, rngs).to_tensor()
    t2 = TensorMap(f, jnp.zeros_like(t), rngs, "from").from_tensor(X)
    np.testing.assert_allclose(np.asarray(t2[2:N-2, 3:N-1]),
                               np.asarray(t[2:N-2, 3:N-1]))


def test_strided_range():
    f = tensor_functor("s: [i] = ([2*i])")
    t = jnp.arange(20.0)
    X = TensorMap(f, t, {"i": (0, 8)}).to_tensor()
    np.testing.assert_allclose(np.asarray(X), np.arange(0, 16, 2))


def test_min_array_shape():
    f = tensor_functor("s: [i, j, 0:5] = ([i-1,j],[i+1,j],[i,j-1:j+2])")
    tm = TensorMap(f, None, {"i": (1, 5), "j": (1, 6)}, "from")
    assert tm.min_array_shape() == (6, 7)
