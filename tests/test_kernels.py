"""Per-kernel interpret-mode validation against the pure-jnp oracles,
with shape/dtype sweeps (hypothesis drives the stencil/flash cases)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fused_mlp.fused_mlp import fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref
from repro.kernels.rwkv6_chunk.ref import rwkv6_chunk_ref
from repro.kernels.rwkv6_chunk.rwkv6_chunk import rwkv6_chunk
from repro.kernels.stencil_gather.ref import stencil_gather_ref
from repro.kernels.stencil_gather.stencil_gather import stencil_gather


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(12, 40), w=st.integers(12, 40),
    seed=st.integers(0, 100),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_stencil_gather_sweep(h, w, seed, dtype):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32)).astype(dtype)
    offs = ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2))
    oh, ow = h - 3, w - 3
    a = stencil_gather(x, offs, oh, ow, origin=(1, 1), block_h=8, block_w=16)
    b = stencil_gather_ref(x, offs, oh, ow, origin=(1, 1))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("widths,acts", [
    ((8, 32, 1), ("relu", "identity")),
    ((6, 64, 16, 4), ("gelu", "tanh", "identity")),
    ((5, 128, 2), ("silu", "identity")),
])
@pytest.mark.parametrize("batch", [16, 37, 130])
def test_fused_mlp_sweep(widths, acts, batch):
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(a, b)).astype(np.float32) * 0.3)
          for a, b in zip(widths[:-1], widths[1:])]
    bs = [jnp.asarray(rng.normal(size=(b,)).astype(np.float32) * 0.1)
          for b in widths[1:]]
    x = jnp.asarray(rng.normal(size=(batch, widths[0])).astype(np.float32))
    a = fused_mlp(x, ws, bs, acts, batch_tile=32)
    b = fused_mlp_ref(x, ws, bs, acts)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    sq=st.sampled_from([32, 64, 96]),
    kv_heads=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    causal=st.booleans(),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_flash_attention_sweep(b, sq, kv_heads, group, causal, dtype):
    rng = np.random.default_rng(1)
    H = kv_heads * group
    q = jnp.asarray(rng.normal(size=(b, sq, H, 16)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, sq, kv_heads, 16)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, sq, kv_heads, 16)).astype(np.float32)).astype(dtype)
    a = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    r = flash_attention_ref(q, k, v, causal=causal)
    tol = 5e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(r, np.float32), rtol=tol, atol=tol)


def test_flash_attention_kv_valid_len():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 1, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    a = flash_attention(q, k, v, causal=False, kv_valid_len=40, block_q=8,
                        block_k=16)
    r = flash_attention_ref(q[:, :, :, :], k[:, :40], v[:, :40], causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("T", [8, 33, 64])
@pytest.mark.parametrize("hd", [8, 16])
def test_rwkv6_chunk_sweep(T, hd):
    rng = np.random.default_rng(3)
    B, H = 2, 2
    r, k, v = (jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.7, 0.999, (B, T, H, hd)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)).astype(np.float32)) * 0.1
    oa, sa = rwkv6_chunk(r, k, v, w, u, s0)
    ob, sb = rwkv6_chunk_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(oa), np.asarray(ob), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=1e-5,
                               atol=1e-5)


def test_rwkv6_chunk_matches_block_chunked_path():
    """Kernel oracle == the model's associative-scan chunked formulation."""
    from repro.configs.archs import reduced
    from repro.configs.base import get_config
    from repro.models import blocks

    cfg = reduced(get_config("rwkv6-1.6b"))
    p = blocks.rwkv6_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32).astype(cfg.jdtype)
    y1, st1 = blocks.rwkv6_seq(cfg, p, x, chunk=8)
    y2, st2 = blocks.rwkv6_seq(cfg, p, x, chunk=32)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=2e-2,
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(st1["S"]), np.asarray(st2["S"]),
                               rtol=2e-2, atol=2e-2)


# ------------------------------------------------ fused_mlp under GSPMD ----
def test_fused_mlp_sharded_falls_back_on_single_shard():
    """1-device mesh: the wrapper must route to the plain op (no shard_map)."""
    from repro.kernels.fused_mlp.ops import fused_mlp_sharded
    from repro.launch.mesh import make_local_mesh
    rng = np.random.default_rng(4)
    ws = [jnp.asarray(rng.normal(size=(6, 32)).astype(np.float32) * 0.3),
          jnp.asarray(rng.normal(size=(32, 2)).astype(np.float32) * 0.3)]
    bs = [jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 0.1),
          jnp.asarray(rng.normal(size=(2,)).astype(np.float32) * 0.1)]
    x = jnp.asarray(rng.normal(size=(16, 6)).astype(np.float32))
    mesh = make_local_mesh()
    out = fused_mlp_sharded(x, ws, bs, ("relu", "identity"),
                            mesh=mesh, data_axes=("data",))
    ref = fused_mlp_ref(x, ws, bs, ("relu", "identity"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_mlp_sharded_parity_8_shards():
    """Parity vs the unsharded kernel ref on a real 8-way data mesh.

    Subprocess: the 8 host devices must be forced before jax initializes
    (same pattern as tests/test_dist.py).
    """
    import pathlib
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.kernels.fused_mlp.ops import fused_mlp_sharded
from repro.kernels.fused_mlp.ref import fused_mlp_ref

mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
ws = [jnp.asarray(rng.normal(size=(a, b)).astype(np.float32) * 0.3)
      for a, b in ((6, 64), (64, 16), (16, 3))]
bs = [jnp.asarray(rng.normal(size=(b,)).astype(np.float32) * 0.1)
      for b in (64, 16, 3)]
acts = ("gelu", "relu", "identity")
x = jnp.asarray(rng.normal(size=(64, 6)).astype(np.float32))
# eager shard_map path
out = fused_mlp_sharded(x, ws, bs, acts, mesh=mesh, data_axes=("data",))
ref = fused_mlp_ref(x, ws, bs, acts)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
# jitted (the engine's serving path traces it under jit)
jout = jax.jit(lambda x: fused_mlp_sharded(
    x, ws, bs, acts, mesh=mesh, data_axes=("data",)))(x)
np.testing.assert_allclose(np.asarray(jout), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
# non-divisible batch falls back to the unsharded op, still correct
xo = jnp.asarray(rng.normal(size=(13, 6)).astype(np.float32))
oo = fused_mlp_sharded(xo, ws, bs, acts, mesh=mesh, data_axes=("data",))
np.testing.assert_allclose(np.asarray(oo),
                           np.asarray(fused_mlp_ref(xo, ws, bs, acts)),
                           rtol=2e-5, atol=2e-5)
# Pallas interpret kernel per shard (the TPU VMEM path's CPU oracle)
kout = fused_mlp_sharded(x, ws, bs, acts, mesh=mesh, data_axes=("data",),
                         force_kernel=True)
np.testing.assert_allclose(np.asarray(kout), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("SHARDED_MLP_OK")
"""
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=str(root))
    assert "SHARDED_MLP_OK" in out.stdout, out.stderr[-2000:]
