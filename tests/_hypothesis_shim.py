"""Deterministic stand-in for `hypothesis` when it isn't installed.

The offline image has no hypothesis wheel; conftest.py installs this shim
into sys.modules only in that case, so environments with the real package
keep true shrinking/property testing.  The shim draws `max_examples`
samples from a per-test seeded generator — same API subset the tests use
(`given`, `settings`, `strategies.integers/sampled_from/booleans/floats`),
fully deterministic across runs.
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.floats = floats


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 20)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)
        wrapper._max_examples = 20
        # hide the drawn parameters from pytest's fixture resolution
        # (real hypothesis exposes a zero-arg signature the same way)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
