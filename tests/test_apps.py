"""The 5 paper benchmarks: accurate paths + full surrogate round trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (ALL_APPS, binomial, bonds, minibude, miniweather,
                        particlefilter)
from repro.nas.nested import best_trial, nested_search, save_trial


def test_minibude_accurate():
    e = minibude.energies(minibude.make_inputs(64))
    assert e.shape == (64,) and bool(jnp.isfinite(e).all())
    # pose perturbation changes energy (it's a real forcefield, not const)
    p = minibude.make_inputs(2)
    assert abs(float(e[0] - e[1])) >= 0


def test_binomial_put_price_bounds():
    opts = binomial.make_inputs(64)
    pr = binomial.prices(opts)
    K = opts[:, 1]
    assert bool((pr >= -1e-4).all())
    assert bool((pr <= K + 1e-4).all())  # american put <= strike
    # deep ITM put is worth ~ K - S
    deep = jnp.asarray([[1.0, 90.0, 1.0, 0.02, 0.1]])
    assert float(binomial.prices(deep)[0]) > 80.0


def test_bonds_sanity():
    b = bonds.make_inputs(64)
    v = bonds.valuations(b)
    assert bool(jnp.isfinite(v).all())
    # zero accrual fraction -> zero accrued interest
    z = jnp.asarray([[0.05, 0.05, 10.0, 0.0]])
    assert abs(float(bonds.valuations(z)[0, 0])) < 1e-6


def test_miniweather_stable():
    s = miniweather.init_state()
    s2 = miniweather.run(s, 50)
    assert bool(jnp.isfinite(s2).all())
    assert float(jnp.abs(s2 - s).max()) > 1e-4  # it evolves


def test_particlefilter_tracks():
    frames, truth = particlefilter.make_video(60, seed=3)
    est = particlefilter.track(frames)
    rmse = particlefilter.qoi_error(truth, est)
    assert rmse < 3.0, rmse  # paper's algorithmic baseline quality ballpark


@pytest.mark.slow
def test_surrogate_round_trip_binomial(tmp_path):
    """collect -> nested BO -> deploy -> error within sane bounds."""
    n = 1024
    opts = binomial.make_inputs(n, seed=1)
    region = binomial.make_region(n, mode="collect",
                                  database=str(tmp_path / "db"))
    region(opts=opts)
    region.db.flush()
    res = nested_search(binomial, region.db.group("binomial"),
                        outer_iters=4, inner_iters=0, epochs=12,
                        verbose=False)
    bt = best_trial(res)
    mp = save_trial(bt, tmp_path / "model")
    test_opts = binomial.make_inputs(256, seed=2)
    r2 = binomial.make_region(256, mode="infer", model=str(mp))
    y = r2(opts=test_opts)["out"]
    ref = binomial.accurate(test_opts)["out"]
    assert binomial.qoi_error(ref, y) < 8.0  # prices span [0, 100]


def test_miniweather_interleave_reduces_error(tmp_path):
    """Observation 4: interleaving accurate steps cuts propagated error."""
    from repro.nas.train_surrogate import fit
    from repro.nn.serialize import save_model
    from repro.nas.space import build_net

    mw = miniweather
    region = mw.make_region(mode="collect", database=str(tmp_path / "db"))
    s = mw.init_state()
    for _ in range(60):
        s = region(state=s)["state"]
    region.db.flush()
    d = region.db.group("miniweather").load()
    X = d["inputs"].reshape(d["inputs"].shape[0], -1)
    Y = d["outputs"].reshape(d["outputs"].shape[0], -1)
    net = build_net(mw.surrogate_space(), {"k1": 3, "ch1": 8, "k2": 0})
    params, rmse, stats = fit(net, X, Y, epochs=25,
                              x_reshape=(30, 30, 20))
    mp = save_model(tmp_path / "m", net, params, extra=stats)
    region2 = mw.make_region(mode="predicated", model=str(mp))
    s0 = mw.init_state()
    ref = mw.run(s0, 16)
    err_all = mw.qoi_error(ref, mw.run(s0, 16, region2, interleave=(0, 1)))
    err_mix = mw.qoi_error(ref, mw.run(s0, 16, region2, interleave=(1, 1)))
    assert err_mix < err_all + 1e-9, (err_mix, err_all)
