"""repro.tune: VMEM accounting, tune cache, kernel tuner, adaptive flush
controller, and the hot-path hardening it rides on (donated applies,
pooled scratch buffers, engine context normalization)."""
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import InferenceEngine
from repro.kernels.fused_mlp.fused_mlp import fits_vmem
from repro.nn import MLP
from repro.nn.serialize import save_model
from repro.serve import FlushPolicy, ScratchPool, ServeQueue
from repro.serve.stats import ServeStats
from repro.tune import (AdaptiveFlushController, TuneCache, autotune,
                        candidate_tiles, predict_batch_latency_s,
                        serve_buckets, sweep_fused_mlp, widths_from_spec)
from repro.tune.cache import best_tile, shape_key


def _rows(n, seed=0, feat=2):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=(n, feat)).astype(np.float32))


def _bundle(tmp, name="m", hidden=16, feat=2):
    net = MLP((1, feat), [hidden], 1)
    return save_model(tmp / name, net, net.init(jax.random.PRNGKey(0)))


# ---------------------------------------------------------- fits_vmem ------
def test_fits_vmem_counts_bias_and_tile_padding():
    widths = (8, 128)
    # exact accounting for f32: weights 8x128, bias one (8,128) tile,
    # in/out activation tiles double-buffered at max width 128
    exact = (8 * 128 + 8 * 128 + 2 * 2 * 128 * 128) * 4
    assert fits_vmem(widths, 128, budget=exact)
    assert not fits_vmem(widths, 128, budget=exact - 1)
    # the old accounting (no bias, no padding, single-buffered) said
    # ~135KB; a budget between the two must now be rejected — accepting
    # it is exactly the near-budget overflow the tuner cannot survive
    assert not fits_vmem(widths, 128, budget=200_000)


def test_fits_vmem_pads_ragged_weight_rows():
    # [129, 5] occupies a (136, 128) f32 tile in VMEM, not 129x5
    padded_w = 136 * 128 * 4
    bias = 8 * 128 * 4
    acts = 2 * 2 * 8 * 256 * 4  # tile 8, max width padded 129 -> 256
    exact = padded_w + bias + acts
    assert fits_vmem((129, 5), 8, budget=exact)
    assert not fits_vmem((129, 5), 8, budget=exact - 1)


def test_fits_vmem_batch_tile_scales_activations():
    widths = (64, 64)
    assert fits_vmem(widths, 8, budget=2 ** 20)
    # activation tiles grow with the batch tile and must hit the budget
    assert not fits_vmem(widths, 4096, budget=2 ** 20)


# --------------------------------------------------------- tune cache ------
def test_tune_cache_roundtrip_and_persistence(tmp_path):
    c = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    rec = {"batch_tile": 64, "us": 10.0, "exact": True}
    c.store([5, 16, 1], jnp.float32, "cpu", 256, rec)
    assert c.lookup([5, 16, 1], jnp.float32, "cpu", 256)["batch_tile"] == 64
    assert c.lookup([5, 16, 1], jnp.float32, "cpu", 512) is None
    # a fresh instance reads the same file: persistence across processes
    c2 = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    assert c2.lookup([5, 16, 1], jnp.float32, "cpu", 256)["us"] == 10.0


def test_tune_cache_corrupt_file_is_a_miss(tmp_path):
    p = tmp_path / "fused_mlp.json"
    p.write_text("{not json")
    c = TuneCache("fused_mlp", p)
    assert c.lookup([1, 2], jnp.float32, "cpu", 8) is None
    c.store([1, 2], jnp.float32, "cpu", 8, {"batch_tile": 8, "exact": True})
    assert c.lookup([1, 2], jnp.float32, "cpu", 8)["batch_tile"] == 8


def test_tune_cache_reloads_on_external_write(tmp_path):
    p = tmp_path / "fused_mlp.json"
    c1 = TuneCache("fused_mlp", p)
    c2 = TuneCache("fused_mlp", p)
    c1.store([3, 4], jnp.float32, "cpu", 8, {"batch_tile": 4, "exact": True})
    # c2 sees c1's write via the mtime fingerprint, no restart needed
    assert c2.lookup([3, 4], jnp.float32, "cpu", 8)["batch_tile"] == 4


def test_best_tile_refuses_unvalidated_entries(tmp_path, monkeypatch):
    import repro.tune.cache as cache_mod
    c = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    monkeypatch.setattr(cache_mod, "_default", {"fused_mlp": c})
    widths = [5, 16, 1]
    assert best_tile(widths, jnp.float32, "cpu", 256) is None  # untuned
    c.store(widths, jnp.float32, "cpu", 256,
            {"batch_tile": 64, "exact": False})
    assert best_tile(widths, jnp.float32, "cpu", 256) is None  # not exact
    c.store(widths, jnp.float32, "cpu", 256,
            {"batch_tile": 64, "exact": True})
    assert best_tile(widths, jnp.float32, "cpu", 256) == 64
    # eager batch sizes bucket to the serve shape: 200 -> bucket 256
    assert best_tile(widths, jnp.float32, "cpu", 200) == 64


def test_tune_cache_migrates_legacy_flat_file(tmp_path):
    """A schema-1 cache (flat {key: record}, bare batch_tile) must lift
    into the namespaced schema-2 layout on first load — atomically, so
    deployed caches and the CI actions/cache entry survive the registry
    refactor — and keep serving its entries."""
    import json
    p = tmp_path / "fused_mlp.json"
    key = shape_key([5, 16, 1], jnp.float32, "cpu", 256)
    p.write_text(json.dumps({key: {"batch_tile": 64, "us": 10.0,
                                   "exact": True}}))
    c = TuneCache("fused_mlp", p)
    rec = c.lookup([5, 16, 1], jnp.float32, "cpu", 256)
    assert rec["batch_tile"] == 64
    assert rec["params"] == {"batch_tile": 64}  # record migrated
    # ... and the winner reaches the dispatch path
    import repro.tune.cache as cache_mod
    data = json.loads(p.read_text())
    assert data["schema"] == cache_mod.SCHEMA  # file rewritten
    assert data["kernel"] == "fused_mlp"
    assert data["entries"][key]["params"] == {"batch_tile": 64}
    # a fresh instance reads the migrated layout directly
    c2 = TuneCache("fused_mlp", p)
    assert c2.lookup([5, 16, 1], jnp.float32, "cpu", 256)["us"] == 10.0


def test_best_params_namespaced_per_kernel(tmp_path, monkeypatch):
    from repro.tune import best_params
    import repro.tune.cache as cache_mod
    fa = TuneCache("flash_attention", tmp_path / "flash_attention.json")
    fa.put("k1", {"params": {"block_q": 32, "block_kv": 64},
                  "exact": True})
    fa.put("k2", {"params": {"block_q": 16, "block_kv": 16},
                  "exact": False})
    monkeypatch.setattr(cache_mod, "_default", {"flash_attention": fa})
    assert best_params("flash_attention", ["k1"]) == {"block_q": 32,
                                                      "block_kv": 64}
    assert best_params("flash_attention", ["k2"]) is None  # unvalidated
    assert best_params("flash_attention", ["k2", "k1"]) == \
        {"block_q": 32, "block_kv": 64}  # ordered fallback chain


def test_shape_key_stable():
    assert shape_key([5, 16, 1], jnp.float32, "cpu", 256) == \
        shape_key((5, 16, 1), jnp.float32, "cpu", 256)


def test_shape_key_normalizes_dtype_spellings():
    """The tuner stores jnp.float32 (a type); the serving path looks up
    x.dtype (a np.dtype) — one cache key, or the cache never hits."""
    x = jnp.zeros((1,), jnp.float32)
    keys = {shape_key([5, 16, 1], d, "cpu", 64)
            for d in (jnp.float32, np.float32, x.dtype, "float32")}
    assert len(keys) == 1
    assert "class" not in next(iter(keys))


def test_best_tile_exact_batch_before_pow2_bucket(tmp_path, monkeypatch):
    """Shard-rounded dispatch buckets (e.g. 12 on a 6-shard mesh) are
    not powers of two; the exact batch must hit before re-bucketing."""
    import repro.tune.cache as cache_mod
    c = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    monkeypatch.setattr(cache_mod, "_default", {"fused_mlp": c})
    widths = [5, 16, 1]
    c.store(widths, jnp.float32, "cpu", 12, {"batch_tile": 4, "exact": True})
    c.store(widths, jnp.float32, "cpu", 16, {"batch_tile": 8, "exact": True})
    assert best_tile(widths, jnp.float32, "cpu", 12) == 4   # exact bucket
    assert best_tile(widths, jnp.float32, "cpu", 13) == 8   # pow2 fallback


def test_sweep_to_serving_path_end_to_end(tmp_path, monkeypatch):
    """No stubs between store and lookup: a swept record must be what
    fused_mlp_op actually applies (guards key-spelling regressions)."""
    import repro.kernels.fused_mlp.ops as ops_mod
    import repro.tune.cache as cache_mod
    c = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    monkeypatch.setattr(cache_mod, "_default", {"fused_mlp": c})
    rec = sweep_fused_mlp([4, 16, 2], 32, cache=c, reps=1, warmup=0)
    seen = {}
    orig = ops_mod.fused_mlp

    def spy(x, ws, bs, acts, *, batch_tile, interpret):
        seen["tile"] = batch_tile
        return orig(x, ws, bs, acts, batch_tile=batch_tile,
                    interpret=interpret)

    monkeypatch.setattr(ops_mod, "fused_mlp", spy)
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32))]
    bs = [jnp.asarray(rng.normal(size=(16,)).astype(np.float32)),
          jnp.asarray(rng.normal(size=(2,)).astype(np.float32))]
    x = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    ops_mod.fused_mlp_op(x, ws, bs, ("relu", "identity"), force_kernel=True)
    assert seen["tile"] == rec["batch_tile"]


def test_autotune_warms_per_shard_batches(tmp_path):
    mp = _bundle(tmp_path, "shardtune", hidden=8, feat=3)
    c = TuneCache("fused_mlp", tmp_path / "cache.json")
    autotune(mp, buckets=[16], n_shards=4, cache=c, reps=1, warmup=0)
    backend = jax.default_backend()
    # both the global dispatch bucket and the per-shard local batch the
    # shard_map body will trace with are warmed
    assert c.lookup([3, 8, 1], jnp.float32, backend, 16) is not None
    assert c.lookup([3, 8, 1], jnp.float32, backend, 4) is not None


# ------------------------------------------------------- kernel tuner ------
def test_candidate_tiles_vmem_filtered_and_bucket_clipped():
    cands = candidate_tiles([4, 16, 2], 64)
    assert cands[0] == 128  # default always swept (kernel pads B up)
    assert all(t <= 64 for t in cands[1:])
    assert 64 in cands
    # a huge net rejects fat tiles but keeps thin ones
    wide = [2048, 2048, 2048]
    thin = candidate_tiles(wide, 512, extra=(8,))
    assert all(fits_vmem(wide, t) for t in thin)


def test_sweep_fused_mlp_picks_exact_winner(tmp_path):
    c = TuneCache("fused_mlp", tmp_path / "fused_mlp.json")
    rec = sweep_fused_mlp([4, 16, 2], 32, cache=c, reps=1, warmup=0)
    assert rec["exact"] is True
    tiles = [s["params"]["batch_tile"] for s in rec["swept"]]
    assert 128 in tiles  # the default is always in the comparison set
    valid_us = [s["us"] for s in rec["swept"] if s["exact"]]
    assert rec["us"] == min(valid_us)
    assert rec["us"] <= rec["default_us"]      # winner is the argmin,
    assert rec["speedup_x"] >= 1.0             # so this is structural
    # second call is a cache hit: identical record, no re-measure
    again = sweep_fused_mlp([4, 16, 2], 32, cache=c, reps=1, warmup=0)
    assert again == rec


def test_autotune_from_bundle_path(tmp_path):
    mp = _bundle(tmp_path, "tuneme", hidden=8, feat=3)
    c = TuneCache("fused_mlp", tmp_path / "cache.json")
    recs = autotune(mp, buckets=[8], cache=c, reps=1, warmup=0)
    assert len(recs) == 1 and recs[0]["exact"]
    assert c.lookup([3, 8, 1], jnp.float32,
                    jax.default_backend(), 8) is not None


def test_autotune_rejects_non_mlp_bundle(tmp_path):
    from repro.nn.layers import Activation, Conv2D, Sequential
    net = Sequential([Conv2D(4, 3), Activation("relu")], (1, 8, 8, 2))
    mp = save_model(tmp_path / "conv", net, net.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="not a pure MLP"):
        autotune(mp, buckets=[8])


def test_widths_from_spec():
    spec = {"in_shape": [1, 5],
            "layers": [{"kind": "dense", "features": 16},
                       {"kind": "act", "name": "relu"},
                       {"kind": "dense", "features": 1}]}
    assert widths_from_spec(spec) == [5, 16, 1]
    # flatten folds trailing dims into the feature width
    spec_f = {"in_shape": [1, 4, 3],
              "layers": [{"kind": "flatten"},
                         {"kind": "dense", "features": 2}]}
    assert widths_from_spec(spec_f) == [12, 2]
    assert widths_from_spec(
        {"in_shape": [1, 8, 8, 2],
         "layers": [{"kind": "conv2d", "features": 4}]}) is None


def test_fused_mlp_op_consults_tune_cache(monkeypatch):
    import repro.kernels.fused_mlp.ops as ops_mod
    import repro.tune.cache as cache_mod
    seen = {}
    orig = ops_mod.fused_mlp

    def spy(x, ws, bs, acts, *, batch_tile, interpret):
        seen["tile"] = batch_tile
        return orig(x, ws, bs, acts, batch_tile=batch_tile,
                    interpret=interpret)

    monkeypatch.setattr(ops_mod, "fused_mlp", spy)
    monkeypatch.setattr(cache_mod, "best_params",
                        lambda kernel, keys: {"batch_tile": 32})
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))]
    bs = [jnp.asarray(rng.normal(size=(16,)).astype(np.float32))]
    x = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    ops_mod.fused_mlp_op(x, ws, bs, ("identity",), force_kernel=True)
    assert seen["tile"] == 32  # tuned tile, not the hardcoded default
    # a cached tile that no longer fits VMEM falls back to the default
    monkeypatch.setattr(cache_mod, "best_params",
                        lambda kernel, keys: {"batch_tile": 1 << 20})
    ops_mod.fused_mlp_op(x, ws, bs, ("identity",), force_kernel=True)
    assert seen["tile"] == 128


def test_serve_buckets_cover_policy_range():
    assert serve_buckets(8, 1024) == [8, 16, 32, 64, 128, 256, 512, 1024]
    # shard floor raises the smallest bucket and keeps divisibility
    bs = serve_buckets(8, 100, n_shards=6)
    assert bs[0] == 12 and all(b % 6 == 0 for b in bs)


# ------------------------------------------------- generic kernel sweep ----
def test_sweep_stencil_gather_bit_exact_winner(tmp_path):
    from repro.tune import sweep
    c = TuneCache("stencil_gather", tmp_path / "stencil_gather.json")
    problem = {"h": 40, "w": 40, "out_h": 36, "out_w": 36,
               "offsets": ((0, 1), (1, 0), (0, 0)), "origin": (1, 1),
               "dtype": "float32"}
    rec = sweep("stencil_gather", problem, cache=c, reps=1, warmup=0)
    assert rec["exact"] is True
    assert {"block_h", "block_w"} <= set(rec["params"])
    # the spec default is always the baseline, so this is structural
    assert rec["speedup_x"] >= 1.0
    # cached: a second sweep returns the stored record unmeasured
    assert sweep("stencil_gather", problem, cache=c, reps=1,
                 warmup=0) == rec


def test_sweep_flash_attention_validates_to_spec_tolerance(tmp_path):
    """Flash attention declares a tolerance (online-softmax block order
    changes rounding); every stored winner must still validate."""
    from repro.tune import sweep
    c = TuneCache("flash_attention", tmp_path / "flash_attention.json")
    problem = {"b": 1, "sq": 16, "skv": 16, "h": 1, "kv": 1, "hd": 8,
               "causal": True, "q_offset": 0, "dtype": "float32"}
    rec = sweep("flash_attention", problem, cache=c, reps=1, warmup=0)
    assert rec["exact"] is True
    assert {"block_q", "block_kv"} <= set(rec["params"])
    valid_us = [s["us"] for s in rec["swept"] if s["exact"]]
    assert rec["us"] == min(valid_us)


def test_sweep_record_reaches_registry_dispatch(tmp_path, monkeypatch):
    """No stubs between store and lookup: a swept stencil winner must be
    what the registry dispatch actually applies."""
    import repro.tune.cache as cache_mod
    from repro.kernels import registry
    from repro.tune import sweep
    c = TuneCache("stencil_gather", tmp_path / "stencil_gather.json")
    monkeypatch.setattr(cache_mod, "_default", {"stencil_gather": c})
    spec = registry.get_spec("stencil_gather")
    problem = {"h": 40, "w": 40, "out_h": 36, "out_w": 36,
               "offsets": ((0, 1), (1, 0), (0, 0)), "origin": (1, 1),
               "dtype": "float32"}
    rec = sweep(spec, problem, cache=c, reps=1, warmup=0)
    seen = {}
    orig = spec.run_call

    def spy(problem, arrays, params, *, interpret):
        seen.update(params)
        return orig(problem, arrays, params, interpret=interpret)

    monkeypatch.setattr(spec, "run_call", spy)
    arrays = spec.make_call(problem, np.random.default_rng(0))
    registry.dispatch(spec, problem, arrays, force_kernel=True)
    assert seen == rec["params"]


def test_autotune_registered_skips_paramless_kernels(tmp_path, monkeypatch):
    """rwkv6_chunk has no tunables — the deploy warm-up must not sweep
    it (there is nothing to pick)."""
    import repro.tune.kernel_tuner as kt
    from repro.tune import autotune_registered
    swept = []
    monkeypatch.setattr(
        kt, "sweep",
        lambda spec, problem, **kw: swept.append(spec.name) or
        {"params": {}, "us": 1.0, "default_us": 1.0, "speedup_x": 1.0,
         "exact": True})
    autotune_registered(["rwkv6_chunk"])
    assert swept == []
    autotune_registered(["stencil_gather"])
    assert swept == ["stencil_gather"]


# ------------------------------------------------ adaptive controller ------
def _ctrl(policy=None, widths=(5, 16, 1), **kw):
    policy = policy or FlushPolicy(max_batch_rows=1024, max_delay_s=0.05)
    return AdaptiveFlushController(policy,
                                   widths_for=lambda key: list(widths), **kw)


def test_predict_latency_monotone_in_batch():
    lo = predict_batch_latency_s([5, 128, 1], 8)
    hi = predict_batch_latency_s([5, 128, 1], 4096)
    assert hi >= lo > 0


def test_controller_unknown_widths_degrades_to_static():
    pol = FlushPolicy(max_batch_rows=1024, max_delay_s=0.03)
    c = AdaptiveFlushController(
        pol, widths_for=lambda key: (_ for _ in ()).throw(IOError("gone")))
    assert c.delay_for("k", None) == 0.03
    assert c.batch_rows_for("k", None) == 1024


def test_controller_cold_stats_use_service_cap_not_static():
    c = _ctrl(service_factor=4.0, overhead_s=1e-4)
    d = c.delay_for("k", None)  # no stats at all: model-only decision
    # bounded by the service cap (~4x predicted latency), far below the
    # 50ms static deadline — low-arrival callers stop paying the full
    # static delay the moment the model is known
    assert c.min_delay_s <= d < 0.01
    assert d <= 4.0 * c.predict_latency_s([5, 16, 1], 1024) + 1e-9


def test_controller_high_rate_clamps_to_min_delay():
    c = _ctrl(min_delay_s=5e-4)
    st = ServeStats("k")
    now = time.monotonic()
    st._arrivals = deque([(now - 1.0 + 0.1 * i, 10 ** 6) for i in range(10)],
                         maxlen=256)
    st.requests_enqueued = 10
    d = c.delay_for("k", st)
    assert d == pytest.approx(5e-4)
    assert c.last_decision["k"]["arrival_rate_rows_s"] > 0


def test_controller_warmup_gates_rate_term_only():
    c = _ctrl(warmup_requests=8)
    st = ServeStats("k")
    st.requests_enqueued = 2  # below warmup: rate must not be consulted
    st._arrivals = deque([(time.monotonic(), 10 ** 9)] * 2, maxlen=256)
    d = c.delay_for("k", st)
    assert c.last_decision["k"]["arrival_rate_rows_s"] == 0.0
    assert d > 0


def test_controller_bucket_target_amortizes_overhead():
    # compute-bound toy peaks: the target lands strictly between the
    # floor and the cap, where per-row latency is within eps of flat
    pol = FlushPolicy(max_batch_rows=4096, min_bucket=8)
    c = AdaptiveFlushController(pol, widths_for=lambda k: [64, 64],
                                peak_flops=1e9, overhead_s=1e-4)
    t = c.batch_rows_for("k", None)
    assert 8 < t < 4096
    assert t & (t - 1) == 0  # power of two


# ------------------------------------------- measured-latency loop ---------
def _warm_stats(bucket, busy_s, n=3, key="k"):
    st = ServeStats(key)
    for _ in range(n):
        st.on_batch(requests=1, rows=bucket, bucket=bucket, reason="t",
                    busy_s=busy_s, latencies_s=[busy_s])
    return st


def test_stats_batch_latency_ewma_and_warmup_gate():
    st = ServeStats("k")
    assert st.batch_latency_s(64) is None  # cold
    # first observation of a bucket carries its one-time jit compile:
    # it must never blend into the EWMA the controller trusts
    st.on_batch(requests=1, rows=64, bucket=64, reason="t", busy_s=0.900,
                latencies_s=[0.9])
    assert st.batch_latency_s(64, min_batches=2) is None  # below min obs
    st.on_batch(requests=1, rows=64, bucket=64, reason="t", busy_s=0.020,
                latencies_s=[0.02])
    # the second observation *replaces* the compile-tainted seed
    assert st.batch_latency_s(64, min_batches=2) == pytest.approx(0.020)
    st.on_batch(requests=1, rows=64, bucket=64, reason="t", busy_s=0.010,
                latencies_s=[0.01])
    ewma = st.batch_latency_s(64, min_batches=2)
    # from the third batch on, a plain EWMA tracks the service time
    assert 0.010 < ewma < 0.020
    assert st.batch_latencies()[64][1] == 3
    snap = st.snapshot()
    assert snap["batch_latency_batches"] == {64: 3}
    assert snap["batch_latency_ewma_ms"][64] == pytest.approx(ewma * 1e3,
                                                              rel=1e-3)


def test_stats_failed_dispatches_never_feed_the_latency_model():
    st = ServeStats("k")
    st.on_enqueue(8)
    st.on_failure(requests=1, rows=8, reason="t", busy_s=5.0)
    assert st.batch_latencies() == {}


def test_controller_measured_latency_tightens_the_cap():
    """A roofline prior that overestimates the service time (huge
    overhead guess) holds lone callers too long; once the true latency
    is measured, the cap shrinks to the tight measured factor."""
    c = _ctrl(overhead_s=5e-3, measured_min_batches=2, decision_ttl_s=0.0)
    measured = 5e-4
    # warm the bucket the service cap prices: nothing pending -> the
    # smallest dispatchable bucket
    st = _warm_stats(c.policy.min_bucket, measured, n=3)
    d = c.delay_for("k", st)
    dec = c.last_decision["k"]
    assert dec["latency_source"] == "measured"
    assert dec["batch_latency_s"] == pytest.approx(measured, rel=1e-6)
    assert d == pytest.approx(
        c.measured_service_factor * measured, rel=1e-6)
    assert d < c.service_factor * dec["predicted_batch_latency_s"]


def test_controller_measured_latency_never_inflates_the_cap():
    """The anti-feedback property: serving getting *slower* than the
    prior must not lengthen deadlines (that would compound a slowdown
    into queueing delay)."""
    c = _ctrl(measured_min_batches=2, decision_ttl_s=0.0)
    cold = c.delay_for("cold", None)  # roofline-only bound, same widths
    st = _warm_stats(c.policy.min_bucket, 5.0, n=3)  # pathological 5s
    d = c.delay_for("k", st)
    assert d <= cold + 1e-9


def test_controller_corrects_roofline_from_nearest_warm_bucket():
    """Unmeasured buckets borrow the nearest warm bucket's measured /
    predicted ratio — one warm bucket recalibrates the whole curve."""
    c = _ctrl(measured_min_batches=2, decision_ttl_s=0.0)
    widths = [5, 16, 1]
    st = _warm_stats(64, busy_s=10.0 * c.predict_latency_s(widths, 64), n=3)
    lat, source = c.latency_s(widths, 256, st)
    assert source == "corrected"
    assert lat == pytest.approx(10.0 * c.predict_latency_s(widths, 256),
                                rel=0.05)


def test_controller_cap_bucket_matches_shard_rounded_dispatch():
    """The batcher's dispatch buckets are shard-rounded (bucket_for),
    not always powers of two; the cap must price the bucket the
    dispatch will actually produce so the exact-measured path hits."""
    c = _ctrl(measured_min_batches=2, decision_ttl_s=0.0)
    st = ServeStats("k")
    for _ in range(3):  # warm the 12-row bucket a 6-shard mesh dispatches
        st.on_enqueue(12)
        st.on_batch(requests=1, rows=12, bucket=12, reason="t",
                    busy_s=0.003, latencies_s=[0.003])
    st.on_enqueue(10)  # 10 rows pending: pow2 says 16, observed says 12
    c.delay_for("k", st)
    dec = c.last_decision["k"]
    assert dec["cap_bucket"] == 12
    assert dec["latency_source"] == "measured"
    assert dec["batch_latency_s"] == pytest.approx(0.003)


def test_controller_cold_stats_fall_back_to_roofline_prior():
    c = _ctrl(decision_ttl_s=0.0)
    st = ServeStats("k")  # no batches completed yet
    c.delay_for("k", st)
    assert c.last_decision["k"]["latency_source"] == "roofline"


def test_controller_open_loop_flag_ignores_measurements():
    c = _ctrl(use_measured=False, decision_ttl_s=0.0)
    st = _warm_stats(c.batch_rows_for("k", None), 5.0, n=10)
    c.delay_for("k", st)
    dec = c.last_decision["k"]
    assert dec["latency_source"] == "roofline"
    assert dec["batch_latency_s"] == dec["predicted_batch_latency_s"]


def test_controller_broken_stats_degrade_to_roofline():
    class _Boom(ServeStats):
        def batch_latency_s(self, *a, **kw):
            raise RuntimeError("stats backend gone")

    c = _ctrl(decision_ttl_s=0.0)
    st = _Boom("k")
    d = c.delay_for("k", st)
    assert d is not None
    assert c.last_decision["k"]["latency_source"] == "roofline"


def test_measured_latency_flows_through_real_queue(tmp_path):
    """End to end: batches served through a real queue warm the stats,
    and the controller's next decision prices the measured latency."""
    mp = _bundle(tmp_path)
    pol = FlushPolicy(max_batch_rows=1024, max_delay_s=0.05)
    ctrl = AdaptiveFlushController(pol, warmup_requests=4,
                                   measured_min_batches=1,
                                   decision_ttl_s=0.0)
    q = ServeQueue(pol, controller=ctrl)
    for i in range(6):
        q.submit(mp, _rows(4, seed=i))
        q.flush(mp)
    assert q.stats(mp).batch_latencies()  # batches recorded
    ctrl.delay_for(mp, q.stats(mp))
    assert ctrl.last_decision[mp]["latency_source"] in ("measured",
                                                        "corrected")


# -------------------------------------------- queue/controller wiring ------
class _StubController:
    def __init__(self, delay=None, rows=None, boom=False):
        self._delay, self._rows, self._boom = delay, rows, boom

    def delay_for(self, key, stats):
        if self._boom:
            raise RuntimeError("controller crashed")
        return self._delay

    def batch_rows_for(self, key, stats):
        if self._boom:
            raise RuntimeError("controller crashed")
        return self._rows


def test_queue_adaptive_deadline_via_poll(tmp_path):
    mp = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=None),
                   controller=_StubController(delay=0.02, rows=10 ** 6))
    f = q.submit(mp, _rows(4))
    assert q.poll() == 0  # adaptive deadline not reached yet
    time.sleep(0.03)
    assert q.poll() == 4  # fired from the controller, static policy has none
    assert f.done()
    assert q.stats(mp).snapshot()["flush_reasons"] == {"deadline": 1}


def test_queue_adaptive_batch_trigger(tmp_path):
    mp = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6),
                   controller=_StubController(delay=None, rows=16))
    q.submit(mp, _rows(8, seed=1))
    f = q.submit(mp, _rows(8, seed=2))  # 16 rows: adaptive trigger fires
    assert f.done()
    assert q.stats(mp).snapshot()["flush_reasons"] == {"max_batch": 1}


def test_queue_controller_failure_degrades_to_static(tmp_path):
    mp = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=16, max_delay_s=None),
                   controller=_StubController(boom=True))
    q.submit(mp, _rows(8, seed=1))
    f = q.submit(mp, _rows(8, seed=2))  # static max-batch still applies
    assert f.done()


def test_queue_cold_controller_demand_flush_no_deadlock(tmp_path):
    """Thread + controller whose delay is None (static None, widths
    unknown): a waiting future must still make its own progress."""
    mp = _bundle(tmp_path)
    q = ServeQueue(FlushPolicy(max_batch_rows=10 ** 6, max_delay_s=None),
                   controller=_StubController(delay=None, rows=10 ** 6))
    q.start()
    try:
        f = q.submit(mp, _rows(4))
        assert f.result(timeout=5).shape == (4, 1)
    finally:
        q.stop()


def test_real_controller_end_to_end_bit_identical(tmp_path):
    mp = _bundle(tmp_path)
    pol = FlushPolicy(max_batch_rows=1024, max_delay_s=0.05)
    q = ServeQueue(pol, controller=AdaptiveFlushController(pol))
    with q:
        futs = [q.submit(mp, _rows(4, seed=i)) for i in range(10)]
        outs = [f.result(10) for f in futs]
    eng = InferenceEngine.get(mp)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(o),
                                      np.asarray(eng(_rows(4, seed=i))))
    st = q.stats(mp).snapshot()
    assert st["rows_completed"] == 40 and st["queue_depth_rows"] == 0


# ------------------------------------------------- engine hot path ---------
def test_apply_batched_donate_bit_identical(tmp_path):
    mp = _bundle(tmp_path, "donate")
    eng = InferenceEngine(mp)
    x = _rows(13, seed=3)
    base = np.asarray(eng(x))[:13]  # caller-owned path, never donated
    # 13 rows pad to 16: the padded copy is engine-owned, so the batched
    # apply donates it — results must stay bit-identical regardless
    batched = np.asarray(eng.apply_batched(_rows(13, seed=3)))
    donated = np.asarray(eng.apply_batched(_rows(13, seed=3), donate=True))
    np.testing.assert_array_equal(batched, base)
    np.testing.assert_array_equal(donated, base)
    # the donated apply is a separate compiled variant, cached apart
    assert None in eng._applies and (None, "donate") in eng._applies


def test_apply_batched_prepadded_skips_rebucket(tmp_path):
    mp = _bundle(tmp_path, "prepad")
    eng = InferenceEngine(mp)
    x16 = _rows(16, seed=4)
    out = np.asarray(eng.apply_batched(_rows(16, seed=4), donate=True,
                                       prepadded=True))
    np.testing.assert_array_equal(out, np.asarray(eng(x16)))


def test_engine_meshless_ctx_shares_compile_cache(tmp_path):
    from repro.dist.sharding import use_mesh
    mp = _bundle(tmp_path, "norm")
    eng = InferenceEngine(mp)
    x = _rows(8, seed=5)
    eng(x)
    with use_mesh(None):  # the batcher's no-mesh request ctx
        eng(x)
    assert len(eng._applies) == 1  # same compiled apply, no duplicate


# ------------------------------------------------------ scratch pool -------
def test_scratch_pool_reuses_only_free_buffers():
    p = ScratchPool()
    a = p.take((8, 4), np.float32)
    a[:] = 1.0
    b = p.take((8, 4), np.float32)  # `a` alive: must get fresh memory
    b[:] = 2.0
    assert (a == 1.0).all() and p.stats()["misses"] == 2
    del a, b
    c = p.take((8, 4), np.float32)  # views dropped: pool hit
    assert p.stats()["hits"] == 1
    del c


def test_scratch_pool_row_views_pin_buffer():
    p = ScratchPool()
    buf = p.take((16, 2), np.float32)
    buf[:] = 7.0
    view = buf[3:5]
    del buf
    nxt = p.take((16, 2), np.float32)  # row view alive: no reuse
    nxt[:] = 0.0
    assert (view == 7.0).all()


def test_scratch_pool_grows_and_handles_empty():
    p = ScratchPool()
    small = p.take((4,), np.float32)
    del small
    big = p.take((1024, 8), np.float64)  # larger than any pooled buffer
    assert big.shape == (1024, 8)
    z = p.take((0, 4), np.float32)
    assert z.shape == (0, 4)


def test_batcher_scratch_gather_bit_identical_across_flushes(tmp_path):
    mp = _bundle(tmp_path, "scatter")
    q = ServeQueue(FlushPolicy(max_batch_rows=1024))
    eng = InferenceEngine.get(mp)
    for round_ in range(3):  # repeated flushes reuse the pooled buffers
        futs = [q.submit(mp, _rows(3, seed=10 * round_ + i))
                for i in range(3)]
        q.flush()
        for i, f in enumerate(futs):
            np.testing.assert_array_equal(
                np.asarray(f.result(1)),
                np.asarray(eng(_rows(3, seed=10 * round_ + i))))
    pool = q._batcher.scratch.stats()
    assert pool["hits"] > 0  # steady state is allocation-free
