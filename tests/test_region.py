"""Execution control: collect / infer / predicated semantics."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import approx_ml, tensor_functor
from repro.nas.train_surrogate import fit
from repro.nn import MLP
from repro.nn.serialize import save_model

_ifn = tensor_functor("rin: [i, 0:2] = ([i, 0:2])")
_ofn = tensor_functor("rout: [i, 0:1] = ([i, 0:1])")
N = 128


def _fn(x):
    return {"out": (x[:, :1] * 2 + x[:, 1:] * 0.5)}


def _mk(tmp, mode, model=None, db=None):
    rngs = {"i": (0, N)}
    return approx_ml(_fn, name="lin",
                     inputs={"x": (_ifn, rngs)}, outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, database=db)


@pytest.fixture(scope="module")
def model_path(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("region")
    rng = np.random.default_rng(0)
    X = rng.normal(size=(2048, 2)).astype(np.float32)
    Y = X[:, :1] * 2 + X[:, 1:] * 0.5
    net = MLP((1, 2), [32], 1)
    params, rmse, stats = fit(net, X, Y, epochs=80, lr=3e-3)
    assert rmse < 0.25
    return save_model(tmp / "m", net, params, extra=stats)


def test_collect_writes_database(tmp_path):
    r = _mk(tmp_path, "collect", db=str(tmp_path / "db"))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(N, 2)).astype(np.float32))
    out = r(x=x)
    np.testing.assert_allclose(np.asarray(out["out"]), np.asarray(_fn(x)["out"]))
    r.db.flush()
    d = r.db.group("lin").load()
    assert d["inputs"].shape == (N, 2)
    assert d["outputs"].shape == (N, 1)
    assert d["runtime"].shape == (1,) and d["runtime"][0] > 0


def test_infer_replaces_region(tmp_path, model_path):
    r = _mk(tmp_path, "infer", model=str(model_path))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(N, 2)).astype(np.float32))
    y = r(x=x)["out"]
    ref = _fn(x)["out"]
    assert float(jnp.sqrt(jnp.mean((y - ref) ** 2))) < 0.2


def test_predicated_eager_and_traced(tmp_path, model_path):
    r = _mk(tmp_path, "predicated", model=str(model_path))
    x = jnp.asarray(np.random.default_rng(3).normal(size=(N, 2)).astype(np.float32))
    ref = _fn(x)["out"]
    # eager
    acc = r(predicate=False, x=x)["out"]
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref), rtol=1e-6)
    ml = r(predicate=True, x=x)["out"]
    assert float(jnp.abs(ml - ref).max()) < 1.0
    # traced: both paths in one program (lax.cond)
    f = jax.jit(lambda x, p: r(predicate=p, x=x)["out"])
    np.testing.assert_allclose(np.asarray(f(x, False)), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f(x, True)), np.asarray(ml),
                               rtol=1e-4, atol=1e-4)


def test_collect_inside_jit_taps(tmp_path):
    r = _mk(tmp_path, "collect", db=str(tmp_path / "dbjit"))
    x = jnp.ones((N, 2))

    @jax.jit
    def step(x):
        return r(x=x)["out"]

    y = step(x)
    jax.block_until_ready(y)
    r.db.flush()
    d = r.db.group("lin").load()
    assert d["inputs"].shape[0] == N
