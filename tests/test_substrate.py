"""Substrate tests: checkpoint/restore, data pipeline, compression, optim,
fused loss, sharding rules, HLO collective parser."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import LayerSpec, ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.dist.hlo_analysis import Roofline, collective_stats
from repro.dist.sharding import ShardCtx
from repro.models import lm, loss as loss_lib
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state, warmup_cosine)
from repro.train import trainer
from repro.train.compression import ef_compress, init_residual

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
                   pattern=(LayerSpec(),))


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_exact_resume(tmp_path):
    state = trainer.make_train_state(jax.random.PRNGKey(0), TINY)
    pipe = TokenPipeline(TINY.vocab_size, 16, 4, seed=3)
    step_fn = jax.jit(lambda s, b: trainer.train_step(TINY, s, b))

    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_write=False)
    s = state
    for i in range(6):
        s, _ = step_fn(s, jax.tree.map(jnp.asarray, pipe.batch_at(i)))
        if i == 2:
            mgr.save(i + 1, s)
    final_direct = s

    s2, start = mgr.restore(state)
    assert start == 3
    for i in range(start, 6):
        s2, _ = step_fn(s2, jax.tree.map(jnp.asarray, pipe.batch_at(i)))
    for a, b in zip(jax.tree.leaves(final_direct), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    state = {"w": jnp.arange(8.0)}
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_write=False)
    for i in range(5):
        mgr.save(i, {"w": jnp.arange(8.0) + i})
    assert mgr.all_steps() == [3, 4]
    got, step = mgr.restore(state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0) + 4)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on 1 device, restore onto an 8-device mesh in a subprocess."""
    state = trainer.make_train_state(jax.random.PRNGKey(0), TINY)
    mgr = CheckpointManager(tmp_path / "ck", async_write=False)
    mgr.save(7, state)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, LayerSpec
from repro.train import trainer
from repro.dist.sharding import param_spec_tree
cfg = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
                  pattern=(LayerSpec(),))
mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(4, 2),
                         ("data", "model"))
state = jax.eval_shape(lambda: trainer.make_train_state(jax.random.PRNGKey(0), cfg))
specs = param_spec_tree(state, cfg, mesh)
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
mgr = CheckpointManager({str(tmp_path / 'ck')!r})
restored, step = mgr.restore(state, shardings=shardings)
assert step == 7
leaf = restored["params"]["stack"][0]["mlp"]["w1"]
assert len(leaf.sharding.device_set) > 1
print("ELASTIC_OK")
"""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=str(root))
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- pipeline ---
def test_pipeline_determinism_and_resharding():
    p1 = TokenPipeline(1000, 32, 8, seed=1)
    a = p1.batch_at(5)
    b = p1.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # 2-way split covers the same global batch
    h0 = p1.reshard(0, 2).batch_at(5)
    h1 = p1.reshard(1, 2).batch_at(5)
    glued = np.concatenate([h0["tokens"], h1["tokens"]])
    np.testing.assert_array_equal(glued, a["tokens"])


# ------------------------------------------------------------ compression -
def test_ef_compression_preserves_convergence():
    rng = np.random.default_rng(0)
    Xd = jnp.asarray(rng.normal(size=(256, 10)).astype(np.float32))
    w_true = jnp.asarray(rng.normal(size=(10,)).astype(np.float32))
    y = Xd @ w_true

    def loss(w):
        return ((Xd @ w - y) ** 2).mean()

    g = jax.jit(jax.grad(loss))

    def run(compress):
        w = jnp.zeros(10)
        res = init_residual(w)
        for _ in range(300):
            gg = g(w)
            if compress:
                gg, res = ef_compress(gg, res)
            w = w - 0.05 * gg
        return float(loss(w))

    exact, comp = run(False), run(True)
    assert comp < 1e-3, (exact, comp)


# ----------------------------------------------------------------- optim --
def test_adamw_descends_and_clip():
    w = {"a": jnp.ones((4, 4)) * 2}
    opt = init_opt_state(w, "full")

    def loss(p):
        return (p["a"] ** 2).sum()

    for _ in range(50):
        g = jax.grad(loss)(w)
        g, norm = clip_by_global_norm(g, 1.0)
        assert float(jnp.sqrt(sum((x.astype(jnp.float32) ** 2).sum()
                                  for x in jax.tree.leaves(g)))) <= 1.01
        w, opt = adamw_update(w, g, opt, 0.05, weight_decay=0.0)
    assert float(loss(w)) < 30.0


def test_lean_policy_state_dtypes():
    w = {"a": jnp.ones((4,), jnp.bfloat16)}
    opt = init_opt_state(w, "lean")
    assert "master" not in opt
    assert opt["m"]["a"].dtype == jnp.bfloat16
    g = {"a": jnp.ones((4,), jnp.bfloat16)}
    w2, opt2 = adamw_update(w, g, opt, 0.1, policy="lean")
    assert w2["a"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    assert float(warmup_cosine(jnp.asarray(0), peak_lr=1.0, warmup=10,
                               total=100)) == 0.0
    assert abs(float(warmup_cosine(jnp.asarray(10), peak_lr=1.0, warmup=10,
                                   total=100)) - 1.0) < 0.2


# ------------------------------------------------------------ fused loss --
def test_fused_xent_matches_naive_with_grads():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (2, 32, 16))
    W = jax.random.normal(jax.random.PRNGKey(1), (16, 128))
    t = jax.random.randint(rng, (2, 32), 0, 100)
    args = (x, W)
    l1, g1 = jax.value_and_grad(
        lambda x, W: loss_lib.naive_xent(x, W, t, 100), argnums=(0, 1))(*args)
    l2, g2 = jax.value_and_grad(
        lambda x, W: loss_lib.fused_linear_xent(x, W, t, 100, chunk=8),
        argnums=(0, 1))(*args)
    assert abs(float(l1 - l2)) < 1e-5
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


# --------------------------------------------------- sharding / analysis --
def test_spec_for_divisibility_fallback():
    import numpy as _np
    mesh = jax.sharding.Mesh(_np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    ctx = ShardCtx(mesh)
    # axis size 1 -> everything replicated, never crashes
    assert ctx.spec_for((40, 128), ("heads", "ffn")) == jax.sharding.PartitionSpec(None, None)


def test_collective_parser():
    hlo = """
  %all-gather.1 = bf16[16,4096,1024]{2,1,0} all-gather(bf16[1,4096,1024]{2,1,0} %p0), replica_groups=...
  %all-reduce.2 = f32[256,512]{1,0} all-reduce(f32[256,512]{1,0} %p1), to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(f32[128,64]{1,0} %p2), dimensions={0}
  %done = f32[4]{0} all-reduce-done(f32[4]{0} %x)
"""
    st = collective_stats(hlo)
    ag = 16 * 4096 * 1024 * 2
    ar = 256 * 512 * 4 * 2  # 2x ring factor
    rs = 128 * 64 * 4
    assert st.per_kind_bytes["all-gather"] == ag
    assert st.per_kind_bytes["all-reduce"] == ar
    assert st.per_kind_bytes["reduce-scatter"] == rs
    assert st.per_kind_count["all-gather"] == 1


def test_roofline_terms():
    r = Roofline(flops_global=197e12 * 256, hbm_bytes_global=819e9 * 128,
                 coll_bytes_global=50e9 * 64, chips=256,
                 model_flops=197e12 * 128)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert abs(r.collective_s - 0.25) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.useful_flops_fraction - 0.5) < 1e-9
