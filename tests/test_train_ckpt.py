"""Sharded train-state checkpointing: trainer entry points wire
`ckpt.CheckpointManager` to `dist.sharding.param_spec_tree` (elastic
restore onto whatever mesh the current job runs)."""
import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import LayerSpec, ModelConfig
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import (make_train_state, restore_train_state,
                                 save_train_state, state_shardings)

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
                   pattern=(LayerSpec(),))


@pytest.fixture(scope="module")
def state():
    return make_train_state(jax.random.PRNGKey(0), TINY)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_state_shardings_shape_and_mesh_resolution(state):
    mesh = make_local_mesh()
    shardings = state_shardings(TINY, state, mesh)
    # full tree coverage, every leaf a NamedSharding on the given mesh
    flat_state = jax.tree.leaves(state)
    flat_sh = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    assert len(flat_state) == len(flat_sh)
    assert all(isinstance(s, NamedSharding) and s.mesh == mesh
               for s in flat_sh)
    # no active/explicit mesh: unsharded restore path
    assert state_shardings(TINY, state) is None


def test_save_restore_roundtrip_sharded(tmp_path, state):
    mgr = CheckpointManager(tmp_path, async_write=False)
    save_train_state(mgr, 3, state)
    like = jax.tree.map(lambda x: jax.numpy.zeros_like(x), state)
    with use_mesh(make_local_mesh()):
        restored, step = restore_train_state(mgr, TINY, like)
    assert step == 3
    _assert_trees_equal(restored, state)
    # restored leaves are laid out by the active mesh's derived specs
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)


def test_restore_explicit_mesh_without_context(tmp_path, state):
    mgr = CheckpointManager(tmp_path, async_write=False)
    save_train_state(mgr, 7, state)
    like = jax.tree.map(lambda x: jax.numpy.zeros_like(x), state)
    mesh = make_local_mesh()
    restored, step = restore_train_state(mgr, TINY, like, mesh=mesh)
    assert step == 7
    _assert_trees_equal(restored, state)


def test_restore_unsharded_without_mesh(tmp_path, state):
    mgr = CheckpointManager(tmp_path, async_write=False)
    save_train_state(mgr, 1, state)
    like = jax.tree.map(lambda x: jax.numpy.zeros_like(x), state)
    restored, step = restore_train_state(mgr, TINY, like)
    assert step == 1
    _assert_trees_equal(restored, state)
