"""Quantized inference tier: math, gate verdicts, engine tier
resolution, cache schema, and the drift re-sweep trigger.

The tune-cache tests pin the contract the gate leans on: a passing
verdict is a normal schema-2 validated winner, a failing one is
``exact=False`` — structurally unresolvable by ``best_params`` — and
concurrent writers (an f32 sweep, an int8 sweep, and gate evaluations)
can race the same ``artifacts/tune`` directory without ever tearing a
file or corrupting the legacy-migration path.
"""
import json
import multiprocessing
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.tune.cache as cache_mod
from repro.quant.budgets import clear_budgets, rmse_budget, set_rmse_budget
from repro.quant.gate import GATE_NAMESPACE
from repro.tune.cache import TuneCache, best_params


@pytest.fixture(autouse=True)
def _isolate(tmp_path, monkeypatch):
    """Every test gets empty budget + cache registries and no cached
    engines; the gate namespace writes under tmp, never the repo's
    artifacts/tune."""
    from repro.core.engine import InferenceEngine
    clear_budgets()
    monkeypatch.setattr(cache_mod, "_default", {
        GATE_NAMESPACE: TuneCache(GATE_NAMESPACE,
                                  path=tmp_path / "quant_gate.json"),
        "fused_mlp": TuneCache("fused_mlp", path=tmp_path / "fused_mlp.json"),
        "fused_mlp_int8": TuneCache("fused_mlp_int8",
                                    path=tmp_path / "fused_mlp_int8.json"),
    })
    InferenceEngine.invalidate()
    yield
    InferenceEngine.invalidate()
    clear_budgets()


def _bundle(tmp, widths=(4, 16, 2), seed=0):
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, widths[0]), list(widths[1:-1]), widths[-1])
    return save_model(tmp / "m", net, net.init(jax.random.PRNGKey(seed)))


def _rows(n, d=4, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _gate_budget(mp, rows, rel=0.05):
    """Register a budget at ``rel`` x the bundle's f32 output RMS."""
    from repro.nn.serialize import load_model
    net, params, _ = load_model(mp)
    y = np.asarray(net.apply(params, jnp.asarray(rows)))
    budget = rel * float(np.sqrt(np.mean(np.square(y))))
    set_rmse_budget(mp, budget)
    return budget


# ------------------------------------------------------------ quant math ----
def test_weight_scale_factoring_is_exact():
    """The dequant identity the kernels rely on: row and channel scales
    are constant over the contraction dim, so they factor exactly out
    of the int32 dot — no approximation beyond the int8 rounding."""
    from repro.quant.quantize import (qdot, quantize_rows,
                                      quantize_weights_per_channel)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    wq, ws = quantize_weights_per_channel(w)
    hq, hs = quantize_rows(h)
    manual = (jnp.dot(hq.astype(jnp.float32), wq.astype(jnp.float32))
              * hs * ws)
    np.testing.assert_array_equal(np.asarray(qdot(hq, hs, wq, ws)),
                                  np.asarray(manual))
    # roundtrip error bounded by half an int8 step per element
    np.testing.assert_allclose(np.asarray(wq, np.float32) * np.asarray(ws),
                               np.asarray(w),
                               atol=float(jnp.abs(w).max()) / 127.0)


def test_quantize_zero_guards():
    """All-zero rows/channels must quantize to zeros, never NaN."""
    from repro.quant.quantize import (quantize_rows,
                                      quantize_weights_per_channel)
    w = jnp.zeros((8, 4), jnp.float32)
    wq, ws = quantize_weights_per_channel(w)
    assert np.isfinite(np.asarray(ws)).all()
    assert not np.asarray(wq).any()
    h = jnp.zeros((3, 8), jnp.float32)
    hq, hs = quantize_rows(h)
    assert np.isfinite(np.asarray(hs)).all()
    assert not np.asarray(hq).any()


def test_quant_mlp_ref_tracks_f32():
    from repro.quant.quantize import quant_mlp_ref, quantize_params
    rng = np.random.default_rng(1)
    ws = [rng.normal(size=(8, 32)).astype(np.float32) * 0.3,
          rng.normal(size=(32, 2)).astype(np.float32) * 0.3]
    bs = [rng.normal(size=(32,)).astype(np.float32) * 0.1,
          rng.normal(size=(2,)).astype(np.float32) * 0.1]
    x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    acts = ("relu", "identity")
    h = x
    for w, b, a in zip(ws, bs, acts):
        h = jnp.dot(h, jnp.asarray(w)) + jnp.asarray(b)
        if a == "relu":
            h = jax.nn.relu(h)
    yq = np.asarray(quant_mlp_ref(x, quantize_params(ws, bs), acts))
    y32 = np.asarray(h)
    rmse = float(np.sqrt(np.mean((yq - y32) ** 2)))
    assert rmse < 0.05 * float(np.sqrt(np.mean(y32 ** 2)))


# ------------------------------------------------- gate verdict lifecycle ---
def test_gate_pass_roundtrips_schema2_and_binds_fingerprint(tmp_path):
    from repro.quant.gate import gate_bundle, gate_passed, verdict
    mp = _bundle(tmp_path)
    rows = _rows(128)
    budget = _gate_budget(mp, rows)
    rec = gate_bundle(mp, rows)
    assert rec["exact"] is True and rec["params"] == {"gated": 1}
    assert rec["rmse"] <= budget and rec["budget"] == pytest.approx(budget)
    assert gate_passed(mp)
    # the verdict survives a cold re-read of the schema-2 file
    data = json.loads((tmp_path / "quant_gate.json").read_text())
    assert data["schema"] == 2 and data["kernel"] == GATE_NAMESPACE
    assert verdict(mp)["fingerprint"] == rec["fingerprint"]
    # a pass resolves through best_params like any validated winner
    assert best_params(GATE_NAMESPACE, [os.path.abspath(mp)]) == {"gated": 1}
    # retraining the bundle un-gates it until re-gated
    _bundle(tmp_path, seed=7)
    assert not gate_passed(mp)


def test_gate_fail_is_never_resolvable(tmp_path):
    from repro.obs import metrics as _m
    from repro.quant.gate import gate_bundle, gate_passed
    mp = _bundle(tmp_path)
    rows = _rows(128)
    _gate_budget(mp, rows)
    fails = _m.counter("repro_quant_gate_fail_total",
                       "quant gate evaluations that failed the RMSE budget",
                       ("bundle",))
    before = fails.value(bundle=mp)
    rec = gate_bundle(mp, rows, scale_mult=64.0)
    assert rec["exact"] is False and rec["params"] == {"gated": 0}
    assert not gate_passed(mp)
    assert fails.value(bundle=mp) == before + 1
    # the TuneCache resolution invariant the fail shape exploits
    assert best_params(GATE_NAMESPACE, [os.path.abspath(mp)]) is None


def test_gate_without_budget_is_an_error(tmp_path):
    from repro.quant.gate import gate_bundle
    mp = _bundle(tmp_path)
    with pytest.raises(ValueError, match="no RMSE budget"):
        gate_bundle(mp, _rows(32))


def test_calibration_rows_are_heldout(tmp_path):
    from repro.core.database import SurrogateDB
    from repro.quant.calibrate import calibration_rows
    db = SurrogateDB(tmp_path / "db")
    x, y = _rows(100), _rows(100, d=1, seed=1)
    db.group("r").append(x, y, 0.0)
    db.flush()
    rows = calibration_rows(db, "r", max_rows=8)
    assert rows.shape == (8, 4) and rows.dtype == np.float32
    _, held = db.group("r").train_test_split()
    np.testing.assert_array_equal(rows, held["inputs"][:8])
    db.group("empty").append(_rows(0), _rows(0, d=1), 0.0)
    db.flush()
    with pytest.raises(ValueError, match="no held-out"):
        calibration_rows(db, "empty")


# --------------------------------------------------- legacy cache schema ----
def test_legacy_schema1_migration_untouched_by_quant_writes(tmp_path):
    """Writing int8/gate records into their own namespaces must leave a
    legacy schema-1 fused_mlp file's migration byte-for-byte intact."""
    legacy = tmp_path / "fused_mlp.json"
    legacy.write_text(json.dumps(
        {"4-16-2|float32|cpu|b32": {"batch_tile": 64, "us": 1.0,
                                    "exact": True}}))
    c = TuneCache("fused_mlp", path=legacy)
    assert c.get("4-16-2|float32|cpu|b32")["params"] == {"batch_tile": 64}
    migrated = legacy.read_text()
    assert json.loads(migrated)["schema"] == 2
    # now hammer the sibling namespaces
    cache_mod._default["fused_mlp_int8"].put(
        "4-16-2|float32|cpu|b32", {"params": {"batch_tile": 32},
                                   "exact": True})
    cache_mod._default[GATE_NAMESPACE].put(
        "/some/bundle", {"params": {"gated": 1}, "exact": True})
    assert legacy.read_text() == migrated
    assert best_params("fused_mlp_int8",
                       ["4-16-2|float32|cpu|b32"]) == {"batch_tile": 32}


def _quant_cache_writer(path, wid, n_puts):
    """Spawn worker: race pass/fail gate verdicts (wid 0/1) or int8
    sweep records (wid 2) against siblings on the same directory."""
    from repro.tune.cache import TuneCache
    if wid == 2:
        c = TuneCache("fused_mlp_int8", path=path)
        for i in range(n_puts):
            c.put(f"4-16-2|float32|cpu|b{32 << (i % 3)}",
                  {"params": {"batch_tile": 32}, "us": float(i),
                   "exact": True, "swept": []})
        return
    c = TuneCache("quant_gate", path=path)
    for i in range(n_puts):
        passed = wid == 0
        c.put(f"/bundles/m{i % 5}",
              {"params": {"gated": int(passed)}, "exact": passed,
               "rmse": float(i), "budget": 1.0, "fingerprint": [i, i]})


def test_concurrent_f32_int8_gate_writes_never_tear(tmp_path):
    """A pass-writer and a fail-writer racing one quant_gate.json plus
    an int8 sweep writing its sibling: every observable intermediate
    must parse as a schema-2 cache, and surviving fail records must
    stay unresolvable."""
    gate_path = str(tmp_path / "quant_gate.json")
    int8_path = str(tmp_path / "fused_mlp_int8.json")
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_quant_cache_writer,
                         args=(gate_path if w < 2 else int8_path, w, 25))
             for w in range(3)]
    for p in procs:
        p.start()
    while any(p.is_alive() for p in procs):
        for f in (gate_path, int8_path):
            if os.path.exists(f):
                try:
                    data = json.loads(open(f).read())
                except ValueError as e:  # pragma: no cover - the regression
                    for p in procs:
                        p.terminate()
                    raise AssertionError(f"torn cache file {f}: {e}")
                assert data.get("schema") == 2
    for p in procs:
        p.join(timeout=30)
        assert p.exitcode == 0
    gate = TuneCache("quant_gate", path=gate_path)
    assert gate.entries()
    for key, rec in gate.entries().items():
        resolved = cache_mod._record_params(rec)
        if rec["exact"]:
            assert resolved == {"gated": 1}
        else:
            assert resolved is None  # fail records never resolve
    int8 = TuneCache("fused_mlp_int8", path=int8_path)
    assert all(r["params"]["batch_tile"] == 32
               for r in int8.entries().values())


# -------------------------------------------------- engine tier selection ---
def test_engine_tier_modes(tmp_path, monkeypatch):
    from repro.core.engine import InferenceEngine
    from repro.obs import metrics as _m
    from repro.quant.gate import gate_bundle
    mp = _bundle(tmp_path)
    rows = _rows(128)
    budget = _gate_budget(mp, rows)
    gate_bundle(mp, rows)

    # auto off-TPU: quantization buys nothing, serve f32
    monkeypatch.delenv("REPRO_QUANT", raising=False)
    assert jax.default_backend() != "tpu"
    assert InferenceEngine.get(mp).tier == "f32"

    # never pins f32 even with a passing gate
    monkeypatch.setenv("REPRO_QUANT", "never")
    InferenceEngine.invalidate(mp)
    y_f32 = np.asarray(InferenceEngine.get(mp).apply_batched(
        jnp.asarray(rows)))

    # force serves the gated int8 tier on any backend
    monkeypatch.setenv("REPRO_QUANT", "force")
    InferenceEngine.invalidate(mp)
    eng = InferenceEngine.get(mp)
    assert eng.tier == "int8" and eng._qlayers is not None
    served = _m.counter("repro_quant_served_rows_total",
                        "rows served by the gated int8 tier", ("bundle",))
    before = served.value(bundle=mp)
    yq = np.asarray(eng.apply_batched(jnp.asarray(rows)))
    assert served.value(bundle=mp) >= before + rows.shape[0]
    assert np.isfinite(yq).all()
    assert float(np.sqrt(np.mean((yq - y_f32) ** 2))) <= budget


def test_engine_force_without_gate_serves_f32(tmp_path, monkeypatch):
    """force is not a gate bypass: no verdict (or a fail) means f32."""
    from repro.core.engine import InferenceEngine
    from repro.quant.gate import gate_bundle
    mp = _bundle(tmp_path)
    monkeypatch.setenv("REPRO_QUANT", "force")
    assert InferenceEngine.get(mp).tier == "f32"
    # a fail verdict keeps it f32, bit-identical to the never path
    rows = _rows(64)
    _gate_budget(mp, rows)
    gate_bundle(mp, rows, scale_mult=64.0)
    eng = InferenceEngine.get(mp)
    assert eng.tier == "f32"
    y_force = np.asarray(eng.apply_batched(jnp.asarray(rows)))
    monkeypatch.setenv("REPRO_QUANT", "never")
    InferenceEngine.invalidate(mp)
    y_never = np.asarray(InferenceEngine.get(mp).apply_batched(
        jnp.asarray(rows)))
    np.testing.assert_array_equal(y_force, y_never)


def test_engine_retrain_ungates(tmp_path, monkeypatch):
    from repro.core.engine import InferenceEngine
    from repro.quant.gate import gate_bundle
    mp = _bundle(tmp_path)
    rows = _rows(64)
    _gate_budget(mp, rows)
    gate_bundle(mp, rows)
    monkeypatch.setenv("REPRO_QUANT", "force")
    assert InferenceEngine.get(mp).tier == "int8"
    # retrain: fresh weights, stale verdict -> f32 until re-gated
    _bundle(tmp_path, seed=9)
    assert InferenceEngine.get(mp).tier == "f32"


def test_select_tier_spec_resolution_order():
    from repro.kernels import registry
    base = registry.get_spec("fused_mlp")
    q = registry.get_spec("fused_mlp_int8")
    problem = {"widths": (4, 16, 2), "acts": ("relu", "identity"),
               "batch": 32, "dtype": "float32"}
    assert registry.quantized_variant(base) is q
    # ungated -> base; gated -> int8; explicit f32 pins base even gated;
    # explicit int8 bypasses the gate (direct testing only)
    assert registry.select_tier_spec(base, problem, gated=False)[0] is base
    assert registry.select_tier_spec(base, problem, gated=True)[0] is q
    assert registry.select_tier_spec(base, problem, gated=True,
                                     explicit="f32")[0] is base
    assert registry.select_tier_spec(base, problem, gated=False,
                                     explicit="int8")[0] is q
    # a problem the int8 variant can't hold falls back to base
    fat = {"widths": (8192, 8192, 8192), "acts": ("relu", "identity"),
           "batch": 32, "dtype": "float32"}
    assert registry.select_tier_spec(base, fat, gated=True)[0] is base
    # a kernel with no quantized twin always resolves itself
    fa = registry.get_spec("stencil_gather")
    assert registry.select_tier_spec(fa, None, gated=True)[0] is fa


# -------------------------------------------- per-operand VMEM cost model ---
def test_flash_vmem_model_prices_int8_kv_below_f32():
    """The satellite fix: `_fits` prices each operand at its own dtype.
    A KV cache that busts a tight budget at f32 fits as int8."""
    from repro.kernels.flash_attention import int8 as fa8
    from repro.kernels.flash_attention import ops as fa32
    problem = {"b": 1, "sq": 128, "skv": 4096, "h": 8, "kv": 2, "hd": 128,
               "causal": True, "q_offset": 0, "dtype": "float32"}
    params = {"block_q": 128, "block_kv": 128}
    budget = 7 * 2 ** 20
    assert not fa32._fits(problem, params, budget=budget)
    assert fa8._fits(problem, params, budget=budget)


def test_fused_mlp_vmem_model_prices_int8_weights_below_f32():
    from repro.kernels.fused_mlp.fused_mlp import fits_vmem
    from repro.kernels.fused_mlp.int8 import fits_vmem_int8
    widths = (256, 1024, 1024, 1)
    budget = 5 * 2 ** 20
    assert not fits_vmem(widths, 128, budget=budget)
    assert fits_vmem_int8(widths, 128, budget=budget)


def test_candidate_tiles_respect_activation_dtype():
    """The f32 kernel's ladder is dtype-aware too: halving the
    activation bytes admits tiles the f32 pricing rejects."""
    from repro.kernels.fused_mlp.fused_mlp import fits_vmem
    widths = (512, 1024, 1024, 64)
    # find a tile that only fits at 2-byte activations
    tight = next(b for b in (2 ** 20 * m for m in range(3, 64))
                 if fits_vmem(widths, 512, budget=b, dtype_bytes=2)
                 and not fits_vmem(widths, 512, budget=b, dtype_bytes=4))
    assert fits_vmem(widths, 512, budget=tight, dtype_bytes=2)


# ------------------------------------------------- shadow budget fallback ---
def test_shadow_scorer_budget_chain(tmp_path):
    """explicit set_budget > shared registry > default budget."""
    from repro.obs.quality import ShadowScorer
    s = ShadowScorer()
    s.set_default_budget(0.5)
    key = str(tmp_path / "bundle")
    s.observe(key, rmse=0.1)
    assert s.snapshot()["keys"][key]["budget_rmse"] == 0.5
    set_rmse_budget(key, 0.2)
    assert s.snapshot()["keys"][key]["budget_rmse"] == 0.2
    s.set_budget(key, 0.3)
    assert s.snapshot()["keys"][key]["budget_rmse"] == 0.3
    assert rmse_budget(key) == 0.2  # registry itself unchanged


# ----------------------------------------------------- resweep triggering ---
def test_resweep_trigger_dedup_and_counter(tmp_path, monkeypatch):
    from repro.obs import metrics as _m
    from repro.tune.resweep import ResweepWorker
    mp = _bundle(tmp_path)
    spec = json.loads((tmp_path / "m" / "spec.json").read_text())
    swept = []
    monkeypatch.setattr(
        ResweepWorker, "_sweep_cell",
        staticmethod(lambda k, w, b, d, a: swept.append((k, w, b, a))))
    worker = ResweepWorker(after=4)
    worker.enable()
    resweeps = _m.counter("repro_tune_resweep_total",
                          "drift-triggered background kernel sweeps "
                          "completed", ("kernel",))
    eng = types.SimpleNamespace(spec=spec, tier="f32")
    cold = types.SimpleNamespace(bucket_batches=lambda b: 1)
    hot = types.SimpleNamespace(bucket_batches=lambda b: 100)
    # below threshold: no trigger
    assert not worker.observe(eng, 64, cold)
    before = resweeps.value(kernel="fused_mlp")
    # sustained bucket: one enqueue, then dedup
    assert worker.observe(eng, 64, hot)
    assert not worker.observe(eng, 64, hot)
    assert worker.flush()
    assert resweeps.value(kernel="fused_mlp") == before + 1
    assert swept == [("fused_mlp", (4, 16, 2), 64, ("relu", "identity"))]
    # an int8-tier engine re-sweeps both ladders
    eng8 = types.SimpleNamespace(spec=spec, tier="int8")
    assert worker.observe(eng8, 32, hot)
    assert worker.flush()
    kernels = {k for k, *_ in swept}
    assert kernels == {"fused_mlp", "fused_mlp_int8"}
    # a key the cache already resolves is suppressed, not re-swept
    from repro.tune.cache import shape_key
    key = shape_key((4, 16, 2), "float32", jax.default_backend(), 128)
    cache_mod._default["fused_mlp"].put(
        key, {"params": {"batch_tile": 64}, "exact": True})
    n = len(swept)
    assert not worker.observe(eng, 128, hot)
    worker.flush()
    assert len(swept) == n


def test_resweep_disabled_is_inert(tmp_path):
    from repro.tune.resweep import ResweepWorker
    worker = ResweepWorker(after=1)
    assert not worker.enabled
    hot = types.SimpleNamespace(bucket_batches=lambda b: 100)
    assert not worker.observe(types.SimpleNamespace(spec={}, tier="f32"),
                              64, hot)
