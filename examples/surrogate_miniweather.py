"""MiniWeather surrogate campaign: the paper's Observation-4 experiment.

collect -> nested BO search -> deploy -> interleave accurate/surrogate
timesteps and measure error propagation (paper Fig. 9).

Run:  PYTHONPATH=src python examples/surrogate_miniweather.py
"""
import pathlib
import tempfile

import jax
import numpy as np

from repro.apps import miniweather as mw
from repro.nas.nested import best_trial, nested_search, save_trial


def main():
    tmp = pathlib.Path(tempfile.mkdtemp())
    state = mw.init_state()

    # 1) data collection over a training trajectory (paper: first 1000 steps)
    region = mw.make_region(mode="collect", database=str(tmp / "db"))
    s = state
    for _ in range(120):
        s = region(state=s)["state"]
    region.db.flush()

    # 2) nested BO search (reduced budget for CPU)
    res = nested_search(mw, region.db.group("miniweather"),
                        outer_iters=5, inner_iters=2, epochs=20)
    bt = best_trial(res)
    mp = save_trial(bt, tmp / "model")
    print(f"best surrogate: {bt['arch']} val_rmse={bt['val_rmse']:.5f}")

    # 3) interleave configurations (paper Fig. 9d)
    region2 = mw.make_region(mode="predicated", model=str(mp))
    horizon = 40
    ref = mw.run(state, horizon)
    for (na, ns) in [(1, 0), (1, 1), (1, 3), (0, 1)]:
        approx = mw.run(state, horizon, region=region2, interleave=(na, ns))
        err = mw.qoi_error(ref, approx)
        label = f"{na}:{ns}" if na or ns else "acc"
        print(f"  interleave accurate:surrogate = {na}:{ns:<2d} "
              f"RMSE@{horizon} = {err:.5f}")


if __name__ == "__main__":
    main()
