"""Batched LM serving demo: prefill + decode with KV caches.

Runs a small llama-style model, prefills a batch of prompts, then decodes
tokens autoregressively — the same serve_step the multi-pod dry-run lowers
for decode_32k/long_500k cells.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import lm


def main():
    cfg = ModelConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=8,
                      n_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8192,
                      pattern=(LayerSpec(),))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, prompt_len, gen = 4, 32, 48
    cache_len = prompt_len + gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.perf_counter()
    logits, caches = lm.prefill(cfg, params, prompts, cache_len=cache_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda p, c, t, pos: lm.serve_step(cfg, p, c, t, pos))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, caches = step(params, caches, tok, prompt_len + i)
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"prefill {B}x{prompt_len} in {t_prefill*1e3:.1f}ms; "
          f"decoded {gen} tokens in {t_decode*1e3:.1f}ms "
          f"({B*gen/t_decode:.0f} tok/s incl. first-call jit)")
    print("sample:", seqs[0, :16].tolist())


if __name__ == "__main__":
    main()
