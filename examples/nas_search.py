"""Standalone nested-BO surrogate search (paper §V-C) for any benchmark.

Run:  PYTHONPATH=src python examples/nas_search.py --app binomial --n 2048
"""
import argparse
import pathlib
import tempfile

from repro.apps import ALL_APPS
from repro.nas.nested import best_trial, nested_search, save_trial


def collect(app_name, app, n, db_path):
    if app_name == "miniweather":
        region = app.make_region(mode="collect", database=db_path)
        s = app.init_state()
        for _ in range(n):
            s = region(state=s)["state"]
    elif app_name == "particlefilter":
        frames, _ = app.make_video(n)
        region = app.make_region(n, mode="collect", database=db_path)
        region(frames=frames.reshape(n, -1))
    else:
        x = app.make_inputs(n)
        region = app.make_region(n, mode="collect", database=db_path)
        key = [k for k in region.inputs][0]
        region(**{key: x})
    region.db.flush()
    return region.db


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="binomial", choices=list(ALL_APPS))
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--outer", type=int, default=8)
    ap.add_argument("--inner", type=int, default=4)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    app = ALL_APPS[args.app]
    tmp = pathlib.Path(args.out or tempfile.mkdtemp())
    db = collect(args.app, app, args.n, str(tmp / "db"))
    res = nested_search(app, db.group(args.app),
                        outer_iters=args.outer, inner_iters=args.inner)
    print(f"\nexplored {len(res['trials'])} architectures; Pareto front:")
    for i in res["pareto"]:
        t = res["trials"][i]
        print(f"  {t['arch']}  rmse={t['val_rmse']:.5f} "
              f"lat={t['latency']*1e3:.2f}ms")
    bt = best_trial(res)
    mp = save_trial(bt, tmp / "model")
    print(f"best model saved to {mp}")


if __name__ == "__main__":
    main()
