"""Quickstart: the HPAC-ML programming model in 60 lines.

Mirrors the paper's Fig. 2: a 2-D stencil region annotated with tensor
functors, run in collect mode, then replaced by a surrogate.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import tempfile

import jax
import jax.numpy as jnp

from repro.core import SurrogateDB, approx_ml, tensor_functor
from repro.nas.train_surrogate import fit
from repro.nn import MLP
from repro.nn.serialize import save_model

N = M = 34

# --- declare the data bridge (paper Fig. 2 syntax) -------------------------
ifn = tensor_functor("ifnctr: [i, j, 0:5] = ([i-1,j],[i+1,j],[i,j-1:j+2])")
ofn = tensor_functor("ofnctr: [i, j] = ([i,j])")
RANGES = {"i": (1, N - 1), "j": (1, M - 1)}


# --- the accurate execution path -------------------------------------------
def smooth_step(t):
    """5-point smoothing: the computation the surrogate will replace."""
    interior = 0.2 * (t[1:-1, 1:-1] + t[:-2, 1:-1] + t[2:, 1:-1]
                      + t[1:-1, :-2] + t[1:-1, 2:])
    return {"t": t.at[1:-1, 1:-1].set(interior)}


def main():
    tmp = pathlib.Path(tempfile.mkdtemp())
    t = jax.random.normal(jax.random.PRNGKey(0), (N, M))

    # 1) collect training data while running the real code
    region = approx_ml(smooth_step, name="smooth",
                       inputs={"t": (ifn, RANGES)},
                       outputs={"t": (ofn, RANGES)},
                       mode="collect", database=str(tmp / "db"))
    state = t
    for _ in range(64):
        state = region(t=state)["t"]
    region.db.flush()

    # 2) train a surrogate offline from the database
    d = region.db.group("smooth").load()
    X = d["inputs"].reshape(-1, 5)
    Y = d["outputs"].reshape(-1, 1)
    net = MLP((1, 5), [32], 1)
    params, rmse, stats = fit(net, X, Y, epochs=40)
    mp = save_model(tmp / "model", net, params, extra=stats)
    print(f"collected {X.shape[0]} samples; surrogate val RMSE={rmse:.5f}")

    # 3) same region, now predicated: accurate and surrogate paths coexist
    region2 = approx_ml(smooth_step, name="smooth",
                        inputs={"t": (ifn, RANGES)},
                        outputs={"t": (ofn, RANGES)},
                        mode="predicated", model=str(mp))
    ref = smooth_step(t)["t"]
    ml = region2(predicate=True, t=t)["t"]
    acc = region2(predicate=False, t=t)["t"]
    print("surrogate RMSE vs accurate:",
          float(jnp.sqrt(jnp.mean((ml - ref) ** 2))))
    print("accurate path exact:", bool(jnp.allclose(acc, ref)))


if __name__ == "__main__":
    main()
