"""End-to-end LM training driver with production plumbing:

  * deterministic seekable data pipeline,
  * atomic/async checkpointing + exact resume,
  * straggler watchdog (p99 step-time flagging),
  * optional int8 error-feedback gradient compression,
  * optional simulated mid-run failure (--simulate-failure) to exercise
    the recovery path.

Default config is a ~20M-param llama-style model that trains a few
hundred steps on CPU; --preset 100m gives the ~100M assignment target.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import pathlib
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import LayerSpec, ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.train import trainer
from repro.train.compression import ef_compress, init_residual, wire_bytes

PRESETS = {
    "20m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                head_dim=32, d_ff=1024, vocab_size=8192),
    "100m": dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                 head_dim=64, d_ff=2048, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="20m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--simulate-failure", action="store_true",
                    help="crash at step 60%% through; rerun to resume")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", pattern=(LayerSpec(),),
                      **PRESETS[args.preset])
    n = cfg.param_counts()["total"]
    print(f"model {cfg.name}: {n/1e6:.1f}M params")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=7)
    state = trainer.make_train_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if mgr.latest_step() is not None:
        state, start = mgr.restore(state)
        print(f"resumed from checkpoint at step {start}")

    residual = init_residual(state["params"]) if args.grad_compress else None
    compress = None
    if args.grad_compress:
        un, comp = wire_bytes(state["params"])
        print(f"grad compression: {un/1e6:.1f}MB -> {comp/1e6:.1f}MB on the "
              f"cross-pod wire per step")

        def compress(grads):
            nonlocal residual
            g, residual = ef_compress(grads, residual)
            return g

    @jax.jit
    def step_fn(state, batch):
        return trainer.train_step(cfg, state, batch,
                                  grad_compress=compress)

    times = []
    fail_at = int(args.steps * 0.6)
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = jax.tree.map(jax.numpy.asarray, pipe.batch_at(step))
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        # straggler watchdog: flag steps beyond p99 of the trailing window
        if len(times) > 20:
            p99 = float(np.percentile(times[-50:], 99))
            if dt > max(2 * np.median(times[-50:]), p99 * 1.5):
                print(f"  [watchdog] step {step} took {dt*1e3:.0f}ms "
                      f"(p99 {p99*1e3:.0f}ms) — straggler flagged")
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if step and step % args.ckpt_every == 0:
            mgr.save(step, state)
        if args.simulate_failure and step == fail_at and start == 0:
            mgr.save(step, state)
            mgr.wait()
            print(f"simulated failure at step {step} — rerun to resume")
            raise SystemExit(17)
    mgr.save(args.steps, state)
    mgr.wait()
    print(f"done; median step {np.median(times)*1e3:.0f}ms; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
