"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32 (broadcastable)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, position_ids, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, hd]; position_ids: [3, B, S] (temporal, height, width).
    ``sections`` give the per-component split of the hd/2 frequencies.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # [half]
    # per-frequency positions, section c uses position component c
    ang_parts = []
    start = 0
    for c, sec in enumerate(sections):
        f = freqs[start:start + sec]
        p = position_ids[c].astype(jnp.float32)  # [B, S]
        ang_parts.append(p[..., None] * f)  # [B, S, sec]
        start += sec
    ang = jnp.concatenate(ang_parts, axis=-1)  # [B, S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(length: int, dim: int):
    """Whisper-style sinusoidal table [length, dim]."""
    half = dim // 2
    scale = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    pos = jnp.arange(length)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=1)
