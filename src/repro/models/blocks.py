"""Layer blocks: GQA / MLA / RWKV6 / Mamba mixers + dense / GLU / MoE MLPs.

Every mixer exposes:
  ``<name>_init(rng, cfg, cross=False)``      -> param dict
  ``<name>_seq(cfg, p, x, ...)``              -> (y, final_state_or_cache)
  ``<name>_step(cfg, p, x, state, pos, ...)`` -> (y, new_state)
and an ``init_state(cfg, batch, cache_len)`` shape helper used by the
serving layer.  State/caches are explicit pytrees so `lax.scan` can thread
them through the layer stack.

Recurrent mixers (RWKV6, Mamba) run exact chunked scans for full sequences:
an outer `lax.scan` over chunks carries the recurrent state; within a chunk
`jax.lax.associative_scan` computes all intermediate states in O(log c)
passes.  This bounds live memory to O(chunk * state) and avoids the
log-space pairwise overflow of decay-product formulations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import constrain
from repro.models import attention as attn_lib
from repro.models import rope as rope_lib


def _dense_init(rng, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----
def norm_init(cfg, rng=None):
    p = {"scale": jnp.ones((cfg.d_model,), cfg.jdtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
    return p


def apply_norm(cfg, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head(x, scale, eps=1e-6):
    """Per-head RMS norm (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _act(name):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


# ------------------------------------------------------------ GQA mixer ----
def gqa_init(rng, cfg, cross=False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd), cfg.jdtype),
        "wk": _dense_init(ks[1], (d, KV * hd), cfg.jdtype),
        "wv": _dense_init(ks[2], (d, KV * hd), cfg.jdtype),
        "wo": _dense_init(ks[3], (H * hd, d), cfg.jdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.jdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.jdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.jdtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), cfg.jdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.jdtype)
    return p


def _padded_heads(cfg, tp=16):
    H = cfg.n_heads
    return ((H + tp - 1) // tp) * tp if H % tp else H


def _project_qkv(cfg, p, xq, xkv, positions, position_ids=None, rope=True):
    B, Sq, _ = xq.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, xkv.shape[1], KV, hd)
    v = v.reshape(B, xkv.shape[1], KV, hd)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_head(q, p["q_norm"])
        k = rms_head(k, p["k_norm"])
    if rope and cfg.rope == "rope":
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        kpos = positions if xkv is xq else jnp.arange(k.shape[1])
        k = rope_lib.apply_rope(k, kpos, cfg.rope_theta)
    elif rope and cfg.rope == "mrope":
        q = rope_lib.apply_mrope(q, position_ids, cfg.rope_theta, cfg.mrope_sections)
        k = rope_lib.apply_mrope(k, position_ids, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def gqa_seq(cfg, p, x, *, positions, position_ids=None, causal=True,
            cross_kv=None, cache_len=None):
    """Full-sequence attention. Returns (y, kv) where kv = (k, v) for caching."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cross_kv is not None:
        k, v = cross_kv
        q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)).reshape(B, S, H, hd)
        causal = False
    else:
        q, k, v = _project_qkv(cfg, p, x, x, positions, position_ids)
    kv_out = (k, v)
    Hp = _padded_heads(cfg)
    if Hp != H:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
    q = constrain(q, "batch", None, "heads", None)
    k_r = attn_lib.repeat_kv(k, max(1, H // KV), Hp)
    v_r = attn_lib.repeat_kv(v, max(1, H // KV), Hp)
    k_r = constrain(k_r, "batch", None, "heads", None)
    v_r = constrain(v_r, "batch", None, "heads", None)
    if S * k.shape[1] > 4096 * 4096 // 4:
        o = attn_lib.chunked_attention(q, k_r, v_r, causal=causal,
                                       chunk=cfg.attn_chunk,
                                       unroll=cfg.unroll_inner)
    else:
        o = attn_lib.full_attention(q, k_r, v_r, causal=causal)
    if Hp != H:
        o = o[:, :, :H]
    o = o.reshape(B, S, H * hd)
    y = o @ p["wo"]
    return y, kv_out


def gqa_init_cache(cfg, batch, cache_len, dtype):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, cache_len, KV, hd), jnp.int8),
            "v": jnp.zeros((batch, cache_len, KV, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, KV), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, cache_len, KV), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
    }


def _quantize_kv(t):
    """Per-(token, head) int8 symmetric quantization. t: [B,S,KV,hd]."""
    scale = jnp.maximum(jnp.abs(t.astype(jnp.float32)).max(-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _int8_decode_attention(cfg, q, kq, vq, ks, vs, valid, *, chunk=2048):
    """Online-softmax decode attention with in-loop int8 dequant.

    q: [B,1,H,hd]; kq/vq: [B,S,KV,hd] int8; ks/vs: [B,S,KV] scales.
    The full bf16 cache is never materialized — each chunk dequantizes in
    VMEM-sized blocks (mirrors what a fused TPU kernel does).
    """
    B, _, H, hd = q.shape
    S, KV = kq.shape[1], kq.shape[2]
    n_rep = max(1, H // KV)
    scale = 1.0 / (hd ** 0.5)
    nchunk = max(1, S // chunk)
    chunk = S // nchunk
    qf = q.astype(jnp.float32)

    def body(carry, ci):
        acc, m, l = carry
        sl = ci * chunk
        kb = jax.lax.dynamic_slice_in_dim(kq, sl, chunk, 1)
        vb = jax.lax.dynamic_slice_in_dim(vq, sl, chunk, 1)
        ksb = jax.lax.dynamic_slice_in_dim(ks, sl, chunk, 1)
        vsb = jax.lax.dynamic_slice_in_dim(vs, sl, chunk, 1)
        kd = kb.astype(jnp.bfloat16) * ksb[..., None].astype(jnp.bfloat16)
        vd = vb.astype(jnp.bfloat16) * vsb[..., None].astype(jnp.bfloat16)
        kd = attn_lib.repeat_kv(kd, n_rep, H)
        vd = attn_lib.repeat_kv(vd, n_rep, H)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kd.astype(jnp.float32)) * scale
        pos = sl + jnp.arange(chunk)
        s = jnp.where((pos < valid)[None, None, None, :], s, attn_lib.NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(jnp.bfloat16), vd,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, H, 1, hd), jnp.float32)
    m0 = jnp.full((B, H, 1), attn_lib.NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, 1), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nchunk),
                                  unroll=bool(cfg.unroll_inner))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def gqa_step(cfg, p, x, cache, pos, *, position_ids=None, cross_kv=None,
             long_ctx=False):
    """Single-token decode. x: [B, 1, D]; cache k/v: [B, S, KV, hd]."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    seq_ax = "longseq" if long_ctx else "kvseq"
    if cross_kv is not None:
        k, v = cross_kv
        q = (x @ p["wq"] + (p["bq"] if cfg.qkv_bias else 0)).reshape(B, 1, H, hd)
        valid = k.shape[1]
        new_cache = cache
    else:
        pos_arr = jnp.full((1,), pos, jnp.int32)
        pid = None
        if cfg.rope == "mrope":
            pid = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (3, B, 1)) \
                if position_ids is None else position_ids
        q, k_new, v_new = _project_qkv(cfg, p, x, x, pos_arr, pid)
        if cfg.kv_cache_dtype == "int8":
            kq, ks_new = _quantize_kv(k_new)
            vq, vs_new = _quantize_kv(v_new)
            kc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0))
            ks = jax.lax.dynamic_update_slice(cache["k_scale"], ks_new,
                                              (0, pos, 0))
            vs = jax.lax.dynamic_update_slice(cache["v_scale"], vs_new,
                                              (0, pos, 0))
            kc = constrain(kc, "batch", seq_ax, None, None)
            vc = constrain(vc, "batch", seq_ax, None, None)
            ks = constrain(ks, "batch", seq_ax, None)
            vs = constrain(vs, "batch", seq_ax, None)
            new_cache = {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}
            # shard-local dequant (measured best: chunked slices over the
            # seq-sharded cache regress 14x — see EXPERIMENTS.md §Perf B2)
            k = kc.astype(jnp.bfloat16) * ks[..., None].astype(jnp.bfloat16)
            v = vc.astype(jnp.bfloat16) * vs[..., None].astype(jnp.bfloat16)
            k = constrain(k, "batch", seq_ax, None, None)
            v = constrain(v, "batch", seq_ax, None, None)
        else:
            k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
            v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
            k = constrain(k, "batch", seq_ax, None, None)
            v = constrain(v, "batch", seq_ax, None, None)
            new_cache = {"k": k, "v": v}
        valid = pos + 1
    k_r = attn_lib.repeat_kv(k, max(1, H // KV), H)
    v_r = attn_lib.repeat_kv(v, max(1, H // KV), H)
    o = attn_lib.full_attention(q, k_r, v_r, causal=False, kv_valid_len=valid)
    y = o.reshape(B, 1, H * hd) @ p["wo"]
    return y, new_cache


# ------------------------------------------------------------ MLA mixer ----
def mla_init(rng, cfg, cross=False):
    d, H = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(rng, 5)
    return {
        "w_q": _dense_init(ks[0], (d, H * qk), cfg.jdtype),
        "w_dkv": _dense_init(ks[1], (d, r), cfg.jdtype),
        "w_kr": _dense_init(ks[2], (d, cfg.qk_rope_dim), cfg.jdtype),
        "w_ukv": _dense_init(ks[3], (r, H * (cfg.qk_nope_dim + cfg.v_head_dim)), cfg.jdtype),
        "wo": _dense_init(ks[4], (H * cfg.v_head_dim, d), cfg.jdtype),
        "ckv_norm": jnp.ones((r,), cfg.jdtype),
    }


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rdim = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = (x @ p["w_q"]).reshape(B, S, H, nope + rdim)
    qn, qr = q[..., :nope], q[..., nope:]
    qr = rope_lib.apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _rms_vec(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def mla_seq(cfg, p, x, *, positions, position_ids=None, causal=True,
            cross_kv=None, cache_len=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rdim, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qn, qr = _mla_q(cfg, p, x, positions)
    ckv = _rms_vec(x @ p["w_dkv"], p["ckv_norm"])  # [B,S,r]
    kr = rope_lib.apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                             cfg.rope_theta)  # [B,S,1,rdim]
    kv = (ckv @ p["w_ukv"]).reshape(B, S, H, nope + vd)
    kn, v = kv[..., :nope], kv[..., nope:]
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, (B, S, H, rdim))], axis=-1)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    if S * S > 4096 * 4096 // 4:
        o = attn_lib.chunked_attention(q, k, v, causal=causal,
                                       chunk=cfg.attn_chunk,
                                       unroll=cfg.unroll_inner)
    else:
        o = attn_lib.full_attention(q, k, v, causal=causal)
    y = o.reshape(B, S, H * vd) @ p["wo"]
    return y, (ckv, kr[:, :, 0, :])


def mla_init_cache(cfg, batch, cache_len, dtype):
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
    }


def mla_step(cfg, p, x, cache, pos, *, position_ids=None, cross_kv=None,
             long_ctx=False):
    """Absorbed-matmul MLA decode: scores/values live in kv_lora space."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rdim, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    pos_arr = jnp.full((1,), pos, jnp.int32)
    qn, qr = _mla_q(cfg, p, x, pos_arr)  # [B,1,H,*]
    ckv_new = _rms_vec(x @ p["w_dkv"], p["ckv_norm"])
    kr_new = rope_lib.apply_rope((x @ p["w_kr"])[:, :, None, :], pos_arr,
                                 cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, pos, 0))
    seq_ax = "longseq" if long_ctx else "kvseq"
    ckv = constrain(ckv, "batch", seq_ax, None)
    kr = constrain(kr, "batch", seq_ax, None)
    w_uk = p["w_ukv"].reshape(r, H, nope + vd)[:, :, :nope]  # [r,H,nope]
    w_uv = p["w_ukv"].reshape(r, H, nope + vd)[:, :, nope:]  # [r,H,vd]
    q_lat = jnp.einsum("bqhn,rhn->bqhr", qn.astype(jnp.float32),
                       w_uk.astype(jnp.float32))  # [B,1,H,r]
    s = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bqhn,bsn->bhqs", qr.astype(jnp.float32),
                       kr.astype(jnp.float32))
    s = s / np.sqrt(nope + rdim)
    valid = jnp.arange(ckv.shape[1]) < (pos + 1)
    s = jnp.where(valid[None, None, None, :], s, attn_lib.NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    y = o.reshape(B, 1, H * vd) @ p["wo"]
    return y, {"ckv": ckv, "kr": kr}


# ----------------------------------------------------------- RWKV6 mixer ---
def rwkv6_init(rng, cfg, cross=False):
    d, ld = cfg.d_model, cfg.rwkv_lora_dim
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    ks = jax.random.split(rng, 10)
    decay = -6.0 + 5.0 * (jnp.arange(d) / max(1, d - 1)) ** 0.7
    return {
        "mu_base": jnp.full((d,), 0.5, cfg.jdtype),
        "mu_wkvrg": jnp.full((5, d), 0.5, cfg.jdtype),
        "lora_a_mix": _dense_init(ks[0], (d, 5 * ld), cfg.jdtype, 0.01),
        "lora_b_mix": (jax.random.normal(ks[1], (5, ld, d)) * 0.01).astype(cfg.jdtype),
        "w0": decay.astype(cfg.jdtype),
        "lora_a_w": _dense_init(ks[2], (d, 2 * ld), cfg.jdtype, 0.01),
        "lora_b_w": (jax.random.normal(ks[3], (2 * ld, d)) * 0.01).astype(cfg.jdtype),
        "w_u": (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(cfg.jdtype),
        "wr_tm": _dense_init(ks[5], (d, d), cfg.jdtype),
        "wk_tm": _dense_init(ks[6], (d, d), cfg.jdtype),
        "wv_tm": _dense_init(ks[7], (d, d), cfg.jdtype),
        "wg_tm": _dense_init(ks[8], (d, d), cfg.jdtype),
        "wo": _dense_init(ks[9], (d, d), cfg.jdtype),
        "gn_scale": jnp.ones((d,), cfg.jdtype),
        "gn_bias": jnp.zeros((d,), cfg.jdtype),
    }


def _rwkv_mix(cfg, p, x, x_prev):
    """Data-dependent token-shift (Finch ddlerp). Returns xw,xk,xv,xr,xg."""
    dx = x_prev - x
    xxx = x + dx * p["mu_base"]
    mix = jnp.tanh(xxx @ p["lora_a_mix"])
    B, S, _ = x.shape
    mix = mix.reshape(B, S, 5, cfg.rwkv_lora_dim)
    delta = jnp.einsum("bsfl,fld->fbsd", mix, p["lora_b_mix"])
    outs = []
    for i in range(5):
        outs.append(x + dx * (p["mu_wkvrg"][i] + delta[i]))
    return outs


def _rwkv_wkvrg(cfg, p, x, x_prev):
    xw, xk, xv, xr, xg = _rwkv_mix(cfg, p, x, x_prev)
    B, S, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    r = (xr @ p["wr_tm"]).reshape(B, S, H, hd)
    k = (xk @ p["wk_tm"]).reshape(B, S, H, hd)
    v = (xv @ p["wv_tm"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg_tm"])
    w_log = -jnp.exp(jnp.clip(
        p["w0"].astype(jnp.float32)
        + (jnp.tanh(xw @ p["lora_a_w"][:, :cfg.rwkv_lora_dim * 2].astype(x.dtype))
           @ p["lora_b_w"].astype(x.dtype)).astype(jnp.float32),
        -20.0, 1.0))
    w = jnp.exp(w_log).reshape(B, S, H, hd)  # decay in (0,1)
    return r, k, v, g, w


def _rwkv_groupnorm(cfg, p, o):
    """Per-head group norm of the wkv output. o: [B,S,H,hd]"""
    B, S, H, hd = o.shape
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = ((of - mu) ** 2).mean(-1, keepdims=True)
    y = ((of - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, H * hd)
    y = y * p["gn_scale"].astype(jnp.float32) + p["gn_bias"].astype(jnp.float32)
    return y


def rwkv6_seq(cfg, p, x, *, positions=None, position_ids=None, causal=True,
              cross_kv=None, cache_len=None, chunk=64, x_prev0=None, S0=None):
    """Chunked exact WKV scan. Returns (y, state) with state=(S, x_last)."""
    B, S, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    x_prev = jnp.concatenate(
        [x_prev0[:, None] if x_prev0 is not None else jnp.zeros((B, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    r, k, v, g, w = _rwkv_wkvrg(cfg, p, x, x_prev)
    u = p["w_u"].astype(jnp.float32)

    nchunk = S // chunk if S % chunk == 0 else -(-S // chunk)
    pad = nchunk * chunk - S
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    def to_chunks(t):
        return t.reshape(B, nchunk, chunk, H, hd).transpose(1, 0, 2, 3, 4).astype(jnp.float32)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    def chunk_body(S_in, xs):
        rb, kb, vb, wb = xs  # [B,c,H,hd]
        a = wb[..., None]                      # diag decay  [B,c,H,hdk,1]
        b = kb[..., :, None] * vb[..., None, :]  # k (x) v   [B,c,H,hdk,hdv]

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        A, Bc = jax.lax.associative_scan(comb, (a, b), axis=1)
        # state BEFORE t: shift the inclusive scan right by one
        A_prev = jnp.concatenate([jnp.ones_like(A[:, :1]), A[:, :-1]], axis=1)
        B_prev = jnp.concatenate([jnp.zeros_like(Bc[:, :1]), Bc[:, :-1]], axis=1)
        S_prev = A_prev * S_in[:, None] + B_prev  # [B,c,H,hdk,hdv]
        o = jnp.einsum("bchi,bchij->bchj", rb, S_prev)
        o = o + jnp.einsum("bchi,bchi,bchj->bchj", rb, u * kb, vb)
        S_out = A[:, -1] * S_in + Bc[:, -1]
        return S_out, o

    S_init = (S0 if S0 is not None
              else jnp.zeros((B, H, hd, hd), jnp.float32))
    S_fin, o = jax.lax.scan(chunk_body, S_init, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, nchunk * chunk, H, hd)[:, :S]
    y = _rwkv_groupnorm(cfg, p, o) * g.astype(jnp.float32)
    y = y.astype(x.dtype) @ p["wo"]
    return y, {"S": S_fin, "x_last": x[:, -1]}


def rwkv6_init_cache(cfg, batch, cache_len, dtype):
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_last": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv6_step(cfg, p, x, state, pos, *, position_ids=None, cross_kv=None,
               long_ctx=False):
    B = x.shape[0]
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_size
    r, k, v, g, w = _rwkv_wkvrg(cfg, p, x, state["x_last"][:, None])
    rf, kf, vf, wf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v, w))
    u = p["w_u"].astype(jnp.float32)
    S = state["S"]
    o = jnp.einsum("bhi,bhij->bhj", rf, S) + jnp.einsum(
        "bhi,bhi,bhj->bhj", rf, u * kf, vf)
    S_new = wf[..., None] * S + kf[..., None] * vf[..., None, :]
    y = _rwkv_groupnorm(cfg, p, o[:, None]) * g.astype(jnp.float32)
    y = y.astype(x.dtype) @ p["wo"]
    return y, {"S": S_new, "x_last": x[:, 0]}


# ----------------------------------------------------------- Mamba mixer ---
def mamba_init(rng, cfg, cross=False):
    d, di = cfg.d_model, cfg.mamba_d_inner
    ds, dc, dr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.dt_rank
    ks = jax.random.split(rng, 6)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), cfg.jdtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di)) * 0.1).astype(cfg.jdtype),
        "conv_b": jnp.zeros((di,), cfg.jdtype),
        "w_x": _dense_init(ks[2], (di, dr + 2 * ds), cfg.jdtype),
        "w_dt": _dense_init(ks[3], (dr, di), cfg.jdtype),
        "b_dt": jnp.full((di,), -4.6, cfg.jdtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(cfg.jdtype),
        "D_skip": jnp.ones((di,), cfg.jdtype),
        "w_out": _dense_init(ks[4], (di, d), cfg.jdtype),
    }


def _mamba_ssm_inputs(cfg, p, xz):
    """xz: conv'd activation [B,S,di] -> (dt, Bmat, Cmat)."""
    ds, dr = cfg.mamba_d_state, cfg.dt_rank
    proj = xz @ p["w_x"]
    dt, Bm, Cm = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["w_dt"] + p["b_dt"])  # [B,S,di]
    return dt, Bm, Cm


def mamba_seq(cfg, p, x, *, positions=None, position_ids=None, causal=True,
              cross_kv=None, cache_len=None, chunk=64, conv0=None, h0=None):
    B, S, d = x.shape
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", None, "dinner")
    # causal depthwise conv via shifts
    prev = conv0 if conv0 is not None else jnp.zeros((B, dc - 1, di), x.dtype)
    xp = jnp.concatenate([prev, xin], axis=1)
    conv = sum(xp[:, i:i + S] * p["conv_w"][i] for i in range(dc)) + p["conv_b"]
    conv_state = xp[:, S:S + dc - 1] if S >= dc - 1 else xp[:, -(dc - 1):]
    xc = jax.nn.silu(conv)
    dt, Bm, Cm = _mamba_ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di,ds]

    nchunk = -(-S // chunk)
    pad = nchunk * chunk - S
    xc_orig = xc
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))

    def toc(t):
        return t.reshape(B, nchunk, chunk, t.shape[-1]).transpose(1, 0, 2, 3).astype(jnp.float32)

    dtc, Bmc, Cmc, xcc = map(toc, (dt, Bm, Cm, xc))

    def chunk_body(h_in, xs):
        dtb, Bb, Cb, xb = xs  # [B,c,*]
        a = jnp.exp(dtb[..., None] * A)          # [B,c,di,ds]
        b = (dtb * xb)[..., None] * Bb[:, :, None, :]  # [B,c,di,ds]

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        Ac, Bc_ = jax.lax.associative_scan(comb, (a, b), axis=1)
        h = Ac * h_in[:, None] + Bc_             # inclusive states [B,c,di,ds]
        y = jnp.einsum("bcds,bcs->bcd", h, Cb)
        return h[:, -1], y

    h_init = h0 if h0 is not None else jnp.zeros((B, di, ds), jnp.float32)
    h_fin, y = jax.lax.scan(chunk_body, h_init, (dtc, Bmc, Cmc, xcc))
    y = y.transpose(1, 0, 2, 3).reshape(B, nchunk * chunk, di)[:, :S]
    y = y + xc_orig.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"conv": conv_state, "h": h_fin}


def mamba_init_cache(cfg, batch, cache_len, dtype):
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, dc - 1, di), dtype),
        "h": jnp.zeros((batch, di, ds), jnp.float32),
    }


def mamba_step(cfg, p, x, state, pos, *, position_ids=None, cross_kv=None,
               long_ctx=False):
    B = x.shape[0]
    di, ds, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)  # [B,1,di]
    xp = jnp.concatenate([state["conv"], xin], axis=1)  # [B,dc,di]
    conv = (xp * p["conv_w"]).sum(axis=1, keepdims=True) + p["conv_b"]
    xc = jax.nn.silu(conv)
    dt, Bm, Cm = _mamba_ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)
    b = (dt[:, 0] * xc[:, 0]).astype(jnp.float32)[..., None] * \
        Bm[:, 0, None, :].astype(jnp.float32)
    h = a * state["h"] + b
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + xc[:, 0].astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"conv": xp[:, 1:], "h": h}


# -------------------------------------------------------------- MLPs -------
def mlp_init(rng, cfg, kind):
    d = cfg.d_model
    ks = jax.random.split(rng, 4)
    if kind == "swiglu":
        return {
            "w1": _dense_init(ks[0], (d, cfg.d_ff), cfg.jdtype),
            "w3": _dense_init(ks[1], (d, cfg.d_ff), cfg.jdtype),
            "w2": _dense_init(ks[2], (cfg.d_ff, d), cfg.jdtype),
        }
    if kind == "gelu":
        p = {
            "w_up": _dense_init(ks[0], (d, cfg.d_ff), cfg.jdtype),
            "w_down": _dense_init(ks[1], (cfg.d_ff, d), cfg.jdtype),
        }
        if cfg.qkv_bias:
            p["b_up"] = jnp.zeros((cfg.d_ff,), cfg.jdtype)
            p["b_down"] = jnp.zeros((d,), cfg.jdtype)
        return p
    if kind == "rwkv_cm":
        return {
            "cm_mu_k": jnp.full((d,), 0.5, cfg.jdtype),
            "cm_mu_r": jnp.full((d,), 0.5, cfg.jdtype),
            "wk_cm": _dense_init(ks[0], (d, cfg.d_ff), cfg.jdtype),
            "wv_cm": _dense_init(ks[1], (cfg.d_ff, d), cfg.jdtype),
            "wr_cm": _dense_init(ks[2], (d, d), cfg.jdtype),
        }
    if kind == "moe":
        e_ff = cfg.moe_d_ff or cfg.d_ff
        E = cfg.n_experts
        p = {
            "w_router": _dense_init(ks[0], (d, E), jnp.float32),
            "we1": _dense_init(ks[1], (E, d, e_ff), cfg.jdtype),
            "we3": _dense_init(ks[2], (E, d, e_ff), cfg.jdtype),
            "we2": _dense_init(ks[3], (E, e_ff, d), cfg.jdtype),
        }
        if cfg.n_shared_experts:
            sf = e_ff * cfg.n_shared_experts
            ks2 = jax.random.split(ks[3], 3)
            p["ws1"] = _dense_init(ks2[0], (d, sf), cfg.jdtype)
            p["ws3"] = _dense_init(ks2[1], (d, sf), cfg.jdtype)
            p["ws2"] = _dense_init(ks2[2], (sf, d), cfg.jdtype)
        return p
    raise ValueError(kind)


def mlp_apply(cfg, p, x, kind, cm_prev=None):
    act = _act(cfg.act)
    if kind == "swiglu":
        h = act(x @ p["w1"]) * (x @ p["w3"])
        h = constrain(h, "batch", "seq", "ffn")
        return h @ p["w2"], None
    if kind == "gelu":
        h = x @ p["w_up"] + (p["b_up"] if "b_up" in p else 0)
        h = constrain(jax.nn.gelu(h), "batch", "seq", "ffn")
        return h @ p["w_down"] + (p["b_down"] if "b_down" in p else 0), None
    if kind == "rwkv_cm":
        B, S, d = x.shape
        prev = cm_prev if cm_prev is not None else jnp.zeros((B, 1, d), x.dtype)
        x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1) if S > 1 else prev
        xk = x + (x_prev - x) * p["cm_mu_k"]
        xr = x + (x_prev - x) * p["cm_mu_r"]
        h = jnp.square(jax.nn.relu(xk @ p["wk_cm"]))
        h = constrain(h, "batch", "seq", "ffn")
        return jax.nn.sigmoid(xr @ p["wr_cm"]) * (h @ p["wv_cm"]), x[:, -1:]
    if kind == "moe":
        return moe_apply(cfg, p, x)
    raise ValueError(kind)


def _raw_scatter(upd, e, p, E, C):
    """upd [G,N,d] -> buf [G,E,C+1,d]; group-local batched scatter-add."""
    G, N, d = upd.shape

    def one(u_g, e_g, p_g):
        return jnp.zeros((E, C + 1, d), u_g.dtype).at[e_g, p_g].add(u_g)

    # experts -> model (EP) when divisible; otherwise the feature dim takes
    # the model axis so expert-output reductions emit reduce-scatters
    return constrain(jax.vmap(one)(upd, e, p), "data", "experts", None,
                     "model")


def _raw_gather(src, e, p):
    """src [G,E,C+1,d] -> out [G,N,d]; group-local batched gather."""

    def one(s_g, e_g, p_g):
        return s_g[e_g, p_g]

    return constrain(jax.vmap(one)(src, e, p), "data", None, "model")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _dispatch_scatter(upd, e, p, E, C):
    return _raw_scatter(upd, e, p, E, C)


def _dispatch_fwd(upd, e, p, E, C):
    return _raw_scatter(upd, e, p, E, C), (e, p)


def _dispatch_bwd(E, C, res, g):
    e, p = res
    # adjoint of scatter-add is gather: keeps cotangents group-sharded
    return (_raw_gather(constrain(g, "data", "experts", None, "model"),
                        e, p), None, None)


_dispatch_scatter.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine_gather(src, e, p):
    return _raw_gather(src, e, p)


def _combine_fwd(src, e, p):
    return _raw_gather(src, e, p), (e, p, src.shape)


def _combine_bwd(res, g):
    e, p, shape = res
    E, C1 = shape[1], shape[2]
    d_src = _raw_scatter(constrain(g, "data", None, "model"), e, p, E,
                         C1 - 1)
    return d_src, None, None


_combine_gather.defvjp(_combine_fwd, _combine_bwd)


def _moe_groups(T: int) -> int:
    """Routing groups, aligned to the data shards (GShard-style local
    dispatch: tokens scatter only within their group, so the dispatch
    scatter/gather stays shard-local and GSPMD never replicates the flat
    token tensors)."""
    from repro.dist.sharding import current_ctx
    ctx = current_ctx()
    g = ctx.axis_size("batch") if ctx is not None else 1
    return g if g > 1 and T % g == 0 else 1


def moe_apply(cfg, p, x):
    """Capacity-based top-k routing, group-local dispatch (GShard-style)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    G = _moe_groups(T)
    Tg = T // G
    xg = constrain(x.reshape(G, Tg, d), "data", None, None)
    logits = xg.astype(jnp.float32) @ p["w_router"]  # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [G,Tg,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = max(1, -(-int(cfg.capacity_factor * k * Tg) // E))
    flat_e = constrain(gate_idx.reshape(G, Tg * k), "data", None)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [G,Tg*k,E]
    pos = jnp.cumsum(oh, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C
    pos_c = constrain(jnp.where(keep, pos, C), "data", None)  # overflow row

    xin_flat = jnp.repeat(xg, k, axis=1)  # [G,Tg*k,d]
    upd = constrain(xin_flat * keep[..., None].astype(x.dtype),
                    "data", None, None)
    buf = _dispatch_scatter(upd, flat_e, pos_c, E, C)
    xin = constrain(buf[:, :, :C], "data", "experts", None, None)  # [G,E,C,d]

    act = _act(cfg.act)
    h = act(jnp.einsum("gecd,edf->gecf", xin, p["we1"])) * \
        jnp.einsum("gecd,edf->gecf", xin, p["we3"])
    h = constrain(h, "data", "experts", None, "ffn")
    out_e = jnp.einsum("gecf,efd->gecd", h, p["we2"])  # [G,E,C,d]
    out_e = constrain(out_e, "data", "experts", None, "model")
    out_e = jnp.concatenate(
        [out_e, jnp.zeros((G, E, 1, d), out_e.dtype)], axis=2)

    gathered = _combine_gather(out_e, flat_e, pos_c)  # [G,Tg*k,d]
    w = (gate_vals.reshape(G, Tg * k) * keep.astype(jnp.float32)).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(G, Tg, k, d).sum(axis=2)
    y = constrain(y, "data", None, "model")
    if cfg.n_shared_experts:
        hs = act(xg @ p["ws1"]) * (xg @ p["ws3"])
        hs = constrain(hs, "data", None, "ffn")
        y = y + hs @ p["ws2"]
    return y.reshape(B, S, d), None


MIXER_INIT = {"gqa": gqa_init, "mla": mla_init, "rwkv6": rwkv6_init,
              "mamba": mamba_init}
MIXER_SEQ = {"gqa": gqa_seq, "mla": mla_seq, "rwkv6": rwkv6_seq,
             "mamba": mamba_seq}
MIXER_STEP = {"gqa": gqa_step, "mla": mla_step, "rwkv6": rwkv6_step,
              "mamba": mamba_step}
MIXER_CACHE = {"gqa": gqa_init_cache, "mla": mla_init_cache,
               "rwkv6": rwkv6_init_cache, "mamba": mamba_init_cache}
