"""Memory-aware cross-entropy.

``fused_linear_xent`` folds the LM head matmul into a sequence-chunked,
rematerialized loss: full [B, S, V] logits are never live — only one
[B, chunk, V_shard] f32 block at a time.  On a 151k-vocab 4B model this is
the difference between ~12 GB and ~0.5 GB of per-chip loss temporaries.
Chunks are a Python loop (not lax.scan) so the dry-run FLOP accounting is
exact and XLA can still overlap chunk k+1's matmul with chunk k's reduce.

``naive_xent`` is the oracle used by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


def naive_xent(x, W, targets, vocab_size):
    """x [B,S,D] @ W [D,Vp] -> mean xent against targets [B,S]."""
    logits = (x @ W).astype(jnp.float32)
    if W.shape[1] != vocab_size:
        mask = jnp.arange(W.shape[1]) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - tgt).mean()


def fused_linear_xent(x, W, targets, vocab_size, chunk: int = 512,
                      unroll: bool = False):
    """Sequence-chunked fused linear + softmax-xent (rematerialized)."""
    B, S, D = x.shape
    Vp = W.shape[1]
    x = constrain(x, "batch", None, None)  # un-shard seq: chunks stay local
    nchunk = max(1, S // chunk)
    chunk = S // nchunk
    assert S % nchunk == 0, (S, chunk)
    vmask = (jnp.arange(Vp) < vocab_size) if Vp != vocab_size else None

    @jax.checkpoint
    def chunk_loss(xc, tc):
        logits = (xc @ W).astype(jnp.float32)
        logits = constrain(logits, "batch", None, "vocab")
        if vmask is not None:
            logits = jnp.where(vmask, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return (lse - tgt).sum()

    xc = x.reshape(B, nchunk, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nchunk, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        return tot + chunk_loss(*xs), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc),
                            unroll=bool(unroll))
    return total / (B * S)


def embed_lookup(embed, tokens):
    """Embedding gather whose backward keeps the grad sharded.

    The naive `take` VJP scatter-adds into a full (often replicated)
    [V, D] f32 buffer under SPMD; constraining the cotangent keeps it on
    the (vocab -> model, d_model -> data) layout of the table itself.
    """

    shape, dtype = embed.shape, embed.dtype

    @jax.custom_vjp
    def _lookup(emb, tok):
        return jnp.take(emb, tok, axis=0)

    def fwd(emb, tok):
        return jnp.take(emb, tok, axis=0), tok

    def bwd(tok, g):
        zeros = constrain(jnp.zeros(shape, jnp.float32), "vocab", "fsdp")
        d_emb = zeros.at[tok.reshape(-1)].add(
            g.reshape(-1, shape[1]).astype(jnp.float32))
        d_emb = constrain(d_emb, "vocab", "fsdp")
        return d_emb.astype(dtype), None

    _lookup.defvjp(fwd, bwd)
    return _lookup(embed, tokens)
