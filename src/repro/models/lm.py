"""Unified LM: pattern-scanned decoder (+ optional encoder) over the blocks.

Public surface:
  init_params(rng, cfg)                  -> params pytree
  forward(cfg, params, tokens, ...)      -> logits
  train_loss(cfg, params, batch)         -> scalar loss
  init_caches(cfg, batch, cache_len)     -> decode cache pytree
  prefill(cfg, params, tokens, ...)      -> (logits_last, caches)
  serve_step(cfg, params, caches, token, pos, ...) -> (logits, caches)
"""
from __future__ import annotations

import functools
import re
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain, current_ctx
from repro.models import blocks, loss as loss_lib, rope as rope_lib
from repro.models.blocks import (MIXER_CACHE, MIXER_INIT, MIXER_SEQ,
                                 MIXER_STEP, apply_norm, mlp_apply, mlp_init,
                                 norm_init)

# ---------------------------------------------------------------- init -----


def init_layer(rng, cfg, spec):
    ks = jax.random.split(rng, 5)
    p = {
        "ln1": norm_init(cfg),
        "mixer": MIXER_INIT[spec.mixer](ks[0], cfg),
        "ln2": norm_init(cfg),
        "mlp": mlp_init(ks[1], cfg, spec.mlp),
    }
    if spec.cross_attn:
        p["ln_cross"] = norm_init(cfg)
        p["cross"] = blocks.gqa_init(ks[2], cfg, cross=True)
    if cfg.ffn_surrogate_dim:
        d, sd = cfg.d_model, cfg.ffn_surrogate_dim
        p["surr"] = {
            "w1": blocks._dense_init(ks[3], (d, sd), cfg.jdtype),
            "w2": blocks._dense_init(ks[4], (sd, d), cfg.jdtype),
        }
    return p


def init_params(rng, cfg):
    ks = jax.random.split(rng, 8)
    Vp, D = cfg.padded_vocab, cfg.d_model
    p = {"tok_embed": (jax.random.normal(ks[0], (Vp, D)) * 0.02).astype(cfg.jdtype)}
    R = cfg.pattern_repeats
    p["prefix"] = [init_layer(k, cfg, s)
                   for k, s in zip(jax.random.split(ks[1], max(1, len(cfg.prefix))),
                                   cfg.prefix)]
    stack = []
    for i, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(ks[2], i), R)
        stack.append(jax.vmap(lambda k: init_layer(k, cfg, spec))(keys))
    p["stack"] = tuple(stack)
    p["final_norm"] = norm_init(cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[3], (D, Vp)) * 0.02).astype(cfg.jdtype)
    if cfg.rope == "none" and not _is_recurrent_only(cfg):
        p["pos_embed"] = (jax.random.normal(ks[4], (cfg.max_pos, D)) * 0.01).astype(cfg.jdtype)
    if cfg.enc_dec:
        Re = cfg.enc_layers // len(cfg.enc_pattern)
        enc_stack = []
        for i, spec in enumerate(cfg.enc_pattern):
            keys = jax.random.split(jax.random.fold_in(ks[5], i), Re)
            enc_stack.append(jax.vmap(lambda k: init_layer(k, cfg, spec))(keys))
        p["encoder"] = {"stack": tuple(enc_stack), "final_norm": norm_init(cfg)}
    return p


def _is_recurrent_only(cfg):
    return all(s.mixer in ("rwkv6", "mamba") for s in
               list(cfg.prefix) + list(cfg.pattern))


# ------------------------------------------------------------- forward -----


def _apply_layer_seq(cfg, p, spec, x, *, positions, position_ids, enc_out):
    kw = dict(positions=positions, position_ids=position_ids)
    h, mc = MIXER_SEQ[spec.mixer](cfg, p["mixer"], apply_norm(cfg, p["ln1"], x), **kw)
    x = x + h
    x = constrain(x, "batch", _seq_ax(cfg), None)
    if spec.cross_attn and enc_out is not None:
        ckv = _cross_kv(cfg, p["cross"], enc_out)
        h, _ = blocks.gqa_seq(cfg, p["cross"], apply_norm(cfg, p["ln_cross"], x),
                              positions=positions, cross_kv=ckv)
        x = x + h
    h, cm_new = mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x), spec.mlp)
    x = x + h
    x = constrain(x, "batch", _seq_ax(cfg), None)
    cache = {"mixer": mc}
    if spec.mlp == "rwkv_cm":
        cache["cm_x_last"] = cm_new
    return x, cache


def _seq_ax(cfg):
    # SSM/hybrid archs keep seq unsharded (sequential chunk scans); attention
    # archs shard the residual stream's seq dim (Megatron-SP style).
    return None if any(s.mixer in ("rwkv6", "mamba")
                       for s in list(cfg.pattern) + list(cfg.prefix)) else "seq"


def _cross_kv(cfg, pc, enc_out):
    B, Se, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = enc_out @ pc["wk"]
    v = enc_out @ pc["wv"]
    if cfg.qkv_bias:
        k, v = k + pc["bk"], v + pc["bv"]
    return k.reshape(B, Se, KV, hd), v.reshape(B, Se, KV, hd)


def _embed(cfg, params, tokens, pos_offset=0):
    x = loss_lib.embed_lookup(params["tok_embed"], tokens)
    if "pos_embed" in params:
        S = tokens.shape[1]
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos_offset, S, 0)
        x = x + pe[None]
    return constrain(x, "batch", _seq_ax(cfg), None)


def encode(cfg, params, enc_embeds):
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    x = enc_embeds + rope_lib.sinusoidal(enc_embeds.shape[1], cfg.d_model
                                         ).astype(enc_embeds.dtype)[None]
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])
    for i, spec in enumerate(cfg.enc_pattern):
        def body(h, lp, spec=spec):
            h2, _ = _apply_layer_seq(cfg, lp, spec, h, positions=positions,
                                     position_ids=None, enc_out=None)
            return h2, None
        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["encoder"]["stack"][i])
        else:
            Re = cfg.enc_layers // len(cfg.enc_pattern)
            for r in range(Re):
                lp = jax.tree.map(lambda t: t[r], params["encoder"]["stack"][i])
                x, _ = body(x, lp)
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


def hidden_states(cfg, params, tokens, *, position_ids=None, enc_embeds=None,
                  collect_caches=False):
    """tokens [B,S] -> (final-normed hidden [B,S,D], caches, enc_out)."""
    B, S = tokens.shape
    enc_out = encode(cfg, params, enc_embeds) if cfg.enc_dec else None
    x = _embed(cfg, params, tokens)
    positions = jnp.arange(S)
    caches = {"prefix": [], "stack": []}
    for p, spec in zip(params["prefix"], cfg.prefix):
        x, c = _apply_layer_seq(cfg, p, spec, x, positions=positions,
                                position_ids=position_ids, enc_out=enc_out)
        caches["prefix"].append(c)

    def body(h, lps):
        new_c = []
        for lp, spec in zip(lps, cfg.pattern):
            h, c = _apply_layer_seq(cfg, lp, spec, h, positions=positions,
                                    position_ids=position_ids, enc_out=enc_out)
            new_c.append(c)
        return h, tuple(new_c) if collect_caches else None

    bodyf = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, stack_caches = jax.lax.scan(bodyf, x, params["stack"])
    else:  # unrolled: exact per-layer cost accounting for the dry-run
        collected = []
        for r in range(cfg.pattern_repeats):
            lps = jax.tree.map(lambda t: t[r], params["stack"])
            x, c = bodyf(x, lps)
            collected.append(c)
        stack_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
                        if collect_caches else None)
    caches["stack"] = stack_caches
    x = apply_norm(cfg, params["final_norm"], x)
    return x, caches, enc_out


def _head_matrix(cfg, params, dtype):
    head = params.get("lm_head")
    return head if head is not None else params["tok_embed"].T.astype(dtype)


def _logits_from_hidden(cfg, params, x):
    logits = x @ _head_matrix(cfg, params, x.dtype)
    logits = constrain(logits, "batch", None, "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def forward(cfg, params, tokens, *, position_ids=None, enc_embeds=None,
            collect_caches=False, last_only=False):
    """tokens [B,S] -> logits [B,S,Vp] (or [B,1,Vp] with last_only)."""
    x, caches, enc_out = hidden_states(cfg, params, tokens,
                                       position_ids=position_ids,
                                       enc_embeds=enc_embeds,
                                       collect_caches=collect_caches)
    if last_only:
        x = x[:, -1:]
    logits = _logits_from_hidden(cfg, params, x)
    if collect_caches:
        return logits, caches, enc_out
    return logits


def train_loss(cfg, params, batch, *, fused: bool = True):
    x, _, _ = hidden_states(cfg, params, batch["tokens"],
                            position_ids=batch.get("position_ids"),
                            enc_embeds=batch.get("enc_embeds"))
    W = _head_matrix(cfg, params, x.dtype)
    if fused:
        return loss_lib.fused_linear_xent(x, W, batch["targets"],
                                          cfg.vocab_size,
                                          unroll=cfg.unroll_inner)
    return loss_lib.naive_xent(x, W, batch["targets"], cfg.vocab_size)


# -------------------------------------------------------------- decode -----


def _layer_cache(cfg, spec, batch, cache_len, dtype):
    c = {"mixer": MIXER_CACHE[spec.mixer](cfg, batch, cache_len, dtype)}
    if spec.mlp == "rwkv_cm":
        c["cm_x_last"] = jnp.zeros((batch, 1, cfg.d_model), dtype)
    return c


def init_caches(cfg, batch, cache_len, dtype=None, enc_out=None, params=None):
    """Decode caches: prefix list + per-slot stacked trees (+ cross-kv)."""
    dtype = dtype or cfg.jdtype
    R = cfg.pattern_repeats
    caches = {
        "prefix": [_layer_cache(cfg, s, batch, cache_len, dtype) for s in cfg.prefix],
        "stack": tuple(
            jax.tree.map(lambda x: jnp.broadcast_to(x, (R,) + x.shape),
                         _layer_cache(cfg, s, batch, cache_len, dtype))
            for s in cfg.pattern),
    }
    if cfg.enc_dec:
        assert enc_out is not None and params is not None
        for i in range(len(cfg.pattern)):
            ck, cv = jax.vmap(lambda pc: _cross_kv(cfg, pc, enc_out))(
                params["stack"][i]["cross"])
            caches["stack"][i]["cross_k"] = ck
            caches["stack"][i]["cross_v"] = cv
    return caches


def _apply_layer_step(cfg, p, spec, x, cache, pos, *, position_ids, long_ctx):
    kw = dict(position_ids=position_ids, long_ctx=long_ctx)
    cross_kv = None
    if spec.cross_attn and "cross_k" in cache:
        cross_kv = (cache["cross_k"], cache["cross_v"])
    h, mc = MIXER_STEP[spec.mixer](cfg, p["mixer"], apply_norm(cfg, p["ln1"], x),
                                   cache["mixer"], pos, **kw)
    x = x + h
    if spec.cross_attn and cross_kv is not None:
        h, _ = blocks.gqa_step(cfg, p["cross"], apply_norm(cfg, p["ln_cross"], x),
                               None, pos, cross_kv=cross_kv)
        x = x + h
    cm_prev = cache.get("cm_x_last")
    cm_new = cm_prev
    if cfg.ffn_surrogate_dim and "surr" in p:
        # surrogate execution path (paper: the NN replaces the dominant
        # kernel); the accurate path is taken on interleaved steps
        xn = apply_norm(cfg, p["ln2"], x)
        h = jax.nn.silu(xn @ p["surr"]["w1"]) @ p["surr"]["w2"]
    else:
        h, cm_new = mlp_apply(cfg, p["mlp"], apply_norm(cfg, p["ln2"], x),
                              spec.mlp, cm_prev=cm_prev)
    x = x + h
    new_cache = dict(cache)
    new_cache["mixer"] = mc
    if cm_prev is not None:
        new_cache["cm_x_last"] = cm_new
    return x, new_cache


def serve_step(cfg, params, caches, tokens, pos, *, position_ids=None,
               long_ctx=False):
    """One decode step. tokens [B,1] -> (logits [B,Vp], new caches)."""
    x = jnp.take(params["tok_embed"], tokens, axis=0)
    if "pos_embed" in params:
        pe = jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1, 0)
        x = x + pe[None]
    x = constrain(x, "batch", None, None)
    new_prefix = []
    for p, spec, c in zip(params["prefix"], cfg.prefix, caches["prefix"]):
        x, c2 = _apply_layer_step(cfg, p, spec, x, c, pos,
                                  position_ids=position_ids, long_ctx=long_ctx)
        new_prefix.append(c2)

    def body(h, xs):
        lps, cs = xs
        new_cs = []
        for lp, spec, c in zip(lps, cfg.pattern, cs):
            h, c2 = _apply_layer_step(cfg, lp, spec, h, c, pos,
                                      position_ids=position_ids,
                                      long_ctx=long_ctx)
            new_cs.append(c2)
        return h, tuple(new_cs)

    if cfg.scan_layers:
        x, new_stack = jax.lax.scan(body, x, (params["stack"], caches["stack"]))
    else:
        collected = []
        for r in range(cfg.pattern_repeats):
            lps = jax.tree.map(lambda t: t[r], params["stack"])
            cs = jax.tree.map(lambda t: t[r], caches["stack"])
            x, c = body(x, (lps, cs))
            collected.append(c)
        new_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
    x = apply_norm(cfg, params["final_norm"], x)
    head = params.get("lm_head")
    logits = x[:, 0] @ (head if head is not None
                        else params["tok_embed"].T.astype(x.dtype))
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits, {"prefix": new_prefix, "stack": new_stack}


def prefill(cfg, params, tokens, *, position_ids=None, enc_embeds=None,
            cache_len=None):
    """Forward over the prompt; returns (last-token logits, decode caches)."""
    logits, caches, enc_out = forward(cfg, params, tokens,
                                      position_ids=position_ids,
                                      enc_embeds=enc_embeds,
                                      collect_caches=True, last_only=True)
    B, S = tokens.shape
    cache_len = cache_len or S
    out = init_caches(cfg, B, cache_len, cfg.jdtype, enc_out=enc_out,
                      params=params)
    for i, (spec, src) in enumerate(zip(cfg.prefix, caches["prefix"])):
        out["prefix"][i]["mixer"] = _fill_mixer(
            cfg, spec, out["prefix"][i]["mixer"], src["mixer"])
        if "cm_x_last" in src:
            out["prefix"][i]["cm_x_last"] = src["cm_x_last"]
    for i, spec in enumerate(cfg.pattern):
        src = caches["stack"][i]
        out["stack"][i]["mixer"] = _fill_mixer(
            cfg, spec, out["stack"][i]["mixer"], src["mixer"])
        if "cm_x_last" in src:
            out["stack"][i]["cm_x_last"] = src["cm_x_last"]
    return logits[:, -1], out


def _fill_mixer(cfg, spec, dst, src):
    """Write prefill-produced kv/states into preallocated cache buffers."""
    if src is None:
        return dst
    if spec.mixer == "gqa":
        k, v = src
        dst = dict(dst)
        if "k_scale" in dst:  # int8 cache: quantize prefill kv
            kq, ks = blocks._quantize_kv(k)
            vq, vs = blocks._quantize_kv(v)
            for key, val in (("k", kq), ("v", vq), ("k_scale", ks),
                             ("v_scale", vs)):
                dst[key] = jax.lax.dynamic_update_slice(
                    dst[key], val.astype(dst[key].dtype),
                    (0,) * dst[key].ndim)
            return dst
        dst["k"] = jax.lax.dynamic_update_slice(
            dst["k"], k.astype(dst["k"].dtype), (0,) * dst["k"].ndim)
        dst["v"] = jax.lax.dynamic_update_slice(
            dst["v"], v.astype(dst["v"].dtype), (0,) * dst["v"].ndim)
        return dst
    if spec.mixer == "mla":
        ckv, kr = src
        dst = dict(dst)
        dst["ckv"] = jax.lax.dynamic_update_slice(
            dst["ckv"], ckv.astype(dst["ckv"].dtype), (0,) * dst["ckv"].ndim)
        dst["kr"] = jax.lax.dynamic_update_slice(
            dst["kr"], kr.astype(dst["kr"].dtype), (0,) * dst["kr"].ndim)
        return dst
    if spec.mixer in ("rwkv6", "mamba"):
        return jax.tree.map(lambda d, s: s.astype(d.dtype), dst, src)
    return dst
