"""Attention compute: chunked (flash-style) softmax in pure JAX.

This is the portable implementation and the oracle for
``repro.kernels.flash_attention``.  KV is processed in ``chunk``-sized
blocks with a running max / denominator (online softmax), so live memory
is O(Sq * chunk) instead of O(Sq * Skv) — the difference between a 32k
prefill fitting in VMEM-era HBM budgets or not.

All inputs are [B, S, H, hd]; GQA callers repeat KV heads to H before
calling (the Pallas kernel handles groups natively; see kernels/).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      q_offset=0, kv_valid_len=None, unroll: bool = False):
    """Online-softmax attention.

    q: [B, Sq, H, hd]; k, v: [B, Skv, H, hd].
    causal: mask k_pos > q_pos (+q_offset shifts q positions).
    kv_valid_len: optional scalar; positions >= it are masked (KV caches).
    Returns [B, Sq, H, hd] in q.dtype.
    """
    B, Sq, H, hd = q.shape
    vd = v.shape[-1]  # may differ from hd (MLA: qk 192, v 128)
    Skv = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    nchunk = -(-Skv // chunk)
    pad = nchunk * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunk, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, H, vd).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(Sq) + q_offset  # [Sq]
    valid = Skv if kv_valid_len is None else kv_valid_len

    qf = q.astype(jnp.float32)

    def body(carry, xs):
        acc, m, l, ci = carry
        kb, vb = xs  # [B, chunk, H, hd]
        k_pos = ci * chunk + jnp.arange(chunk)  # [chunk]
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32)) * scale
        mask = (k_pos[None, :] < valid)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        else:
            mask = jnp.broadcast_to(mask, (Sq, chunk))
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))  # [B,H,Sq]
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        # probs stored bf16 (exp/max/sum stats stay f32): halves the live
        # score-block footprint; matches what the Pallas flash kernel keeps
        # in VMEM.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(jnp.bfloat16), vb,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new, ci + 1), None

    acc0 = jnp.zeros((B, H, Sq, vd), jnp.float32)
    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    # unroll=True is used by the dry-run calibration compiles only: XLA's
    # cost_analysis counts a while body once, so exact FLOP accounting
    # needs the chunks inlined.  Production keeps the while loop so buffer
    # assignment reuses one score block.
    (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, 0), (kc, vc),
                                     unroll=bool(unroll))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset=0, kv_valid_len=None):
    """Plain softmax attention (decode path / oracle)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(Skv)
    valid = Skv if kv_valid_len is None else kv_valid_len
    mask = k_pos[None, :] < valid
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    else:
        mask = jnp.broadcast_to(mask, (Sq, Skv))
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def repeat_kv(k, n_rep: int, target_heads: int):
    """Broadcast KV heads to (padded) query head count via gather."""
    B, S, KV, hd = k.shape
    idx = jnp.minimum(jnp.arange(target_heads) // n_rep, KV - 1)
    return jnp.take(k, idx, axis=2)
