"""AdamW with mixed-precision state policies.

``policy="full"``: fp32 master copy + fp32 (m, v) — 12 bytes/param of state.
``policy="lean"``: no master, bf16 (m, v) — 4 bytes/param; the update is
computed in fp32 and applied to the bf16 params directly (v5e practice for
models whose full-policy state would blow the 16 GB/chip HBM budget;
grok-1-314b uses this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_opt_state(params, policy: str = "full"):
    if policy == "full":
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        }
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
    }


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, policy: str = "full"):
    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master=None):
        gf = g.astype(jnp.float32)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * gf
        v_new = b2 * v32 + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * base)
        return new, m_new, v_new

    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    if policy == "full":
        master_leaves = treedef.flatten_up_to(state["master"])
        outs = [upd(p, g, m, v, w) for p, g, m, v, w in
                zip(p_leaves, g_leaves, m_leaves, v_leaves, master_leaves)]
        new_params = treedef.unflatten(
            [o[0].astype(p.dtype) for o, p in zip(outs, p_leaves)])
        return new_params, {
            "step": step,
            "m": treedef.unflatten([o[1] for o in outs]),
            "v": treedef.unflatten([o[2] for o in outs]),
            "master": treedef.unflatten([o[0] for o in outs]),
        }
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(p_leaves, g_leaves, m_leaves, v_leaves)]
    new_params = treedef.unflatten(
        [o[0].astype(p.dtype) for o, p in zip(outs, p_leaves)])
    return new_params, {
        "step": step,
        "m": treedef.unflatten([o[1].astype(jnp.bfloat16) for o in outs]),
        "v": treedef.unflatten([o[2].astype(jnp.bfloat16) for o in outs]),
    }


def clip_by_global_norm(grads, max_norm: float = 1.0):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads),
        0.0)
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def warmup_cosine(step, *, peak_lr=3e-4, warmup=100, total=10000, floor=0.1):
    stepf = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * stepf / max(1, warmup)
    frac = jnp.clip((stepf - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(stepf < warmup, warm, cos)
