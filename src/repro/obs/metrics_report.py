"""Render a metrics snapshot (and optionally a trace) as markdown.

    PYTHONPATH=src python -m repro.obs.metrics_report \
        --metrics artifacts/obs/serve_metrics.json --markdown

Input is the JSON form of ``MetricsRegistry.collect()`` (what
``serve_bench --trace`` writes next to the trace, and what each entry of
``pod_snapshot()`` carries under ``"metrics"``).  With ``--trace`` it
also summarizes span time by name — the quick "where did the batch go"
table without opening Perfetto.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List


def _fmt(v: float) -> str:
    return f"{v:g}"


def _labels(d: dict) -> str:
    return ", ".join(f"{k}={v}" for k, v in sorted(d.items())) or "-"


def render_metrics_markdown(collected: Dict[str, dict]) -> str:
    lines: List[str] = []
    scalars = [(n, m) for n, m in sorted(collected.items())
               if m.get("type") in ("counter", "gauge")]
    if scalars:
        lines += ["| metric | type | labels | value |",
                  "|---|---|---|---|"]
        for name, m in scalars:
            for v in m.get("values", []):
                lines.append(f"| {name} | {m['type']} | "
                             f"{_labels(v.get('labels', {}))} | "
                             f"{_fmt(v.get('value', 0))} |")
        lines.append("")
    hists = [(n, m) for n, m in sorted(collected.items())
             if m.get("type") == "histogram"]
    for name, m in hists:
        lines.append(f"**{name}**")
        lines.append("")
        lines += ["| labels | count | sum | mean | p50 bucket | p99 bucket |",
                  "|---|---|---|---|---|---|"]
        for v in m.get("values", []):
            count = v.get("count", 0)
            total = v.get("sum", 0.0)
            mean = total / count if count else 0.0
            buckets = {float(k): c for k, c in
                       (v.get("buckets") or {}).items()}
            p50 = _quantile_bucket(buckets, count, 0.50)
            p99 = _quantile_bucket(buckets, count, 0.99)
            lines.append(f"| {_labels(v.get('labels', {}))} | {count} | "
                         f"{_fmt(total)} | {_fmt(mean)} | {p50} | {p99} |")
        lines.append("")
    return "\n".join(lines)


def _quantile_bucket(buckets: Dict[float, int], count: int, q: float) -> str:
    """Upper bound of the first bucket whose cumulative count reaches
    the quantile (explicit buckets only bound quantiles, not pin them)."""
    if not count:
        return "-"
    target = q * count
    for le in sorted(buckets):
        if buckets[le] >= target:
            return f"<={le:g}s"
    return ">last"


def render_trace_markdown(events: List[dict]) -> str:
    agg: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev["name"], []).append(ev.get("dur", 0.0))
    lines = ["| span | count | total ms | mean us |", "|---|---|---|---|"]
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        lines.append(f"| {name} | {len(durs)} | {sum(durs) / 1e3:.3f} | "
                     f"{sum(durs) / len(durs):.1f} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.metrics_report",
        description="render obs metrics/trace snapshots as markdown")
    ap.add_argument("--metrics", type=pathlib.Path, default=None,
                    help="JSON file holding MetricsRegistry.collect() "
                         "output (or a pod_snapshot list)")
    ap.add_argument("--trace", type=pathlib.Path, default=None,
                    help="Chrome trace JSON to summarize by span name")
    ap.add_argument("--markdown", action="store_true",
                    help="render markdown (default and only format)")
    args = ap.parse_args(argv)
    if args.metrics is None and args.trace is None:
        ap.error("need --metrics and/or --trace")
    out: List[str] = []
    if args.metrics is not None:
        data = json.loads(args.metrics.read_text())
        snaps = data if isinstance(data, list) else [{"metrics": data}]
        for snap in snaps:
            if len(snaps) > 1:
                out.append(f"### process {snap.get('process', '?')} "
                           f"({snap.get('host', '?')})\n")
            out.append(render_metrics_markdown(snap.get("metrics", snap)))
    if args.trace is not None:
        data = json.loads(args.trace.read_text())
        events = data.get("traceEvents", data) if isinstance(data, dict) \
            else data
        out.append("### span time by name\n")
        out.append(render_trace_markdown(events))
    sys.stdout.write("\n".join(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
