"""Render a metrics snapshot (and optionally a trace) as markdown/JSON.

    PYTHONPATH=src python -m repro.obs.metrics_report \
        --metrics artifacts/obs/serve_metrics.json --markdown
    PYTHONPATH=src python -m repro.obs.metrics_report \
        --metrics artifacts/obs/serve_metrics.json --json

Input is the JSON form of ``MetricsRegistry.collect()`` (what
``serve_bench --trace`` writes next to the trace, and what each entry of
``pod_snapshot()`` carries under ``"metrics"``).  With ``--trace`` it
also summarizes span time by name — the quick "where did the batch go"
table without opening Perfetto.

Histogram quantiles (p50/p90/p99) are linearly interpolated from the
cumulative bucket counts — ``histogram_quantile`` semantics: exact only
if values are uniform within a bucket, and clamped to the largest
finite bucket bound when the quantile lands in the ``+Inf`` bucket.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from typing import Dict, List, Optional

#: numeric alert-state gauge values back to names (see obs.quality)
_STATE_NAMES = {0: "OK", 1: "WARN", 2: "CRITICAL"}


def _fmt(v: float) -> str:
    return f"{v:g}"


def _labels(d: dict) -> str:
    return ", ".join(f"{k}={v}" for k, v in sorted(d.items())) or "-"


def quantile_from_buckets(buckets: Dict[float, int], count: int,
                          q: float) -> Optional[float]:
    """Interpolated quantile from cumulative bucket counts.

    Linear interpolation within the first bucket whose cumulative count
    reaches ``q * count`` (the first bucket's lower bound is 0); when
    the quantile falls past the last finite bucket, returns that
    bucket's bound (a lower bound on the true quantile).
    """
    if not count or not buckets:
        return None
    target = q * count
    prev_le, prev_c = 0.0, 0
    items = sorted(buckets.items())
    for le, c in items:
        if c >= target:
            if math.isinf(le):  # clamp to the largest finite bound
                return prev_le
            span = c - prev_c
            if span <= 0:
                return le
            frac = (target - prev_c) / span
            return prev_le + (le - prev_le) * frac
        prev_le, prev_c = le, c
    return prev_le


def histogram_rows(m: dict) -> List[dict]:
    """Per-labelset summary rows (count/sum/mean/p50/p90/p99) for one
    collected histogram family — shared by markdown and JSON output."""
    rows = []
    for v in m.get("values", []):
        count = v.get("count", 0)
        total = v.get("sum", 0.0)
        buckets = {float(k): c for k, c in (v.get("buckets") or {}).items()}
        rows.append({
            "labels": v.get("labels", {}),
            "count": count,
            "sum": total,
            "mean": total / count if count else 0.0,
            "p50": quantile_from_buckets(buckets, count, 0.50),
            "p90": quantile_from_buckets(buckets, count, 0.90),
            "p99": quantile_from_buckets(buckets, count, 0.99),
        })
    return rows


def render_metrics_markdown(collected: Dict[str, dict]) -> str:
    lines: List[str] = []
    scalars = [(n, m) for n, m in sorted(collected.items())
               if m.get("type") in ("counter", "gauge")]
    if scalars:
        lines += ["| metric | type | labels | value |",
                  "|---|---|---|---|"]
        for name, m in scalars:
            for v in m.get("values", []):
                lines.append(f"| {name} | {m['type']} | "
                             f"{_labels(v.get('labels', {}))} | "
                             f"{_fmt(v.get('value', 0))} |")
        lines.append("")
    hists = [(n, m) for n, m in sorted(collected.items())
             if m.get("type") == "histogram"]
    for name, m in hists:
        lines.append(f"**{name}**")
        lines.append("")
        lines += ["| labels | count | sum | mean | p50 | p90 | p99 |",
                  "|---|---|---|---|---|---|---|"]
        for r in histogram_rows(m):
            def fq(x):
                return _fmt(x) if x is not None else "-"
            lines.append(f"| {_labels(r['labels'])} | {r['count']} | "
                         f"{_fmt(r['sum'])} | {_fmt(r['mean'])} | "
                         f"{fq(r['p50'])} | {fq(r['p90'])} | "
                         f"{fq(r['p99'])} |")
        lines.append("")
    return "\n".join(lines)


def _gauge_map(collected: Dict[str, dict], name: str) -> Dict[tuple, float]:
    out = {}
    for v in (collected.get(name) or {}).get("values", []):
        labels = v.get("labels", {})
        out[tuple(sorted(labels.items()))] = v.get("value", 0.0)
    return out


def render_quality_markdown(collected: Dict[str, dict]) -> str:
    """Surrogate-quality summary: one row per shadow-scored bundle, plus
    SLO burn rates when tracked.  Empty string when no quality metrics
    are present (shadow sampling off)."""
    rmse = _gauge_map(collected, "repro_quality_rmse")
    if not rmse:
        return ""
    max_abs = _gauge_map(collected, "repro_quality_max_abs")
    rel_l2 = _gauge_map(collected, "repro_quality_rel_l2")
    states = _gauge_map(collected, "repro_quality_alert_state")
    samples: Dict[str, float] = {}
    for v in (collected.get("repro_quality_samples_total") or {}).get(
            "values", []):
        key = v.get("labels", {}).get("key", "-")
        samples[key] = samples.get(key, 0) + v.get("value", 0)
    lines = ["### Surrogate quality (shadow-scored)", "",
             "| key | rmse ewma | max-abs ewma | rel-L2 ewma | samples "
             "| alert |",
             "|---|---|---|---|---|---|"]
    for lk, r in sorted(rmse.items()):
        key = dict(lk).get("key", "-")
        st = _STATE_NAMES.get(int(states.get(lk, 0)), "?")
        lines.append(
            f"| {key} | {_fmt(r)} | {_fmt(max_abs.get(lk, 0.0))} | "
            f"{_fmt(rel_l2.get(lk, 0.0))} | {int(samples.get(key, 0))} | "
            f"{st} |")
    lines.append("")
    burns = (collected.get("repro_slo_burn_rate") or {}).get("values", [])
    if burns:
        lines += ["**SLO burn rates**", "",
                  "| key | objective | window | burn |",
                  "|---|---|---|---|"]
        slo_states = _gauge_map(collected, "repro_slo_alert_state")
        for v in sorted(burns, key=lambda v: sorted(
                v.get("labels", {}).items())):
            lb = v.get("labels", {})
            lines.append(f"| {lb.get('key', '-')} | {lb.get('slo', '-')} | "
                         f"{lb.get('window', '-')} | "
                         f"{_fmt(v.get('value', 0.0))} |")
        crits = [dict(lk) for lk, s in slo_states.items() if s >= 2]
        if crits:
            lines.append("")
            lines.append(f"CRITICAL SLOs: {crits}")
        lines.append("")
    return "\n".join(lines)


def render_trace_markdown(events: List[dict]) -> str:
    agg: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev["name"], []).append(ev.get("dur", 0.0))
    lines = ["| span | count | total ms | mean us |", "|---|---|---|---|"]
    for name in sorted(agg, key=lambda n: -sum(agg[n])):
        durs = agg[name]
        lines.append(f"| {name} | {len(durs)} | {sum(durs) / 1e3:.3f} | "
                     f"{sum(durs) / len(durs):.1f} |")
    return "\n".join(lines) + "\n"


def trace_summary(events: List[dict]) -> Dict[str, dict]:
    agg: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev["name"], []).append(ev.get("dur", 0.0))
    return {name: {"count": len(durs), "total_ms": sum(durs) / 1e3,
                   "mean_us": sum(durs) / len(durs)}
            for name, durs in agg.items()}


def _load_snaps(path: pathlib.Path) -> List[dict]:
    data = json.loads(path.read_text())
    return data if isinstance(data, list) else [{"metrics": data}]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.metrics_report",
        description="render obs metrics/trace snapshots as markdown or "
                    "JSON")
    ap.add_argument("--metrics", type=pathlib.Path, default=None,
                    help="JSON file holding MetricsRegistry.collect() "
                         "output (or a pod_snapshot list)")
    ap.add_argument("--trace", type=pathlib.Path, default=None,
                    help="Chrome trace JSON to summarize by span name")
    ap.add_argument("--markdown", action="store_true",
                    help="render markdown (the default)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON document instead of markdown "
                         "(metrics + interpolated histogram quantiles + "
                         "trace summary)")
    args = ap.parse_args(argv)
    if args.metrics is None and args.trace is None:
        ap.error("need --metrics and/or --trace")

    snaps = _load_snaps(args.metrics) if args.metrics is not None else []
    events: List[dict] = []
    if args.trace is not None:
        data = json.loads(args.trace.read_text())
        events = data.get("traceEvents", data) if isinstance(data, dict) \
            else data

    if args.as_json:
        doc: dict = {"snapshots": []}
        for snap in snaps:
            collected = snap.get("metrics", snap)
            doc["snapshots"].append({
                "process": snap.get("process"),
                "host": snap.get("host"),
                "metrics": collected,
                "histogram_quantiles": {
                    name: histogram_rows(m)
                    for name, m in sorted(collected.items())
                    if m.get("type") == "histogram"},
            })
        if args.trace is not None:
            doc["trace_summary"] = trace_summary(events)
        json.dump(doc, sys.stdout, indent=1, default=str)
        sys.stdout.write("\n")
        return 0

    out: List[str] = []
    for snap in snaps:
        if len(snaps) > 1:
            out.append(f"### process {snap.get('process', '?')} "
                       f"({snap.get('host', '?')})\n")
        collected = snap.get("metrics", snap)
        out.append(render_metrics_markdown(collected))
        quality = render_quality_markdown(collected)
        if quality:
            out.append(quality)
    if args.trace is not None:
        out.append("### span time by name\n")
        out.append(render_trace_markdown(events))
    sys.stdout.write("\n".join(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
