"""Serving SLOs: multi-window burn-rate tracking over ``ServeStats``.

An :class:`SLO` declares per-key objectives — a latency target ("99% of
requests resolve within ``latency_threshold_s``") and an availability
target ("99.9% of requests succeed") — and :class:`SLOMonitor`
evaluates them the way production alerting does: **burn rate** per
window, ``error_rate / (1 - target)``, computed over two windows (short
+ long).  Burn 1.0 consumes the error budget exactly at the sustainable
pace; the monitor feeds the *minimum* across windows into an
:class:`~repro.obs.quality.AlertMachine`, so an alert requires the
budget to be burning in the short window (it's happening *now*) **and**
the long window (it's not a blip) — the standard multi-window guard
against both flappy and stale alerts.

Evaluation reads ``ServeStats.request_events()`` (a timestamped ring of
per-request ``(t, latency, ok)`` outcomes that the dispatcher already
records); windows with fewer than ``min_events`` events contribute burn
0, so a key that goes quiet heals rather than alerting on stale data.

Gauges: ``repro_slo_burn_rate{key,slo,window}``,
``repro_slo_alert_state{key,slo}``,
``repro_slo_budget_remaining{key,slo}`` (long window).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Tuple

from . import metrics as _metrics
from .quality import LEVELS, AlertMachine


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-key serving objectives (thresholds are per *request*)."""

    latency_threshold_s: float = 0.25
    latency_target: float = 0.99
    availability_target: float = 0.999
    windows_s: Tuple[float, float] = (60.0, 600.0)
    warn_burn: float = 1.0
    crit_burn: float = 6.0
    min_events: int = 20

    def objectives(self) -> Dict[str, float]:
        return {"latency": self.latency_target,
                "availability": self.availability_target}


class _Tracked:
    __slots__ = ("slo", "stats", "machines", "last")

    def __init__(self, slo: SLO, stats):
        self.slo = slo
        self.stats = stats
        self.machines = {name: AlertMachine(breach_n=2, clear_n=3)
                         for name in slo.objectives()}
        self.last: dict = {}


class SLOMonitor:
    """Evaluates tracked keys' SLOs; optionally on a background ticker.

    ``evaluate(now=...)`` is deterministic for tests; the obs endpoint
    calls ``evaluate()`` on every ``/metrics`` scrape so exported burn
    rates are never staler than the scrape interval.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._tracked: Dict[str, _Tracked] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._m_burn = _metrics.gauge(
            "repro_slo_burn_rate",
            "error-budget burn rate per key/objective/window",
            ("key", "slo", "window"))
        self._m_state = _metrics.gauge(
            "repro_slo_alert_state",
            "SLO alert state per key/objective (0=OK 1=WARN 2=CRITICAL)",
            ("key", "slo"))
        self._m_budget = _metrics.gauge(
            "repro_slo_budget_remaining",
            "fraction of the long-window error budget left",
            ("key", "slo"))
        self._m_events = _metrics.gauge(
            "repro_slo_window_events",
            "request outcomes observed in the long window", ("key",))

    # --------------------------------------------------------- tracking ---
    def track(self, key: str, stats, slo: Optional[SLO] = None) -> SLO:
        """Watch ``stats`` (a ``ServeStats``) against ``slo``."""
        slo = slo or SLO()
        with self._lock:
            self._tracked[key] = _Tracked(slo, stats)
        return slo

    def untrack(self, key: Optional[str] = None) -> None:
        with self._lock:
            if key is None:
                self._tracked.clear()
            else:
                self._tracked.pop(key, None)

    def tracked_keys(self):
        with self._lock:
            return sorted(self._tracked)

    # ------------------------------------------------------- evaluation ---
    def evaluate(self, now: Optional[float] = None) -> Dict[str, dict]:
        """One evaluation pass over every tracked key.

        Returns (and caches) per-key, per-objective burn rates and alert
        states; publishes the gauges.
        """
        now = time.monotonic() if now is None else now
        with self._lock:
            tracked = dict(self._tracked)
        results: Dict[str, dict] = {}
        for key, tr in tracked.items():
            slo = tr.slo
            long_w = max(slo.windows_s)
            events = tr.stats.request_events(window_s=long_w, now=now)
            self._m_events.set(len(events), key=key)
            per_obj: Dict[str, dict] = {}
            for obj, target in slo.objectives().items():
                budget = max(1.0 - target, 1e-9)
                burns: Dict[str, float] = {}
                counts: Dict[str, int] = {}
                err_long = 0.0
                for w in slo.windows_s:
                    evs = [e for e in events if e[0] >= now - w]
                    n = len(evs)
                    if obj == "latency":
                        # failures count against latency too: a request
                        # that never resolved did not resolve in time
                        bad = sum(1 for _, lat, ok in evs
                                  if not ok or
                                  not (lat <= slo.latency_threshold_s))
                    else:
                        bad = sum(1 for _, _, ok in evs if not ok)
                    err = bad / n if n else 0.0
                    wname = f"{w:g}s"
                    counts[wname] = n
                    burns[wname] = (err / budget
                                    if n >= slo.min_events else 0.0)
                    if w == long_w:
                        err_long = err
                # both windows must burn: feed the minimum
                value = min(burns.values()) if burns else 0.0
                state = tr.machines[obj].step(
                    value, slo.warn_burn, slo.crit_burn)
                remaining = max(0.0, 1.0 - err_long / budget)
                per_obj[obj] = {"burn": burns, "events": counts,
                                "state": state, "value": value,
                                "budget_remaining": remaining}
                for wname, b in burns.items():
                    self._m_burn.set(b, key=key, slo=obj, window=wname)
                self._m_state.set(LEVELS[state], key=key, slo=obj)
                self._m_budget.set(remaining, key=key, slo=obj)
            tr.last = per_obj
            results[key] = per_obj
        return results

    # ------------------------------------------------------------ export ---
    def states(self) -> Dict[str, Dict[str, str]]:
        """Last-evaluated alert state per key/objective (no re-eval)."""
        with self._lock:
            return {k: {obj: m.state for obj, m in tr.machines.items()}
                    for k, tr in self._tracked.items()}

    def worst_state(self) -> str:
        worst = 0
        for states in self.states().values():
            for s in states.values():
                worst = max(worst, LEVELS[s])
        return next(name for name, lv in LEVELS.items() if lv == worst)

    def snapshot(self) -> dict:
        """JSON-able SLO state (what ``pod_snapshot`` all-gathers)."""
        with self._lock:
            keys = {}
            for k, tr in self._tracked.items():
                keys[k] = {"slo": dataclasses.asdict(tr.slo),
                           "objectives": tr.last or {
                               obj: {"state": m.state}
                               for obj, m in tr.machines.items()}}
        return {"keys": keys}

    # ------------------------------------------------------------ ticker ---
    def start(self, interval_s: float = 5.0) -> "SLOMonitor":
        """Evaluate periodically on a daemon thread (long-running pods;
        the obs endpoint's scrape-time evaluate makes this optional)."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()

        def _tick():
            while not self._stop.wait(interval_s):
                try:
                    self.evaluate()
                except Exception as e:  # pragma: no cover - defensive
                    _metrics.warn_once("slo-eval-error",
                                       f"SLO evaluation failed: {e!r}")

        self._thread = threading.Thread(
            target=_tick, name="repro-slo-eval", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)
        self._thread = None


#: process-wide monitor (mirrors obs.TRACER / quality.SHADOW)
MONITOR = SLOMonitor()


def get_monitor() -> SLOMonitor:
    return MONITOR
