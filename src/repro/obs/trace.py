"""Low-overhead tracing for the serving pipeline.

A :class:`Tracer` records :class:`Span`\\ s (named, categorized time
intervals, optionally tagged with a *trace id*) into **per-thread ring
buffers**: recording a span is an append to the current thread's own
``deque`` — no lock on the hot path; the tracer's lock is taken only
when a thread records its first span (ring registration) and when
someone exports.  Disabled (the default), every entry point is a single
attribute check returning a shared no-op context, so instrumented code
pays nothing measurable when tracing is off (the serve-bench overhead
gate holds this at <2% even *enabled*).

Trace ids are minted by :meth:`Tracer.new_trace_id` at
``ServeQueue.submit`` and ride the request object through coalescing,
dispatch, and scatter — spans recorded from the submitter thread, the
dispatcher thread, and a pod-collective dispatch all carry the same id,
which is what makes a request's end-to-end latency decomposable after
the fact (queued → gathered → applied → landed → scattered).

Export is Chrome ``trace_event`` JSON (:meth:`export_chrome_trace`) —
open it at ``chrome://tracing`` or https://ui.perfetto.dev.  Timestamps
are recorded with ``time.monotonic()`` (the clock every serve-path
latency already uses) and shifted to the wall clock at export, so
traces from different processes on one machine merge on a shared
timeline (``repro.obs.pod``).

``annotate=True`` additionally enters a ``jax.profiler.TraceAnnotation``
for every span, so spans line up with XLA's own timeline when a TPU
profile is being captured alongside.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

ENV_TRACE = "REPRO_TRACE"
ENV_ANNOTATE = "REPRO_TRACE_ANNOTATE"


class Span:
    """One recorded interval (``t1 == t0`` marks an instant event)."""

    __slots__ = ("name", "cat", "t0", "t1", "trace", "args", "tid", "thread")

    def __init__(self, name, cat, t0, t1, trace, args, tid, thread):
        self.name, self.cat = name, cat
        self.t0, self.t1 = t0, t1
        self.trace, self.args = trace, args
        self.tid, self.thread = tid, thread

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat, "t0": self.t0,
                "t1": self.t1, "trace": self.trace, "args": self.args,
                "tid": self.tid, "thread": self.thread}

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, {self.dur_s * 1e3:.3f}ms, "
                f"trace={self.trace!r})")


class _NullSpan:
    """Shared no-op context: what ``span()`` returns while disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _LiveSpan:
    __slots__ = ("_tracer", "name", "cat", "trace", "args", "_t0", "_ann")

    def __init__(self, tracer, name, cat, trace, args):
        self._tracer = tracer
        self.name, self.cat = name, cat
        self.trace, self.args = trace, args
        self._ann = None

    def __enter__(self):
        if self._tracer.annotate:
            try:
                from jax.profiler import TraceAnnotation
                self._ann = TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:
                self._ann = None
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        t1 = time.monotonic()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer.rec(self.name, self.cat, self._t0, t1,
                         self.trace, self.args)
        return False


class Tracer:
    """Per-thread ring-buffer event log with Chrome-trace export."""

    def __init__(self, ring_size: int = 8192, annotate: bool = False):
        self.ring_size = ring_size
        self.enabled = False
        self.annotate = annotate
        # monotonic -> wall offset, fixed at construction: export shifts
        # every timestamp by this so per-process traces share a timeline
        self.epoch = time.time() - time.monotonic()
        self._tls = threading.local()
        # (thread_name, tid, deque, drops) — drops is a 2-slot mutable
        # counter: [entries evicted on wrap, evictions already published]
        self._rings: List[tuple] = []
        self._reg_lock = threading.Lock()
        self._seq = itertools.count()
        self._pid_prefix = f"{os.getpid():x}."

    # ---------------------------------------------------------- control ---
    def enable(self, annotate: Optional[bool] = None) -> "Tracer":
        if annotate is not None:
            self.annotate = annotate
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        """Drop recorded spans (drop *counters* survive: they are
        cumulative eviction totals, not ring contents)."""
        with self._reg_lock:
            for _, _, ring, _ in self._rings:
                ring.clear()

    # -------------------------------------------------------- recording ---
    def new_trace_id(self) -> str:
        """Mint a process-unique request trace id (pid-prefixed so ids
        from different pod processes never collide in a merged trace)."""
        return self._pid_prefix + str(next(self._seq))

    def _ring(self) -> tuple:
        """This thread's ``(ring, tid, thread_name, drops)`` — thread
        identity is resolved once at ring registration, not per span
        record."""
        state = getattr(self._tls, "state", None)
        if state is None:
            t = threading.current_thread()
            ring = deque(maxlen=self.ring_size)
            drops = [0, 0]
            state = self._tls.state = (ring, t.ident or 0, t.name, drops)
            with self._reg_lock:
                self._rings.append((t.name, t.ident or 0, ring, drops))
        return state

    def record(self, name: str, t0: float, t1: float, *, cat: str = "serve",
               trace: Optional[str] = None, args: Optional[dict] = None
               ) -> None:
        """Record a span with explicit ``time.monotonic()`` endpoints.

        This is how spans for *past* intervals land (e.g.
        ``serve.request``: the dispatcher stamps the span from the
        request's own ``t_enqueue``, covering queued time it never saw).
        """
        if not self.enabled:
            return
        self.rec(name, cat, t0, t1, trace, args)

    def rec(self, name: str, cat: str, t0: float, t1: float,
            trace: Optional[str], args: Optional[dict]) -> None:
        """Positional fast path of :meth:`record` for per-request serve
        loops (no kwargs packing).  Callers must have checked ``enabled``
        or accept the dead append; ``args`` dicts may be shared across
        records — export copies before mutating."""
        # ring entries are plain tuples: building Span objects is deferred
        # to export so the hot path pays one tuple + one deque append
        ring, tid, tname, drops = self._ring()
        if len(ring) == ring.maxlen:
            drops[0] += 1  # the append below evicts the oldest entry
        ring.append((name, cat, t0, t1, trace, args, tid, tname))

    def instant(self, name: str, *, cat: str = "serve",
                trace: Optional[str] = None, args: Optional[dict] = None
                ) -> None:
        if not self.enabled:
            return
        t = time.monotonic()
        self.record(name, t, t, cat=cat, trace=trace, args=args)

    def span(self, name: str, *, cat: str = "serve",
             trace: Optional[str] = None, args: Optional[dict] = None):
        """Context manager timing its body (no-op unless enabled)."""
        if not self.enabled:
            return _NULL
        return _LiveSpan(self, name, cat, trace, args)

    # ----------------------------------------------------------- export ---
    def drop_counts(self) -> Dict[str, int]:
        """Per-thread-name totals of ring entries evicted on wrap.

        A nonzero count means the exported trace is missing its oldest
        spans for that thread — before this existed the truncation was
        silent and a short-looking trace read as a short run."""
        out: Dict[str, int] = {}
        with self._reg_lock:
            for name, _, _, drops in self._rings:
                out[name] = out.get(name, 0) + drops[0]
        return out

    def publish_drop_counts(self) -> int:
        """Fold eviction counts into ``repro_trace_dropped_total{thread}``
        (delta since last publish; called from every export path so a
        scrape or snapshot always reflects current truncation)."""
        from . import metrics as _metrics
        c = _metrics.counter("repro_trace_dropped_total",
                             "trace ring entries evicted on wrap",
                             ("thread",))
        with self._reg_lock:
            rings = list(self._rings)
        published = 0
        for name, _, _, drops in rings:
            delta = drops[0] - drops[1]
            if delta > 0:
                drops[1] = drops[0]
                c.inc(delta, thread=name)
                published += delta
        return published

    def events(self) -> List[Span]:
        """Snapshot every thread's ring, oldest-first per thread."""
        self.publish_drop_counts()
        with self._reg_lock:
            rings = [(name, tid, list(ring)) for name, tid, ring, _
                     in self._rings]
        out: List[Span] = []
        for _, _, entries in rings:
            out.extend(Span(*e) for e in entries)
        return out

    def chrome_events(self, spans: Optional[List[Span]] = None,
                      pid: Optional[int] = None) -> List[dict]:
        """Spans as Chrome ``trace_event`` dicts (ts/dur in wall-clock
        microseconds)."""
        pid = os.getpid() if pid is None else pid
        out = []
        for s in (self.events() if spans is None else spans):
            args = dict(s.args) if s.args else {}
            if s.trace is not None:
                args["trace"] = s.trace
            ev = {"name": s.name, "cat": s.cat, "pid": pid, "tid": s.tid,
                  "ts": (s.t0 + self.epoch) * 1e6, "args": args}
            if s.t1 > s.t0:
                ev["ph"] = "X"
                ev["dur"] = (s.t1 - s.t0) * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            out.append(ev)
        out.sort(key=lambda e: e["ts"])
        return out

    def export_chrome_trace(self, path=None) -> List[dict]:
        """Dump all recorded spans as Chrome trace JSON; returns the
        event list (and writes ``{"traceEvents": [...]}`` to ``path``)."""
        events = self.chrome_events()
        if path is not None:
            import pathlib
            p = pathlib.Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(
                {"traceEvents": events, "displayTimeUnit": "ms"}))
        return events


# process-wide default tracer: what the serve path consults
TRACER = Tracer()
if os.environ.get(ENV_TRACE, "") not in ("", "0"):
    TRACER.enable(annotate=os.environ.get(ENV_ANNOTATE, "")
                  not in ("", "0"))


def get_tracer() -> Tracer:
    return TRACER


def enable_tracing(ring_size: Optional[int] = None,
                   annotate: Optional[bool] = None) -> Tracer:
    if ring_size is not None:
        TRACER.ring_size = ring_size
    return TRACER.enable(annotate=annotate)


def disable_tracing() -> None:
    TRACER.disable()


def tracing_enabled() -> bool:
    return TRACER.enabled


def export_chrome_trace(path=None) -> List[dict]:
    return TRACER.export_chrome_trace(path)


def merge_chrome_traces(event_lists: List[List[dict]], path=None
                        ) -> List[dict]:
    """Merge per-process Chrome event lists onto one timeline.

    Events already carry wall-clock timestamps and per-process ``pid``
    fields, so the merge is a sort; ``path`` writes the merged artifact
    (what ``dryrun --obs`` publishes for a pod).
    """
    merged: List[dict] = []
    for evs in event_lists:
        merged.extend(evs or [])
    merged.sort(key=lambda e: e.get("ts", 0.0))
    if path is not None:
        import pathlib
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(
            {"traceEvents": merged, "displayTimeUnit": "ms"}))
    return merged


# ------------------------------------------------------- trace analysis ----
def request_coverage(events: List[dict]) -> Dict[str, dict]:
    """Per-trace-id span coverage of the measured enqueue→resolve window.

    For every trace id, the window is [earliest span start, latest span
    end] and coverage is the union of its spans' intervals over that
    window — 1.0 means no unaccounted gap anywhere between a request
    entering ``submit`` and its future resolving.  The serve-bench
    ``--trace`` gate requires >= 0.95 for every sampled request.
    """
    per: Dict[str, List[tuple]] = {}
    for ev in events:
        trace = (ev.get("args") or {}).get("trace")
        if trace is None or ev.get("ph") != "X":
            continue
        t0 = ev["ts"]
        per.setdefault(trace, []).append((t0, t0 + ev.get("dur", 0.0)))
    out: Dict[str, dict] = {}
    for trace, ivals in per.items():
        ivals.sort()
        lo, hi = ivals[0][0], max(b for _, b in ivals)
        covered, cur_a, cur_b = 0.0, ivals[0][0], ivals[0][1]
        for a, b in ivals[1:]:
            if a > cur_b:
                covered += cur_b - cur_a
                cur_a, cur_b = a, b
            else:
                cur_b = max(cur_b, b)
        covered += cur_b - cur_a
        window = hi - lo
        out[trace] = {"window_us": window, "covered_us": covered,
                      "coverage": covered / window if window > 0 else 1.0,
                      "spans": len(ivals)}
    return out
