"""Observability for the serving stack: tracing, metrics, pod snapshots.

Import surface is deliberately flat — instrumented modules do
``from repro.obs import TRACER, metrics`` and nothing else.  This
package imports nothing from ``repro.serve``/``repro.tune``/
``repro.kernels`` (they import *us*), and defers every jax import, so
it is safe at any layer including ``launch.multihost`` pre-bootstrap.
"""
from .trace import (TRACER, Span, Tracer, disable_tracing, enable_tracing,
                    export_chrome_trace, get_tracer, merge_chrome_traces,
                    request_coverage, tracing_enabled)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_registry, note_static_fallback, warn_once)
from .quality import (CRITICAL, LEVELS, OK, SHADOW, WARN, AlertMachine,
                      ShadowScorer, get_shadow)
from .slo import MONITOR, SLO, SLOMonitor, get_monitor
from .server import ObsServer, validate_exposition
from .pod import (local_snapshot, merge_pod_trace, pod_quality_report,
                  pod_snapshot)

__all__ = [
    "TRACER", "Span", "Tracer", "enable_tracing", "disable_tracing",
    "tracing_enabled", "get_tracer", "export_chrome_trace",
    "merge_chrome_traces", "request_coverage",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "warn_once", "note_static_fallback",
    "SHADOW", "ShadowScorer", "AlertMachine", "get_shadow",
    "OK", "WARN", "CRITICAL", "LEVELS",
    "MONITOR", "SLO", "SLOMonitor", "get_monitor",
    "ObsServer", "validate_exposition",
    "local_snapshot", "pod_snapshot", "merge_pod_trace",
    "pod_quality_report",
]
