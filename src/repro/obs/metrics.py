"""Process-wide metrics registry with Prometheus text export.

Counters, gauges, and explicit-bucket histograms, each labeled (the
serve path labels by queue key, the kernel registry by kernel name and
params provenance).  Unlike tracing, metrics are **always on**: they are
a handful of dict updates per *batch* (not per row), which is noise next
to a dispatch, and the serving stack's health must be observable without
anyone having remembered to flip a flag.

``MetricsRegistry.dump()`` renders the Prometheus text exposition format
(scrape it, or diff two dumps in a test); ``collect()`` returns the same
data as JSON-able dicts (what ``obs.pod_snapshot`` all-gathers and the
``metrics_report`` CLI renders as markdown).

:func:`warn_once` is the degradation-visibility helper: the first time a
tag fires it logs a real ``logging`` warning (so silent fallbacks — an
adaptive controller quietly serving the static policy — become
diagnosable), and every occurrence counts in
``repro_obs_warnings_total`` regardless.
"""
from __future__ import annotations

import logging
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

LOG = logging.getLogger("repro.obs")

#: serve-path batch/request latency buckets (seconds): microseconds to
#: seconds, roughly 2.5x apart — wide enough for CPU CI and TPU pods
DEFAULT_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 1e-1, 2.5e-1, 5e-1, 1.0, 2.5)


def _escape(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def _fmt_value(v: float) -> str:
    """Prometheus sample-value rendering: the exposition format spells
    non-finite values ``NaN`` / ``+Inf`` / ``-Inf`` (``%g`` would emit
    ``nan``/``inf``, which real scrapers reject)."""
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return f"{v:g}"


def _label_str(names: Sequence[str], values: Tuple[str, ...],
               extra: str = "") -> str:
    parts = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str]):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._vals: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: dict) -> Tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def collect(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                    for k, v in sorted(self._vals.items())]

    def dump_lines(self) -> List[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            for k, v in sorted(self._vals.items()):
                out.append(f"{self.name}{_label_str(self.labelnames, k)} "
                           f"{_fmt_value(v)}")
        return out


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._vals.get(self._key(labels), 0.0))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._vals[self._key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + value

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._vals.get(self._key(labels), 0.0))


class Histogram(_Metric):
    """Explicit-bucket histogram: per-labelset cumulative bucket counts
    plus sum and count (the Prometheus histogram contract)."""

    kind = "histogram"

    def __init__(self, name, help, labelnames, buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        value = float(value)
        with self._lock:
            st = self._vals.get(k)
            if st is None:
                st = self._vals[k] = {
                    "counts": [0] * len(self.buckets), "sum": 0.0,
                    "count": 0}
            for i, b in enumerate(self.buckets):
                if value <= b:
                    st["counts"][i] += 1
            st["sum"] += value
            st["count"] += 1

    def snapshot(self, **labels) -> Optional[dict]:
        with self._lock:
            st = self._vals.get(self._key(labels))
            if st is None:
                return None
            return {"buckets": dict(zip(self.buckets, st["counts"])),
                    "sum": st["sum"], "count": st["count"]}

    def collect(self) -> List[dict]:
        with self._lock:
            return [{"labels": dict(zip(self.labelnames, k)),
                     "buckets": dict(zip(self.buckets, st["counts"])),
                     "sum": st["sum"], "count": st["count"]}
                    for k, st in sorted(self._vals.items())]

    def dump_lines(self) -> List[str]:
        out = [f"# HELP {self.name} {self.help}",
               f"# TYPE {self.name} {self.kind}"]
        with self._lock:
            for k, st in sorted(self._vals.items()):
                for b, c in zip(self.buckets, st["counts"]):
                    le = 'le="%g"' % b
                    out.append(
                        f"{self.name}_bucket"
                        f"{_label_str(self.labelnames, k, le)} {c}")
                inf = 'le="+Inf"'
                out.append(f"{self.name}_bucket"
                           f"{_label_str(self.labelnames, k, inf)}"
                           f" {st['count']}")
                out.append(f"{self.name}_sum"
                           f"{_label_str(self.labelnames, k)} "
                           f"{_fmt_value(st['sum'])}")
                out.append(f"{self.name}_count"
                           f"{_label_str(self.labelnames, k)} "
                           f"{st['count']}")
        return out


class MetricsRegistry:
    """Get-or-create metric families; one registry per process."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labelnames, **kw)
                return m
        if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} re-registered as {cls.__name__}"
                f"{tuple(labelnames)} but exists as "
                f"{type(m).__name__}{m.labelnames}")
        return m

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def dump(self) -> str:
        """Prometheus text exposition format."""
        with self._lock:
            metrics = [self._metrics[k] for k in sorted(self._metrics)]
        lines: List[str] = []
        for m in metrics:
            lines.extend(m.dump_lines())
        return "\n".join(lines) + ("\n" if lines else "")

    def collect(self) -> Dict[str, dict]:
        """JSON-able snapshot (pod_snapshot / metrics_report input)."""
        with self._lock:
            metrics = dict(self._metrics)
        return {name: {"type": m.kind, "help": m.help,
                       "values": m.collect()}
                for name, m in sorted(metrics.items())}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _REGISTRY


def counter(name, help="", labelnames=()) -> Counter:
    return _REGISTRY.counter(name, help, labelnames)


def gauge(name, help="", labelnames=()) -> Gauge:
    return _REGISTRY.gauge(name, help, labelnames)


def histogram(name, help="", labelnames=(),
              buckets=DEFAULT_BUCKETS) -> Histogram:
    return _REGISTRY.histogram(name, help, labelnames, buckets=buckets)


def dump() -> str:
    return _REGISTRY.dump()


# ------------------------------------------------------------- warn-once ---
_WARNED: set = set()
_WARN_LOCK = threading.Lock()


def warn_once(tag: str, message: str) -> None:
    """Log ``message`` the first time ``tag`` fires; count every firing.

    The counter (``repro_obs_warnings_total{tag}``) keeps degradations
    visible on a scrape even after the one log line scrolled away.
    """
    counter("repro_obs_warnings_total",
            "warn_once firings by tag", ("tag",)).inc(1, tag=tag)
    with _WARN_LOCK:
        if tag in _WARNED:
            return
        _WARNED.add(tag)
    LOG.warning(message)


def note_static_fallback(key: str, reason: str, detail: str = "") -> None:
    """An adaptive controller degraded to the static flush policy for
    ``key``.  Counted per occurrence, logged once per (key, reason) —
    before this existed the degradation was silent and undiagnosable."""
    counter("repro_controller_static_fallback_total",
            "adaptive-controller decisions degraded to the static policy",
            ("key", "reason")).inc(1, key=key, reason=reason)
    warn_once(f"static-fallback:{reason}:{key}",
              f"AdaptiveFlushController fell back to the static flush "
              f"policy for key {key!r} ({reason})"
              + (f": {detail}" if detail else ""))
