"""Scrapeable observability endpoint: a pod process, not just the CLI.

:class:`ObsServer` runs a stdlib ``ThreadingHTTPServer`` on a daemon
thread and serves:

* ``/metrics`` — the process metrics registry in Prometheus text
  exposition format (0.0.4).  Every scrape first re-evaluates tracked
  SLOs and publishes tracer drop counts, so exported gauges are never
  staler than the scrape interval.
* ``/healthz`` — readiness: 200 when every watched ``ServeQueue`` is
  live and no quality/SLO alert is CRITICAL, else 503 with a JSON body
  naming the offenders.  Point an orchestrator's readiness probe here.
* ``/varz`` — one JSON snapshot: process identity, queue liveness +
  per-key serve stats, quality + SLO state, collected metrics.
* ``/tracez`` — tracing status and the most recent spans (Chrome event
  dicts), with per-thread ring drop counts.

:func:`validate_exposition` is a minimal Prometheus text parser used by
CI (and the ``--validate`` CLI) to fail the build on malformed output:
it checks name/label syntax, escaped label values, ``NaN``/``±Inf``
sample values, duplicate samples, and the histogram contract
(monotonic cumulative buckets, ``+Inf`` bucket == ``_count``, ``_sum``
present).

CLI::

    python -m repro.obs.server --port 9151 --serve-for 60 --demo
    python -m repro.obs.server --validate scrape.prom
"""
from __future__ import annotations

import argparse
import http.server
import json
import math
import os
import re
import socket
import sys
import threading
import time
import traceback
from typing import Dict, List, Optional, Tuple

from . import metrics as _metrics
from .quality import CRITICAL, SHADOW
from .slo import MONITOR, SLO
from .trace import TRACER

ENV_OBS_PORT = "REPRO_OBS_PORT"

CONTENT_TYPE_METRICS = "text/plain; version=0.0.4; charset=utf-8"


class ObsServer:
    """Background HTTP endpoint over the process-wide obs singletons."""

    def __init__(self, *, host: str = "127.0.0.1", port: int = 0,
                 registry=None, tracer=None, tracez_limit: int = 512):
        self.host = host
        self.port = int(port)
        self.registry = registry or _metrics.default_registry()
        self.tracer = tracer or TRACER
        self.tracez_limit = int(tracez_limit)
        self._queues: Dict[str, object] = {}
        self._httpd: Optional[http.server.ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------- wiring ---
    def watch_queue(self, name: str, queue) -> "ObsServer":
        """Readiness tracks ``queue`` (duck-typed: ``healthy()`` +
        optional ``snapshot()``)."""
        self._queues[name] = queue
        return self

    def start(self) -> "ObsServer":
        if self._httpd is not None:
            return self
        handler = _make_handler(self)
        self._httpd = http.server.ThreadingHTTPServer(
            (self.host, self.port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-http",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        httpd, self._httpd = self._httpd, None
        if httpd is not None:
            httpd.shutdown()
            httpd.server_close()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5.0)

    def url(self, path: str = "/") -> str:
        return f"http://{self.host}:{self.port}{path}"

    # --------------------------------------------------------- payloads ---
    def _refresh(self) -> None:
        """Pre-scrape: re-evaluate SLOs, publish trace drop counts."""
        try:
            MONITOR.evaluate()
        except Exception as e:  # scrape must not 500 on a bad tracker
            _metrics.warn_once("obs-scrape-slo-eval",
                               f"SLO evaluation during scrape failed: "
                               f"{e!r}")
        self.tracer.publish_drop_counts()

    def metrics_text(self) -> str:
        self._refresh()
        return self.registry.dump()

    def health(self) -> Tuple[bool, dict]:
        quality = SHADOW.states()
        slo = MONITOR.states()
        critical = [f"quality:{k}" for k, s in sorted(quality.items())
                    if s == CRITICAL]
        critical += [f"slo:{k}:{obj}" for k, states in sorted(slo.items())
                     for obj, s in sorted(states.items()) if s == CRITICAL]
        queues = {}
        for name, q in sorted(self._queues.items()):
            try:
                ok = bool(q.healthy())
            except Exception:
                ok = False
            queues[name] = ok
        dead = [f"queue:{n}" for n, ok in queues.items() if not ok]
        for name, q in sorted(self._queues.items()):
            # tenancy-aware queues name misbehaving tenants (dropping
            # rows, stuck past their pending cap); duck-typed so plain
            # queues and stubs keep working
            offenders = getattr(q, "tenant_offenders", None)
            if offenders is None:
                continue
            try:
                dead += [f"tenant:{t}" for t in offenders()]
            except Exception:
                pass
        pod: dict = {}
        try:
            # lazy: multihost stays jax-free and obs must not force it in
            from repro.launch.multihost import POD_HEALTH
            pod = POD_HEALTH.snapshot()
        except Exception:
            pod = {}
        if pod.get("degraded"):
            dead += ([f"pod:host-{k}" for k in pod.get("offenders") or ()]
                     or ["pod:degraded"])
        ready = not critical and not dead
        return ready, {
            "status": "ok" if ready else "unhealthy",
            "critical": critical + dead,
            "queues": queues,
            "quality": quality,
            "slo": slo,
            "pod": pod,
        }

    def varz(self) -> dict:
        self._refresh()
        queues = {}
        for name, q in sorted(self._queues.items()):
            entry: dict = {}
            try:
                entry = q.snapshot()
            except Exception as e:
                entry = {"error": repr(e)}
            queues[name] = entry
        return {
            "pid": os.getpid(),
            "host": socket.gethostname(),
            "time_unix": time.time(),
            "tracing": self.tracer.enabled,
            "queues": queues,
            "quality": SHADOW.snapshot(),
            "slo": MONITOR.snapshot(),
            "metrics": self.registry.collect(),
        }

    def tracez(self) -> dict:
        events = self.tracer.chrome_events()
        return {
            "enabled": self.tracer.enabled,
            "dropped": self.tracer.drop_counts(),
            "total_events": len(events),
            "events": events[-self.tracez_limit:],
        }


def _make_handler(srv: ObsServer):
    class _Handler(http.server.BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # keep scrapes out of stderr
            pass

        def do_GET(self):
            path = self.path.split("?", 1)[0]
            try:
                if path == "/metrics":
                    body = srv.metrics_text().encode("utf-8")
                    code, ctype = 200, CONTENT_TYPE_METRICS
                elif path == "/healthz":
                    ready, detail = srv.health()
                    body = (json.dumps(detail, indent=1) + "\n").encode()
                    code = 200 if ready else 503
                    ctype = "application/json"
                elif path == "/varz":
                    body = (json.dumps(srv.varz(), indent=1, default=str)
                            + "\n").encode()
                    code, ctype = 200, "application/json"
                elif path == "/tracez":
                    body = (json.dumps(srv.tracez(), default=str)
                            + "\n").encode()
                    code, ctype = 200, "application/json"
                elif path == "/":
                    body = (b"repro obs endpoint\n"
                            b"routes: /metrics /healthz /varz /tracez\n")
                    code, ctype = 200, "text/plain"
                else:
                    body = b"not found\n"
                    code, ctype = 404, "text/plain"
            except Exception:  # a scrape must answer, never hang
                body = traceback.format_exc().encode("utf-8")
                code, ctype = 500, "text/plain"
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            try:
                self.wfile.write(body)
            except (BrokenPipeError, ConnectionResetError):
                pass

    return _Handler


# ------------------------------------------------- exposition validator ----
_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"       # metric name
    r"(?:\{(.*)\})?"                     # optional label body
    r"\s+(\S+)"                          # value
    r"(?:\s+(-?\d+))?$")                 # optional timestamp
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_VALUE_RE = re.compile(
    r"^[+-]?(?:\d+(?:\.\d*)?|\.\d+)(?:[eE][+-]?\d+)?$"
    r"|^[+-]?[Ii]nf$|^[Nn]a[Nn]$")


def _parse_value(s: str, lineno: int) -> float:
    if not _VALUE_RE.match(s):
        raise ValueError(f"line {lineno}: invalid sample value {s!r}")
    return float(s)


def _parse_labels(body: str, lineno: int) -> Dict[str, str]:
    labels: Dict[str, str] = {}
    i = 0
    while i < len(body):
        m = _LABEL_RE.match(body, i)
        if m is None:
            raise ValueError(
                f"line {lineno}: malformed label at offset {i}: "
                f"{body[i:i + 40]!r}")
        labels[m.group(1)] = (
            m.group(2).replace("\\\\", "\x00").replace('\\"', '"')
            .replace("\\n", "\n").replace("\x00", "\\"))
        i = m.end()
        if i < len(body):
            if body[i] != ",":
                raise ValueError(
                    f"line {lineno}: expected ',' between labels at "
                    f"offset {i}")
            i += 1
    return labels


def validate_exposition(text: str) -> dict:
    """Parse Prometheus text exposition format 0.0.4; raise ValueError
    (with line numbers) on malformed output.

    Beyond syntax it enforces the histogram contract per labelset:
    cumulative bucket counts must be non-decreasing in ``le``, the
    ``+Inf`` bucket must equal ``_count``, and ``_sum`` must be present.
    Returns ``{"samples": n, "families": {name: type}}``.
    """
    families: Dict[str, str] = {}
    samples: List[Tuple[str, frozenset, Dict[str, str], float]] = []
    seen: set = set()
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip()
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not _NAME_RE.match(parts[2]):
                raise ValueError(
                    f"line {lineno}: malformed {parts[1]} line: {raw!r}")
            if parts[1] == "TYPE":
                if len(parts) < 4 or parts[3] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped"):
                    raise ValueError(
                        f"line {lineno}: unknown metric type in {raw!r}")
                families[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # comment
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample: {raw!r}")
        name, label_body, value_s = m.group(1), m.group(2), m.group(3)
        labels = _parse_labels(label_body, lineno) if label_body else {}
        value = _parse_value(value_s, lineno)
        ident = (name, frozenset(labels.items()))
        if ident in seen:
            raise ValueError(
                f"line {lineno}: duplicate sample {name}{labels}")
        seen.add(ident)
        samples.append((name, ident[1], labels, value))
    _check_histograms(families, samples)
    return {"samples": len(samples), "families": dict(families)}


def _check_histograms(families: Dict[str, str], samples) -> None:
    hists = {n for n, t in families.items() if t == "histogram"}
    for base in hists:
        groups: Dict[frozenset, dict] = {}
        for name, _, labels, value in samples:
            if not name.startswith(base + "_"):
                continue
            suffix = name[len(base) + 1:]
            key = frozenset((k, v) for k, v in labels.items()
                            if k != "le")
            g = groups.setdefault(
                key, {"buckets": [], "sum": None, "count": None})
            if suffix == "bucket":
                le = labels.get("le")
                if le is None:
                    raise ValueError(
                        f"histogram {base}: bucket sample missing 'le'")
                g["buckets"].append((float(le), value))
            elif suffix == "sum":
                g["sum"] = value
            elif suffix == "count":
                g["count"] = value
        for key, g in groups.items():
            where = f"histogram {base}{dict(key) or ''}"
            if g["count"] is None:
                raise ValueError(f"{where}: missing _count")
            if g["sum"] is None:
                raise ValueError(f"{where}: missing _sum")
            if not g["buckets"]:
                raise ValueError(f"{where}: no buckets")
            g["buckets"].sort(key=lambda bc: bc[0])
            last_le, prev = g["buckets"][-1][0], -1.0
            for le, c in g["buckets"]:
                if c < prev:
                    raise ValueError(
                        f"{where}: bucket counts not cumulative at "
                        f"le={le:g}")
                prev = c
            if not math.isinf(last_le):
                raise ValueError(f"{where}: missing le=\"+Inf\" bucket")
            if g["buckets"][-1][1] != g["count"]:
                raise ValueError(
                    f"{where}: +Inf bucket ({g['buckets'][-1][1]:g}) != "
                    f"_count ({g['count']:g})")


# ----------------------------------------------------------------- demo ----
def _demo_workload() -> "object":
    """Populate the registry with a real serve round-trip + shadow
    scoring + a tracked SLO, so a scrape of the demo server exercises
    every family CI greps for.  Returns the queue (to watch)."""
    import tempfile

    import jax
    import numpy as np

    from repro.nn import MLP
    from repro.nn.serialize import load_model, save_model
    from repro.serve import FlushPolicy, ServeQueue

    tmp = tempfile.mkdtemp(prefix="repro-obs-demo-")
    net = MLP((1, 5), [32, 32], 1)
    params = net.init(jax.random.PRNGKey(0))
    path = save_model(os.path.join(tmp, "demo_bundle"), net, params)
    net, params, _ = load_model(path)
    ref = jax.jit(net.apply)

    q = ServeQueue(FlushPolicy(max_batch_rows=256, max_delay_s=0.05))
    q.start()
    SHADOW.enable(rate=1.0)
    SHADOW.set_budget(path, 0.05)
    MONITOR.track(path, q.stats(path),
                  SLO(latency_threshold_s=2.0, windows_s=(30.0, 120.0),
                      min_events=1))
    rng = np.random.default_rng(0)
    for _ in range(4):
        x = rng.standard_normal((8, 5)).astype(np.float32)
        fut = q.submit(path, x)
        q.flush(path, reason="demo")
        y = fut.result(30.0)
        SHADOW.submit(path, pred=lambda y=y: np.asarray(y),
                      ref=lambda x=x: np.asarray(ref(params, x)),
                      region="demo", rows=x.shape[0], trace=fut.trace)
    SHADOW.flush(30.0)
    MONITOR.evaluate()
    return q


def _self_check(server: ObsServer, expect_quality: bool) -> None:
    import urllib.request

    for route in ("/", "/healthz", "/varz", "/tracez"):
        with urllib.request.urlopen(server.url(route), timeout=10) as r:
            if r.status != 200:
                raise SystemExit(f"{route}: HTTP {r.status}")
    with urllib.request.urlopen(server.url("/metrics"), timeout=10) as r:
        text = r.read().decode("utf-8")
    info = validate_exposition(text)
    if expect_quality and "repro_quality_rmse" not in text:
        raise SystemExit("/metrics missing repro_quality_rmse")
    print(f"self-check ok: {info['samples']} samples, "
          f"{len(info['families'])} families")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.server",
        description="serve /metrics /healthz /varz /tracez, or validate "
                    "a Prometheus exposition file")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int,
                    default=int(os.environ.get(ENV_OBS_PORT, 0) or 0))
    ap.add_argument("--serve-for", type=float, default=0.0,
                    help="serve for N seconds then exit (0 = forever)")
    ap.add_argument("--demo", action="store_true",
                    help="populate the registry with a real serve "
                         "round-trip + shadow scoring before serving")
    ap.add_argument("--self-check", action="store_true",
                    help="scrape own routes once, validate, exit")
    ap.add_argument("--validate", default=None, metavar="PATH",
                    help="validate a Prometheus text file ('-' = stdin) "
                         "and exit")
    args = ap.parse_args(argv)

    if args.validate is not None:
        text = (sys.stdin.read() if args.validate == "-"
                else open(args.validate).read())
        try:
            info = validate_exposition(text)
        except ValueError as e:
            print(f"INVALID: {e}", file=sys.stderr)
            return 1
        print(f"valid exposition: {info['samples']} samples, "
              f"{len(info['families'])} families")
        return 0

    server = ObsServer(host=args.host, port=args.port)
    q = None
    if args.demo:
        q = _demo_workload()
        server.watch_queue("serve", q)
    server.start()
    print(f"obs endpoint on {server.url()} "
          f"(routes: /metrics /healthz /varz /tracez)", flush=True)
    try:
        if args.self_check:
            _self_check(server, expect_quality=args.demo)
            return 0
        if args.serve_for > 0:
            time.sleep(args.serve_for)
        else:  # pragma: no cover - interactive
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:  # pragma: no cover - interactive
        pass
    finally:
        server.stop()
        if q is not None:
            q.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
