"""Pod-wide flight recorder: all-gather every host's recent spans and
metrics so a stalled ``pod_flush`` is attributable to a specific host.

``pod_snapshot()`` serializes the local tracer ring + metrics registry
to JSON bytes, all-gathers them over the same machinery ``pod_flush``
already rides (``launch.multihost.allgather_bytes``), and returns one
dict per process.  Like every pod collective in this repo it is SPMD:
**all processes must call it together**, or the gather deadlocks.

Single-process (no ``jax.distributed``) it degrades to a one-element
list, so callers don't need to branch.
"""
from __future__ import annotations

import json
import os
import socket
from typing import List, Optional

from .metrics import default_registry
from .quality import SHADOW
from .slo import MONITOR
from .trace import TRACER, merge_chrome_traces


def _process_index() -> int:
    """Pod process id: the live jax value when distributed is up, else
    the bootstrap env var (obs must stay importable pre-bootstrap)."""
    try:
        import jax
        return int(jax.process_index())
    except Exception:
        return int(os.environ.get("REPRO_PROCESS_ID", 0) or 0)


def local_snapshot() -> dict:
    """This process's observability state as a JSON-able dict."""
    return {
        "process": _process_index(),
        "pid": os.getpid(),
        "host": socket.gethostname(),
        "events": TRACER.chrome_events(),
        "metrics": default_registry().collect(),
        "quality": SHADOW.snapshot(),
        "slo": MONITOR.snapshot(),
    }


def pod_snapshot() -> List[dict]:
    """All-gather every process's :func:`local_snapshot`.

    Collective: call from all pod processes together (same contract as
    ``ServeQueue.pod_flush``).  Returns the per-process snapshots in
    process order; index ``i`` is process ``i``'s view.
    """
    local = local_snapshot()
    try:
        import jax
        nproc = jax.process_count()
    except Exception:
        nproc = 1
    if nproc <= 1:
        return [local]
    from repro.launch.multihost import allgather_bytes
    blobs = allgather_bytes(json.dumps(local).encode("utf-8"))
    return [json.loads(b.decode("utf-8")) for b in blobs]


def merge_pod_trace(snapshots: List[dict], path: Optional[str] = None
                    ) -> List[dict]:
    """Merge per-host snapshot event lists into one Chrome trace (events
    already carry wall-clock ``ts`` and per-process ``pid``)."""
    return merge_chrome_traces(
        [s.get("events") or [] for s in snapshots], path)


def pod_quality_report(snapshots: List[dict]) -> str:
    """Cross-host drift table from ``pod_snapshot`` output: one row per
    (process, bundle) with the shadow RMSE EWMA and alert state — what
    ``multihost --obs`` prints so drift on *any* host is visible from
    the driver."""
    lines = ["| process | key | rmse ewma | state | samples |",
             "|---:|---|---:|---|---:|"]
    rows = 0
    for s in snapshots:
        keys = ((s.get("quality") or {}).get("keys") or {})
        for key, st in sorted(keys.items()):
            rmse = st.get("rmse_ewma")
            rmse_s = f"{rmse:.4g}" if rmse is not None else "-"
            lines.append(f"| {s.get('process', '?')} | {key} | {rmse_s} "
                         f"| {st.get('state', '?')} "
                         f"| {st.get('samples', 0)} |")
            rows += 1
    if not rows:
        return "(no shadow-quality samples on any host)"
    return "\n".join(lines)
