"""Online surrogate-quality monitoring: shadow scoring + drift alerts.

The paper's value claim is "speedup with minimal accuracy loss"; this
module makes the *accuracy loss* observable while serving, not just in
offline evaluation.  A :class:`ShadowScorer` samples a configurable
fraction of requests flowing through ``MLRegion`` infer paths
(``REPRO_SHADOW_RATE``, default off), replays the sampled rows through
the region's accurate function on a low-priority background thread, and
publishes per-bundle error metrics — RMSE, max-abs, relative-L2 — as
EWMAs plus a per-sample RMSE histogram in the process metrics registry.
Scoring rides the request's existing trace id as a ``quality.shadow``
span, so a Perfetto timeline shows which requests were shadow-scored
and what the replay cost.

Drift is judged by an :class:`AlertMachine` per bundle: OK → WARN →
CRITICAL against a per-bundle RMSE budget, with hysteresis (consecutive
breaches to escalate, consecutive clears plus a shrunken threshold to
de-escalate) so one bad batch doesn't flap the alert.  The same machine
class drives the SLO burn-rate alerts in :mod:`repro.obs.slo`, and the
``/healthz`` endpoint turns any CRITICAL state into a 503.

Budgets resolve through one chain: an explicit ``set_budget`` wins,
then the shared per-bundle registry :mod:`repro.quant.budgets` (the
same numbers the quant gate certifies int8 eligibility against — the
online drift alert and the offline quantization gate cannot disagree
about what "accurate enough" means), then the default budget.

Import contract: this module imports only stdlib + numpy +
``repro.obs.{metrics,trace}`` + ``repro.quant.budgets`` (itself
stdlib-only) — it is safe from ``core.region`` and pre-bootstrap.
"""
from __future__ import annotations

import math
import os
import queue as _queue
import random
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import metrics as _metrics
from .trace import TRACER

ENV_SHADOW_RATE = "REPRO_SHADOW_RATE"
ENV_RMSE_BUDGET = "REPRO_SHADOW_RMSE_BUDGET"

OK = "OK"
WARN = "WARN"
CRITICAL = "CRITICAL"
#: alert severity order — exported as the numeric gauge value
LEVELS: Dict[str, int] = {OK: 0, WARN: 1, CRITICAL: 2}

#: per-sample RMSE histogram buckets: the paper's "as low as 0.01 RMSE"
#: regime sits mid-range, decades on either side for drift headroom
ERROR_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.5, 1.0, 10.0)


class AlertMachine:
    """Hysteretic OK → WARN → CRITICAL ladder.

    Escalation requires ``breach_n`` *consecutive* evaluations whose
    candidate level exceeds the current state; de-escalation requires
    ``clear_n`` consecutive evaluations below it, and a level already
    latched keeps its threshold shrunk by ``hysteresis`` — so a value
    oscillating right at the budget neither raises nor clears the alert
    on every sample.
    """

    def __init__(self, *, breach_n: int = 3, clear_n: int = 5,
                 hysteresis: float = 0.8):
        self.breach_n = int(breach_n)
        self.clear_n = int(clear_n)
        self.hysteresis = float(hysteresis)
        self.state = OK
        self.transitions = 0
        self._up = 0
        self._down = 0

    def _candidate(self, value: float,
                   warn_at: Optional[float],
                   crit_at: Optional[float]) -> str:
        cur = LEVELS[self.state]

        def eff(at: float, latched: bool) -> float:
            return at * self.hysteresis if latched else at

        if crit_at is not None and value >= eff(crit_at, cur >= 2):
            return CRITICAL
        if warn_at is not None and value >= eff(warn_at, cur >= 1):
            return WARN
        return OK

    def step(self, value: float, warn_at: Optional[float],
             crit_at: Optional[float]) -> str:
        """Feed one evaluation; returns the (possibly new) state."""
        if warn_at is None and crit_at is None:
            return self.state  # no budget -> no alerting
        cand = self._candidate(float(value), warn_at, crit_at)
        cur, new = LEVELS[self.state], LEVELS[cand]
        if new > cur:
            self._up += 1
            self._down = 0
            if self._up >= self.breach_n:
                self.state = cand
                self.transitions += 1
                self._up = 0
        elif new < cur:
            self._down += 1
            self._up = 0
            if self._down >= self.clear_n:
                self.state = cand
                self.transitions += 1
                self._down = 0
        else:
            self._up = self._down = 0
        return self.state


class _KeyState:
    __slots__ = ("rmse", "max_abs", "rel_l2", "samples", "rows", "machine")

    def __init__(self):
        self.rmse: Optional[float] = None
        self.max_abs: Optional[float] = None
        self.rel_l2: Optional[float] = None
        self.samples = 0
        self.rows = 0
        self.machine = AlertMachine()


class ShadowScorer:
    """Sampled online accuracy scoring against the accurate function.

    The serve path calls :meth:`sample` (one attribute read + one
    ``random.random`` when enabled; a single attribute check when not)
    and, on a hit, :meth:`submit` with two thunks: ``pred`` yields the
    surrogate's output rows (may block on a serve future), ``ref``
    recomputes the accurate output from a snapshot of the inputs.  Both
    run later on the scorer's single daemon worker — the accurate
    function's cost never lands on the serving path.  The backlog is
    bounded: when the worker falls behind, new samples are *dropped and
    counted* (``repro_quality_dropped_total{key,reason}``) rather than
    growing an unbounded queue.
    """

    EWMA_ALPHA = 0.25
    #: scoring a sample waits until it is at least this old — the replay
    #: runs after the serving burst that produced it, not during it, so
    #: the worker's GIL time does not contend with in-flight dispatches
    MIN_AGE_S = 0.05
    #: the worker sleeps after each sample to cap its CPU share at this
    #: fraction (scoring throughput degrades to counted backlog drops
    #: under sustained load, never to serve-path contention)
    DUTY_CYCLE = 0.5

    def __init__(self, rate: float = 0.0, max_backlog: int = 256):
        self.rate = float(rate)
        self.enabled = self.rate > 0.0
        self.max_backlog = int(max_backlog)
        self._lock = threading.Lock()
        self._q: "_queue.Queue[Optional[tuple]]" = _queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._pending = 0
        self._keys: Dict[str, _KeyState] = {}
        self._budgets: Dict[str, Tuple[float, float]] = {}
        self._default_budget: Optional[Tuple[float, float]] = None
        self._m_rmse = _metrics.gauge(
            "repro_quality_rmse",
            "shadow-scored RMSE EWMA per bundle", ("key",))
        self._m_max_abs = _metrics.gauge(
            "repro_quality_max_abs",
            "shadow-scored max-abs-error EWMA per bundle", ("key",))
        self._m_rel_l2 = _metrics.gauge(
            "repro_quality_rel_l2",
            "shadow-scored relative-L2 EWMA per bundle", ("key",))
        self._m_state = _metrics.gauge(
            "repro_quality_alert_state",
            "drift alert state per bundle (0=OK 1=WARN 2=CRITICAL)",
            ("key",))
        self._m_samples = _metrics.counter(
            "repro_quality_samples_total",
            "shadow samples scored", ("key", "region"))
        self._m_rows = _metrics.counter(
            "repro_quality_rows_total",
            "rows shadow-scored", ("key", "region"))
        self._m_dropped = _metrics.counter(
            "repro_quality_dropped_total",
            "shadow samples dropped before scoring", ("key", "reason"))
        self._m_rmse_hist = _metrics.histogram(
            "repro_quality_rmse_per_sample",
            "per-sample shadow RMSE", ("key",), buckets=ERROR_BUCKETS)
        self._m_score_s = _metrics.histogram(
            "repro_quality_shadow_seconds",
            "worker time scoring one shadow sample", ("key",))

    # ---------------------------------------------------------- control ---
    def enable(self, rate: Optional[float] = None) -> "ShadowScorer":
        if rate is not None:
            self.rate = float(rate)
        self.enabled = self.rate > 0.0
        return self

    def disable(self) -> None:
        self.enabled = False

    def set_budget(self, key: str, rmse_budget: float,
                   warn_ratio: float = 0.5) -> None:
        """RMSE past ``rmse_budget`` is CRITICAL (after hysteresis);
        past ``warn_ratio * rmse_budget`` is WARN."""
        b = (float(rmse_budget) * float(warn_ratio), float(rmse_budget))
        with self._lock:
            self._budgets[key] = b

    def set_default_budget(self, rmse_budget: Optional[float],
                           warn_ratio: float = 0.5) -> None:
        with self._lock:
            if rmse_budget is None:
                self._default_budget = None
            else:
                self._default_budget = (
                    float(rmse_budget) * float(warn_ratio),
                    float(rmse_budget))

    def reset(self) -> None:
        """Forget per-key scores, budgets, and alert states (tests)."""
        with self._lock:
            self._keys.clear()
            self._budgets.clear()
            self._default_budget = None

    def _budget_for_locked(self, key: str) -> Tuple:
        """(warn_at, crit_at) for a key: explicit ``set_budget`` wins,
        then the shared registry (:mod:`repro.quant.budgets` — the quant
        gate's numbers), then the default budget."""
        b = self._budgets.get(key)
        if b is not None:
            return b
        from repro.quant.budgets import budget_pair
        b = budget_pair(key)
        if b is not None:
            return b
        return self._default_budget or (None, None)

    # --------------------------------------------------------- sampling ---
    def sample(self) -> bool:
        """Bernoulli sampling decision for one request."""
        return self.enabled and random.random() < self.rate

    def submit(self, key: str, *, pred: Callable[[], np.ndarray],
               ref: Callable[[], np.ndarray], region: str = "-",
               rows: int = 1, trace: Optional[str] = None) -> bool:
        """Enqueue one sampled request for background scoring.

        Returns False (and counts a drop) when the backlog is full —
        shadow scoring degrades by skipping samples, never by stalling
        the caller.
        """
        with self._lock:
            if self._pending >= self.max_backlog:
                dropped = True
            else:
                dropped = False
                self._pending += 1
                self._ensure_thread_locked()
        if dropped:
            self._m_dropped.inc(1, key=key, reason="backlog")
            return False
        self._q.put((key, region, pred, ref, int(rows), trace,
                     time.monotonic()))
        return True

    def _ensure_thread_locked(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="repro-shadow-score", daemon=True)
            self._thread.start()

    # ----------------------------------------------------------- worker ---
    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            key, region, pred, ref, rows, trace, t_sub = item
            # low priority, part 1: let the burst that sampled this
            # request finish serving before the replay takes any CPU
            age_left = self.MIN_AGE_S - (time.monotonic() - t_sub)
            if age_left > 0:
                time.sleep(age_left)
            t0 = time.monotonic()
            try:
                with TRACER.span("quality.shadow", cat="quality",
                                 trace=trace,
                                 args={"key": key, "region": region}):
                    yp = np.asarray(pred())
                    yr = np.asarray(ref())
                    if yp.size != yr.size:
                        self._m_dropped.inc(1, key=key, reason="shape")
                    else:
                        self._score(key, region, yp,
                                    yr.reshape(yp.shape), rows)
            except Exception as e:  # replay must never kill the worker
                self._m_dropped.inc(1, key=key, reason="error")
                _metrics.warn_once(
                    f"shadow-score-error:{key}",
                    f"shadow scoring failed for bundle {key!r}: {e!r}")
            finally:
                busy = time.monotonic() - t0
                with self._lock:
                    self._pending -= 1
                self._m_score_s.observe(busy, key=key)
                # low priority, part 2: duty-cycle cap — sleep in
                # proportion to the time just spent scoring so the
                # worker never takes more than DUTY_CYCLE of a core
                d = self.DUTY_CYCLE
                time.sleep(min(0.1, busy * (1.0 - d) / d))

    def _score(self, key: str, region: str, yp: np.ndarray,
               yr: np.ndarray, rows: int) -> None:
        d = yp.astype(np.float64) - yr.astype(np.float64)
        rmse = float(np.sqrt(np.mean(np.square(d)))) if d.size else 0.0
        max_abs = float(np.max(np.abs(d))) if d.size else 0.0
        denom = float(np.linalg.norm(yr.astype(np.float64).ravel()))
        rel_l2 = float(np.linalg.norm(d.ravel()) / max(denom, 1e-12))
        self.observe(key, rmse=rmse, max_abs=max_abs, rel_l2=rel_l2,
                     rows=rows, region=region)

    # ---------------------------------------------------------- scoring ---
    def observe(self, key: str, *, rmse: float, max_abs: float = 0.0,
                rel_l2: float = 0.0, rows: int = 1, region: str = "-"
                ) -> str:
        """Fold one scored sample into the EWMAs + alert machine.

        Public so benches and tests can inject scores without a worker
        round-trip; returns the (possibly new) alert state.
        """
        a = self.EWMA_ALPHA
        with self._lock:
            st = self._keys.get(key)
            if st is None:
                st = self._keys[key] = _KeyState()
            for attr, v in (("rmse", rmse), ("max_abs", max_abs),
                            ("rel_l2", rel_l2)):
                cur = getattr(st, attr)
                v = float(v)
                setattr(st, attr, v if cur is None or math.isnan(cur)
                        else cur + a * (v - cur))
            st.samples += 1
            st.rows += int(rows)
            warn_at, crit_at = self._budget_for_locked(key)
            state = st.machine.step(st.rmse, warn_at, crit_at)
            vals = (st.rmse, st.max_abs, st.rel_l2)
        self._m_rmse.set(vals[0], key=key)
        self._m_max_abs.set(vals[1], key=key)
        self._m_rel_l2.set(vals[2], key=key)
        self._m_state.set(LEVELS[state], key=key)
        self._m_samples.inc(1, key=key, region=region)
        self._m_rows.inc(rows, key=key, region=region)
        self._m_rmse_hist.observe(rmse, key=key)
        return state

    # ------------------------------------------------------------ export ---
    def flush(self, timeout: float = 10.0) -> bool:
        """Wait until every submitted sample has been scored."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._pending == 0:
                    return True
            time.sleep(0.002)
        return False

    def stop(self) -> None:
        """Stop the worker thread (tests; restarts lazily on submit)."""
        t = self._thread
        if t is not None and t.is_alive():
            self._q.put(None)
            t.join(timeout=5.0)
        self._thread = None

    def close(self, drain: bool = True, *, timeout: float = 30.0) -> None:
        """Orderly shutdown (``ServeQueue.close`` calls this last).

        Disables sampling so no new replays enqueue, optionally drains
        the backlog (``drain=True`` waits up to ``timeout``), then stops
        the worker — interpreter teardown can no longer race a
        mid-replay scorer.  The worker restarts lazily if the scorer is
        re-enabled and submitted to afterwards (tests reuse the
        singleton), so close is safe to call more than once.
        """
        self.disable()
        if drain:
            self.flush(timeout)
        self.stop()

    def state(self, key: str) -> str:
        with self._lock:
            st = self._keys.get(key)
            return st.machine.state if st is not None else OK

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {k: st.machine.state for k, st in self._keys.items()}

    def worst_state(self) -> str:
        states = self.states().values()
        worst = max((LEVELS[s] for s in states), default=0)
        return next(name for name, lv in LEVELS.items() if lv == worst)

    def snapshot(self) -> dict:
        """JSON-able quality state (what ``pod_snapshot`` all-gathers)."""
        with self._lock:
            keys = {
                k: {"rmse_ewma": st.rmse, "max_abs_ewma": st.max_abs,
                    "rel_l2_ewma": st.rel_l2, "samples": st.samples,
                    "rows": st.rows, "state": st.machine.state,
                    "transitions": st.machine.transitions,
                    "budget_rmse": self._budget_for_locked(k)[1]}
                for k, st in self._keys.items()}
            rate = self.rate if self.enabled else 0.0
        return {"enabled": self.enabled, "rate": rate, "keys": keys}


#: process-wide scorer: what MLRegion consults (mirrors obs.TRACER)
SHADOW = ShadowScorer(
    rate=float(os.environ.get(ENV_SHADOW_RATE, "0") or 0.0))
if os.environ.get(ENV_RMSE_BUDGET, ""):
    SHADOW.set_default_budget(float(os.environ[ENV_RMSE_BUDGET]))


def get_shadow() -> ShadowScorer:
    return SHADOW
