"""Logical-axis sharding: contexts, constraint lowering, spec derivation.

Model and runtime code never names physical mesh axes.  It names *logical*
axes — "batch", "seq", "ffn", "vocab", ... — and an active :class:`ShardCtx`
(installed with :func:`use_mesh`) resolves them against whatever mesh is
live.  Resolution is per-dimension and degrades gracefully:

  * no active mesh            -> :func:`constrain` is a no-op (eager CPU
                                 tests and eager region calls keep working);
  * axis absent / size 1      -> that dimension replicates;
  * size not divisible        -> candidate axes are dropped outer-first
                                 until the remainder divides (never crashes);
  * axis already claimed      -> later dimensions of the same spec fall
                                 through to their next candidate (e.g. MoE:
                                 "experts" takes "model" when E divides it,
                                 otherwise the feature dim takes it).

Logical -> physical mapping (mesh axes: "pod", "data", "model"):

  batch/data -> (pod,) data      fsdp    -> data      (ZeRO-style weights)
  seq        -> model            kvseq   -> model     (decode KV cache)
  longseq    -> data+model       heads/ffn/vocab/dinner/experts -> model

Because "seq" and "ffn" both map to "model", a constraint listing both
(`constrain(h, "batch", "seq", "ffn")`) is claimed left-to-right: training
and prefill run sequence-parallel, while decode (seq dim of 1 is never
divisible) falls through to tensor-parallel on the ffn dim — one constraint
string serves both regimes.

``param_spec_tree`` / ``cache_spec_tree`` derive PartitionSpec pytrees for
LM params (and their mirrored optimizer-state copies) and KV caches from
the *name* of each leaf, right-aligned to its rank, so vmapped layer stacks
(leading repeat axis) and optimizer mirrors need no special-casing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ------------------------------------------------------- logical mapping ---

# ordered outer -> inner; resolution drops candidates outer-first
_LOGICAL_TO_MESH = {
    "batch": ("pod", "data"),
    "data": ("pod", "data"),
    "fsdp": ("data",),
    "seq": ("model",),
    "kvseq": ("model",),
    "longseq": ("data", "model"),
    "heads": ("model",),
    "ffn": ("model",),
    "dinner": ("model",),
    "vocab": ("model",),
    "model": ("model",),
    "experts": ("model",),
}


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """The active mesh + axis roles. Immutable; cheap to construct."""

    mesh: Any = None
    multi_pod: bool = False

    def _candidates(self, logical: str) -> Tuple[str, ...]:
        axes = _LOGICAL_TO_MESH.get(logical, ())
        if not self.multi_pod:
            axes = tuple(a for a in axes if a != "pod")
        if self.mesh is None:
            return ()
        return tuple(a for a in axes
                     if self.mesh.shape.get(a, 1) > 1)

    def axis_size(self, logical: str) -> int:
        """Total shard count a logical axis resolves to (1 if unmapped)."""
        n = 1
        for a in self._candidates(logical):
            n *= self.mesh.shape[a]
        return n

    def mesh_axes_for(self, logical: str) -> Tuple[str, ...]:
        """Physical mesh axes (size > 1) a logical axis maps onto —
        what shard_map wrappers hand to their in/out specs."""
        return self._candidates(logical)

    def local_axis_size(self, logical: str) -> int:
        """Shards of a logical axis owned by THIS process.

        Equals :meth:`axis_size` in single-process runs; on a pod mesh
        the ``pod`` axis spans processes, so a per-host data slab only
        has to divide by the *local* extent (``mesh.local_mesh``) —
        sizing it against the global shard count would force every host
        to pad to the whole pod's width.
        """
        n = 1
        local = getattr(self.mesh, "local_mesh", None)
        local_shape = dict(local.shape) if local is not None else {}
        for a in self._candidates(logical):
            n *= local_shape.get(a, self.mesh.shape[a])
        return n

    def make_global(self, local_rows, logical_axes, *, global_shape=None):
        """Assemble a (possibly cross-process) global array from this
        process's local block.

        ``local_rows`` is the data this process contributes — in a pod,
        its slab of the leading (batch) dimension; ``global_shape`` is
        the full array's shape (defaults to the local shape, which is
        only correct single-process).  Multi-process assembly goes
        through ``jax.make_array_from_process_local_data`` so the result
        is a global jax.Array whose addressable shards are exactly this
        host's rows; single-process it degrades to a plain sharded
        ``device_put``.  Either way the array is placed under the
        resolved sharding for ``logical_axes`` — the per-host feeding
        primitive for the ``pod`` axis.
        """
        import numpy as np
        x = np.asarray(local_rows)
        if self.mesh is None:
            return x
        shape = tuple(global_shape) if global_shape is not None else x.shape
        sharding = NamedSharding(self.mesh, self.spec_for(
            shape, tuple(logical_axes)))
        if jax.process_count() == 1:
            if shape != x.shape:
                raise ValueError(
                    f"make_global: single-process local block {x.shape} "
                    f"must equal the global shape {shape}")
            return jax.device_put(x, sharding)
        return jax.make_array_from_process_local_data(sharding, x, shape)

    def spec_for(self, shape: Sequence[int],
                 logical_axes: Sequence[Optional[str]]) -> P:
        """PartitionSpec for `shape`, one logical name (or None) per dim."""
        assert len(shape) == len(logical_axes), (shape, logical_axes)
        used: set = set()
        entries = []
        for dim, name in zip(shape, logical_axes):
            if name is None or self.mesh is None:
                entries.append(None)
                continue
            cand = [a for a in self._candidates(name) if a not in used]
            while cand:
                n = 1
                for a in cand:
                    n *= self.mesh.shape[a]
                if n > 1 and dim % n == 0:
                    break
                cand = cand[1:]  # drop outermost first
            if not cand:
                entries.append(None)
                continue
            used.update(cand)
            entries.append(cand[0] if len(cand) == 1 else tuple(cand))
        return P(*entries)

    def sharding_for(self, shape, logical_axes) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(shape, logical_axes))


# ------------------------------------------------------------- context -----

_state = threading.local()


def _stack():
    if not hasattr(_state, "ctxs"):
        _state.ctxs = []
    return _state.ctxs


def current_ctx() -> Optional[ShardCtx]:
    """The innermost active ShardCtx, or None outside any use_mesh()."""
    s = _stack()
    return s[-1] if s else None


@contextlib.contextmanager
def use_mesh(mesh, multi_pod: bool = False):
    """Install `mesh` as the active sharding context for this thread."""
    ctx = ShardCtx(mesh, multi_pod)
    s = _stack()
    s.append(ctx)
    try:
        yield ctx
    finally:
        s.pop()


def constrain(x, *logical_axes):
    """`with_sharding_constraint` under an active mesh; no-op otherwise.

    Applies only to tracers: eager arrays pass through untouched, so the
    same model code runs in plain-CPU tests, eager region calls, and
    sharded jit programs.
    """
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    if not isinstance(x, jax.core.Tracer):
        return x
    spec = ctx.spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ----------------------------------------------------- spec derivation -----

# trailing-dim logical axes per parameter leaf name (right-aligned, so the
# vmapped stack's leading repeat axis and fp32 optimizer mirrors just work)
_PARAM_RULES = {
    "tok_embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    # dense / GLU MLPs (also the serve-time FFN surrogate w1/w2)
    "w1": ("fsdp", "ffn"), "w3": ("fsdp", "ffn"), "w2": ("ffn", "fsdp"),
    "w_up": ("fsdp", "ffn"), "w_down": ("ffn", "fsdp"),
    "wk_cm": ("fsdp", "ffn"), "wv_cm": ("ffn", "fsdp"), "wr_cm": ("fsdp", None),
    # attention (gqa + cross + mla)
    "wq": ("fsdp", "heads"), "wk": ("fsdp", "heads"), "wv": ("fsdp", "heads"),
    "wo": ("heads", "fsdp"),
    "w_q": ("fsdp", "heads"), "w_dkv": ("fsdp", None), "w_kr": ("fsdp", None),
    "w_ukv": (None, "heads"),
    # rwkv6
    "wr_tm": ("fsdp", "heads"), "wk_tm": ("fsdp", "heads"),
    "wv_tm": ("fsdp", "heads"), "wg_tm": ("fsdp", "heads"),
    "lora_a_mix": ("fsdp", None), "lora_b_mix": (None, None, "heads"),
    "lora_a_w": ("fsdp", None), "lora_b_w": (None, "heads"),
    # mamba
    "w_in": ("fsdp", "dinner"), "conv_w": (None, "dinner"),
    "w_x": ("dinner", None), "w_dt": (None, "dinner"),
    "w_out": ("dinner", "fsdp"),
    # moe: experts take "model" (EP) when E divides it; otherwise the
    # ffn dim claims it (matches the dispatch constraints in blocks.py)
    "w_router": ("fsdp", None),
    "we1": ("experts", "fsdp", "ffn"), "we3": ("experts", "fsdp", "ffn"),
    "we2": ("experts", "ffn", "fsdp"),
    "ws1": ("fsdp", "ffn"), "ws3": ("fsdp", "ffn"), "ws2": ("ffn", "fsdp"),
}


def _cache_rules(long_ctx: bool):
    seq = "longseq" if long_ctx else "kvseq"
    return {
        "k": ("batch", seq, None, None),
        "v": ("batch", seq, None, None),
        "k_scale": ("batch", seq, None),
        "v_scale": ("batch", seq, None),
        "ckv": ("batch", seq, None),
        "kr": ("batch", seq, None),
        "S": ("batch", "heads", None, None),
        "x_last": ("batch", None),
        "conv": ("batch", None, "dinner"),
        "h": ("batch", "dinner", None),
        "cm_x_last": ("batch", None, None),
        "cross_k": ("batch", None, None, None),
        "cross_v": ("batch", None, None, None),
    }


def _leaf_name(path) -> Optional[str]:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return None


def _spec_from_rules(ctx: ShardCtx, rules: dict, path, leaf) -> P:
    shape = tuple(leaf.shape)
    if not shape:
        return P()
    rule = rules.get(_leaf_name(path))
    if rule is None:
        # unknown leaves (norm scales, biases, mixing coefficients, ...)
        # replicate: sharding decisions stay explicit, replication is
        # always correct
        return P(*([None] * len(shape)))
    n = len(shape)
    axes = rule[-n:] if len(rule) >= n else (None,) * (n - len(rule)) + tuple(rule)
    return ctx.spec_for(shape, axes)


def param_spec_tree(tree, cfg, mesh=None, multi_pod: bool = False):
    """PartitionSpec pytree for LM params or a full train state.

    `tree` is any pytree of arrays/ShapeDtypeStructs whose leaf *names*
    follow models/lm.py + optim/adamw.py (optimizer m/v/master mirrors the
    param names, so one rule table covers both).  `cfg` is accepted for
    call-site symmetry with cache_spec_tree; rules are name-driven.
    """
    del cfg
    ctx = ShardCtx(mesh, multi_pod)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_from_rules(ctx, _PARAM_RULES, path, leaf),
        tree)


def cache_spec_tree(tree, cfg, mesh=None, multi_pod: bool = False, *,
                    long_ctx: bool = False):
    """PartitionSpec pytree for decode caches (models/lm.py layout).

    `long_ctx=True` switches the KV sequence dim from "kvseq" (model axis)
    to "longseq" (data+model): the 500k-context cell has global batch 1, so
    the batch dim replicates and the sequence dim takes every chip.
    """
    del cfg
    ctx = ShardCtx(mesh, multi_pod)
    rules = _cache_rules(long_ctx)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_from_rules(ctx, rules, path, leaf),
        tree)
