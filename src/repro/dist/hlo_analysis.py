"""Post-compile HLO analysis: collective traffic + roofline estimation.

``collective_stats`` parses compiled HLO text (or a jax ``Compiled`` object)
and accounts the bytes each cross-chip collective moves:

  * all-gather       -> full gathered (output) size
  * reduce-scatter   -> full reduced (operand) size
  * all-reduce       -> 2x tensor size (ring = reduce-scatter + all-gather)
  * all-to-all /
    collective-permute -> tensor size, counted once

Async pairs are counted at the ``-start`` op; ``-done`` ops are ignored so
nothing is double-counted.  ``corrected_bytes`` re-prices f32/f64
collectives at 2 bytes/element: the CPU dry-run backend emulates bf16
arithmetic via f32 converts, so its HLO moves f32 over the wire where the
TPU program moves bf16.

``Roofline`` turns (FLOPs, HBM bytes, collective bytes) into the three
classic time terms against per-chip peaks (defaults are v5e-like: 197
TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip) and reports the
dominant bottleneck, the step-time bound, and the achievable-MFU bound.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_WIRE_F32_AS_BF16 = {"f32": 2, "f64": 2}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# opcode immediately followed by "(" (optionally via "-start"); "-done"
# variants never match and async work is attributed to the start op
_OP_RE = re.compile(
    r"(?<![\w-])(" + "|".join(_COLLECTIVES) + r")(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes_list(text: str, dtype_bytes: Dict[str, int]) -> list:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * dtype_bytes.get(dtype, _DTYPE_BYTES[dtype]))
    return out


@dataclasses.dataclass
class CollectiveStats:
    """Per-kind collective counts and wire bytes for one HLO module."""

    per_kind_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    per_kind_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    per_kind_corrected: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.per_kind_bytes.values()))

    @property
    def corrected_bytes(self) -> float:
        """Total bytes with f32/f64 re-priced as bf16 on the wire."""
        return float(sum(self.per_kind_corrected.values()))

    def __str__(self) -> str:
        parts = [f"{k}: n={self.per_kind_count[k]} "
                 f"{self.per_kind_bytes[k]/1e9:.3f}GB"
                 for k in sorted(self.per_kind_count)]
        return "CollectiveStats(" + ", ".join(parts) + ")"


def collective_stats(hlo) -> CollectiveStats:
    """Extract collective traffic from HLO text or a Lowered/Compiled."""
    if hasattr(hlo, "as_text"):
        hlo = hlo.as_text()
    st = CollectiveStats()
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        clean = re.sub(r'"[^"]*"', "", line)  # drop metadata strings
        cut = clean.find(m.group(0))
        left, right = clean[:cut], clean[cut:]
        for prices, acc in ((_DTYPE_BYTES, st.per_kind_bytes),
                            ({**_DTYPE_BYTES, **_WIRE_F32_AS_BF16},
                             st.per_kind_corrected)):
            in_bytes = sum(_shape_bytes_list(right, prices))
            if m.group(2):
                # async: the -start result tuple aliases the operand AND
                # carries the full result, so summing the left side would
                # double-count — but for all-gather the operand is only
                # the shard, so the largest single left-side shape (the
                # gathered result) is the honest wire size
                left_shapes = _shape_bytes_list(left, prices)
                out_bytes = max(left_shapes, default=0)
            else:
                out_bytes = sum(_shape_bytes_list(left, prices))
            b = max(in_bytes, out_bytes)
            if kind == "all-reduce":
                b *= 2
            acc[kind] = acc.get(kind, 0) + b
        st.per_kind_count[kind] = st.per_kind_count.get(kind, 0) + 1
    return st


# ----------------------------------------------------------- roofline ------

PEAK_FLOPS = 197e12   # per-chip bf16 FLOP/s
HBM_BW = 819e9        # per-chip HBM bytes/s
ICI_BW = 50e9         # per-chip interconnect bytes/s


@dataclasses.dataclass
class Roofline:
    """Three-term roofline over *global* (all-chip) resource totals.

    model_flops is the analytic useful work (6ND / 2ND); the HLO FLOP
    count includes remat recompute, so useful_flops_fraction < 1 and the
    achievable MFU is bounded by useful-compute-time / step-time.
    """

    flops_global: float
    hbm_bytes_global: float
    coll_bytes_global: float
    chips: int
    model_flops: float
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops_global / self.chips / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_global / self.chips / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_global / self.chips / self.ici_bw

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / self.flops_global if self.flops_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Best achievable MFU at the roofline step time."""
        if self.step_time_s <= 0:
            return 0.0
        useful_s = self.model_flops / self.chips / self.peak_flops
        return useful_s / self.step_time_s

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "step_time_s": self.step_time_s,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "chips": self.chips,
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
        }
