"""repro.dist: the distributed-execution substrate.

Two modules:

``sharding``
    Logical-axis sharding contexts.  Model/runtime code names *logical*
    axes ("batch", "seq", "ffn", ...); an active :class:`ShardCtx`
    (installed by :func:`use_mesh`) resolves them to the physical mesh
    axes ("pod", "data", "model") with per-dimension divisibility
    fallback, so the same traced program runs on 1 CPU device, a local
    test mesh, or a 512-chip dry-run mesh without edits.

``hlo_analysis``
    Post-compile analysis: a parser extracting collective-communication
    counts/bytes from compiled HLO, and a three-term (compute / HBM /
    interconnect) :class:`Roofline` estimator.

See ``README.md`` in this directory for the axis model.
"""
from repro.dist.hlo_analysis import CollectiveStats, Roofline, collective_stats
from repro.dist.sharding import (ShardCtx, cache_spec_tree, constrain,
                                 current_ctx, param_spec_tree, use_mesh)

__all__ = [
    "CollectiveStats", "Roofline", "collective_stats",
    "ShardCtx", "cache_spec_tree", "constrain", "current_ctx",
    "param_spec_tree", "use_mesh",
]
