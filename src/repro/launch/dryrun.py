import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. compiles the full scanned-layer program on the production mesh and
     prints ``memory_analysis()`` (fits per chip?) and ``cost_analysis()``;
  2. compiles R=1 and R=2 *unrolled* calibration variants: XLA's cost
     analysis counts a `while` body once, so per-layer FLOPs/bytes/
     collective-bytes are obtained as the difference, and totals as
     ``outside + R * per_layer`` (SSM chunk scans stay as inner while loops;
     their loop-body compute is <2% of total FLOPs — documented);
  3. emits the three roofline terms + dominant bottleneck to a JSON artifact
     consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import (SHAPES, all_configs, cell_supported,
                                get_config, with_repeats)
from repro.dist.hlo_analysis import (Roofline, collective_stats)
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

ARTIFACTS = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _compile(cfg, shape, mesh, multi_pod):
    with use_mesh(mesh, multi_pod):
        cell = build_cell(cfg, shape, mesh, multi_pod)
        jitted = jax.jit(cell["fn"], donate_argnums=cell["donate"],
                         out_shardings=cell["out_shardings"])
        lowered = jitted.lower(*cell["args"])
        compiled = lowered.compile()
    return lowered, compiled


def _cost(compiled):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # pre-0.5 jax: one dict per program
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _model_flops(cfg, shape):
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: pathlib.Path,
             force: bool = False, variant: str = "baseline",
             cfg_override=None, shape_override=None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    outpath = outdir / f"{tag}.json"
    if outpath.exists() and not force:
        return json.loads(outpath.read_text())

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = shape_override if shape_override is not None else SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant}
    if not ok:
        rec["status"] = why
        outdir.mkdir(parents=True, exist_ok=True)
        outpath.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    try:
        # --- full compile: proves the cell lowers/partitions/fits ---
        t0 = time.time()
        lowered, compiled = _compile(cfg, shape, mesh, multi_pod)
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_chip_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        }
        # exact per-chip resident bytes from the sharded input spec trees
        # (HLO temp bytes are inflated on the CPU backend, which emulates
        # bf16 arithmetic via f32 converts; see EXPERIMENTS.md methodology)
        with use_mesh(mesh, multi_pod):
            cell_shapes = build_cell(cfg, shape, mesh, multi_pod)["args"]

        def _shard_bytes(leaf):
            if not hasattr(leaf, "sharding") or leaf.sharding is None:
                return leaf.size * leaf.dtype.itemsize
            shard = leaf.sharding.shard_shape(leaf.shape)
            n = 1
            for s in shard:
                n *= s
            return n * leaf.dtype.itemsize

        rec["resident_per_chip_bytes"] = int(sum(
            _shard_bytes(l) for l in jax.tree.leaves(cell_shapes)))
        # analytic activation estimate: remat saves one residual-stream
        # carry per pattern repeat (bf16), sharded over batch (+seq for
        # attention archs)
        dshard = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        seq_shardable = not any(
            s.mixer in ("rwkv6", "mamba")
            for s in list(cfg.pattern) + list(cfg.prefix))
        sshard = mesh.shape.get("model", 1) if seq_shardable else 1
        if shape.kind == "train":
            carry = (shape.global_batch * shape.seq_len * cfg.d_model * 2
                     / (dshard * sshard))
            saved = carry * cfg.pattern_repeats
        else:
            saved = 0.0
        rec["analytic"] = {
            "resident_bytes": rec["resident_per_chip_bytes"],
            "saved_carries_bytes": int(saved),
            "est_hbm_per_chip": int(rec["resident_per_chip_bytes"] + saved),
        }
        rec["fits_16GB_analytic"] = rec["analytic"]["est_hbm_per_chip"] < 16e9
        rec["fits_16GB_hlo_cpu_inflated"] = (
            rec["memory"]["peak_per_chip_bytes"] < 16e9)
        f_full, b_full = _cost(compiled)
        st_full = collective_stats(compiled.as_text())
        rec["raw_full"] = {"flops": f_full, "bytes": b_full,
                           "coll_bytes": st_full.total_bytes,
                           "coll_counts": st_full.per_kind_count}

        # --- calibration: unrolled R=1 / R=2 ---
        R = cfg.pattern_repeats
        cal = {}
        for r in (1, 2):
            c = with_repeats(cfg, r).replace(scan_layers=False,
                                             unroll_inner=True)
            _, comp_r = _compile(c, shape, mesh, multi_pod)
            fl, by = _cost(comp_r)
            st = collective_stats(comp_r.as_text())
            cal[r] = (fl, by, st)
        per_layer_f = max(0.0, cal[2][0] - cal[1][0])
        per_layer_b = max(0.0, cal[2][1] - cal[1][1])
        per_layer_c = {k: max(0.0, cal[2][2].per_kind_bytes.get(k, 0)
                              - cal[1][2].per_kind_bytes.get(k, 0))
                       for k in set(cal[1][2].per_kind_bytes)
                       | set(cal[2][2].per_kind_bytes)}
        flops_dev = cal[1][0] + per_layer_f * (R - 1)
        bytes_dev = cal[1][1] + per_layer_b * (R - 1)
        coll_dev = sum(cal[1][2].per_kind_bytes.values()) + \
            sum(per_layer_c.values()) * (R - 1)
        coll_kinds = {k: cal[1][2].per_kind_bytes.get(k, 0)
                      + per_layer_c.get(k, 0) * (R - 1)
                      for k in set(cal[1][2].per_kind_bytes) | set(per_layer_c)}
        # bf16-on-the-wire correction (see CollectiveStats.corrected_bytes)
        per_layer_corr = max(0.0, cal[2][2].corrected_bytes
                             - cal[1][2].corrected_bytes)
        coll_dev_corr = cal[1][2].corrected_bytes + per_layer_corr * (R - 1)

        roof = Roofline(flops_global=flops_dev * chips,
                        hbm_bytes_global=bytes_dev * chips,
                        coll_bytes_global=coll_dev_corr * chips,
                        chips=chips,
                        model_flops=_model_flops(cfg, shape))
        rec["coll_bytes_raw_per_dev"] = coll_dev
        rec["coll_bytes_corrected_per_dev"] = coll_dev_corr
        rec["roofline"] = roof.to_dict()
        rec["coll_bytes_per_kind_per_dev"] = coll_kinds
        rec["params_total"] = cfg.param_counts()["total"]
        rec["params_active"] = cfg.param_counts()["active"]
        rec["status"] = "ok"
    except Exception as e:  # record the failure; dry-run failures are bugs
        rec["status"] = f"FAIL: {type(e).__name__}: {str(e)[:400]}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    outdir.mkdir(parents=True, exist_ok=True)
    outpath.write_text(json.dumps(rec, indent=1))
    return rec


def run_smoke(outdir: pathlib.Path, force: bool = False) -> dict:
    """Compile one tiny sharded train cell on the 256-chip host mesh.

    Fast proof (CI smoke) that the dist substrate partitions a real
    program: must report non-zero collective bytes or it exits non-zero.
    """
    from repro.configs.base import LayerSpec, ModelConfig, ShapeCell
    tiny = ModelConfig(name="smoke-tiny", n_layers=2, d_model=256,
                       n_heads=16, n_kv_heads=8, head_dim=16, d_ff=512,
                       vocab_size=1024, pattern=(LayerSpec(),))
    shape = ShapeCell("smoke_train", 512, 256, "train")
    rec = run_cell("smoke-tiny", "smoke_train", False, outdir, force=force,
                   variant="smoke", cfg_override=tiny, shape_override=shape)
    coll = rec.get("raw_full", {}).get("coll_bytes", 0)
    print(f"[smoke] status={rec.get('status')} "
          f"compile={rec.get('compile_s', 0)}s "
          f"coll_bytes/dev={coll:.3e} "
          f"counts={rec.get('raw_full', {}).get('coll_counts')}", flush=True)
    if rec.get("status") != "ok" or not coll:
        raise SystemExit(f"smoke cell failed: {rec.get('status')} "
                         f"coll_bytes={coll}")
    return rec


def run_tune(bundle=None, buckets=(64, 256, 1024), force=False,
             kernels="all"):
    """Pre-populate the kernel autotune caches (artifacts/tune/<kernel>.json).

    The registry dispatch consults the kernel-namespaced caches at trace
    time (``repro.kernels.registry.dispatch`` ->
    ``repro.tune.cache.best_params``); running this at deploy — per
    surrogate bundle for fused_mlp, plus every registered kernel's
    representative problems (flash_attention block sizes, stencil_gather
    tiles) — means the first real dispatch already runs the
    measured-best config instead of the hardcoded defaults.
    """
    from repro.tune import autotune, autotune_registered
    names = None if kernels in ("all", None) else \
        [k.strip() for k in kernels.split(",") if k.strip()]
    if names is None or "fused_mlp" in names:
        targets = [bundle] if bundle else [[5, 128, 128, 1],
                                           [16, 256, 256, 4]]
        for t in targets:
            recs = autotune(t, list(buckets), force=force, verbose=True)
            wins = sum(1 for r in recs if r["exact"])
            print(f"[tune] fused_mlp {t}: {wins}/{len(recs)} buckets tuned",
                  flush=True)
        if names is not None:
            names = [k for k in names if k != "fused_mlp"]
            if not names:
                return
    else:
        names = names or []
    recs = autotune_registered(names, force=force, verbose=True)
    wins = sum(1 for r in recs if r["exact"])
    print(f"[tune] registered kernels: {wins}/{len(recs)} problems tuned",
          flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pod-smoke", action="store_true",
                    help="spawn a real 2-process pod (jax.distributed on "
                         "CPU) and run the cross-host serve round-trip — "
                         "the multi-process counterpart of --smoke's "
                         "single-process 512-device fiction")
    ap.add_argument("--pod-processes", type=int, default=2)
    ap.add_argument("--obs", action="store_true",
                    help="with --pod-smoke: run the pod with tracing on, "
                         "all-gather every host's spans/metrics "
                         "(obs.pod_snapshot) and write the merged Chrome "
                         "trace to artifacts/obs/pod_trace.json")
    ap.add_argument("--shadow-rate", type=float, default=None,
                    help="with --pod-smoke: shadow-score this fraction of "
                         "served requests per host (default 1.0 with "
                         "--obs) and report cross-host drift state")
    ap.add_argument("--tune", action="store_true",
                    help="pre-populate the kernel autotune cache for the "
                         "serve-path shapes (see repro.tune)")
    ap.add_argument("--tune-bundle", default=None,
                    help="--tune: autotune this bundle's widths instead of "
                         "the NAS-representative defaults")
    ap.add_argument("--tune-buckets", default="64,256,1024",
                    help="--tune: comma-separated batch buckets to sweep")
    ap.add_argument("--tune-kernels", default="all",
                    help="--tune: comma-separated registered kernels to "
                         "pre-populate (default: all)")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)

    if args.tune:
        run_tune(args.tune_bundle,
                 [int(b) for b in args.tune_buckets.split(",")],
                 force=args.force, kernels=args.tune_kernels)
        return

    if args.pod_smoke:
        # children build their own device view (spawn_local_pod overrides
        # XLA_FLAGS per child); the parent never initializes jax here
        from repro.launch.multihost import run_smoke as run_pod_smoke
        obs_out = None
        if args.obs:
            obs_out = str(ARTIFACTS.parent / "obs" / "pod_trace.json")
        run_pod_smoke(processes=args.pod_processes, obs_out=obs_out,
                      shadow_rate=args.shadow_rate)
        return

    if args.obs:
        ap.error("--obs needs --pod-smoke (the flight recorder is a pod "
                 "collective)")

    if args.smoke:
        run_smoke(outdir, force=args.force)
        return

    if args.all:
        jobs = []
        for arch in all_configs():
            for shape in SHAPES:
                for mp in (False, True):
                    jobs.append((arch, shape, mp))
    else:
        jobs = [(args.arch, args.shape, args.multi_pod)]

    for arch, shape, mp in jobs:
        t0 = time.time()
        rec = run_cell(arch, shape, mp, outdir, force=args.force)
        status = rec.get("status", "?")
        roof = rec.get("roofline", {})
        print(f"[{arch} x {shape} x {'2x16x16' if mp else '16x16'}] "
              f"{status} compile={rec.get('compile_s', 0)}s "
              f"mem/chip={rec.get('memory', {}).get('peak_per_chip_bytes', 0)/1e9:.2f}GB "
              f"dom={roof.get('dominant', '-')} "
              f"t_step={roof.get('step_time_s', 0)*1e3:.2f}ms "
              f"useful={roof.get('useful_flops_fraction', 0)*100:.0f}%",
              flush=True)
        if "memory" in rec:
            print(f"   memory_analysis: {rec['memory']}", flush=True)
        if "raw_full" in rec:
            print(f"   cost_analysis(full, per-dev, body-once): "
                  f"{rec['raw_full']['flops']:.3e} flops; collectives: "
                  f"{rec['raw_full']['coll_counts']}", flush=True)


if __name__ == "__main__":
    main()
