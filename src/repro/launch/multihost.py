"""Multi-process pod bootstrap + local test harness.

Everything before this module ran the ``pod`` mesh axis as a fiction:
``dryrun --smoke`` forces 512 host devices in *one* process and calls it
a pod.  This module makes the axis real:

``bootstrap()``
    Environment-driven wrapper around ``jax.distributed.initialize``.
    Launchers (SLURM scripts, k8s pods, :func:`spawn_local_pod`) export
    ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
    (+ optional ``REPRO_LOCAL_DEVICES`` host-device partitioning) and
    every process calls ``bootstrap()`` before touching jax state.  On
    CPU it enables the Gloo cross-process collectives the backend needs
    (without them every multi-process computation fails with
    "Multiprocess computations aren't implemented on the CPU backend").

``spawn_local_pod(n, target)``
    CPU-local test harness: forks ``n`` fresh processes on this machine
    (spawn, never fork — jax is multithreaded), each bootstrapping into
    one pod process with ``devices_per_host`` forced host-platform
    devices, and runs ``target`` ("pkg.mod:fn") in all of them.  This is
    what the multi-process CI lane and tests/test_multihost.py drive:
    real ``jax.distributed`` process groups, real cross-host collectives,
    one machine.

``allgather_counts`` / ``barrier``
    The two collectives the serve path needs: agreeing on per-host row
    counts before assembling a cross-host mega-batch
    (``Batcher.dispatch_pod``), and synchronizing bundle rewrites between
    batches (the NAS-retrain-under-load scenario in
    ``benchmarks/multihost_bench.py``).

No jax import at module level: children of :func:`spawn_local_pod`
import this module *before* their env is final, and the parent harness
must be able to drive pods without initializing a backend of its own.
"""
from __future__ import annotations

import dataclasses
import importlib
import os
import socket
import tempfile
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_LOCAL_DEVICES"
ENV_POD_WATCHDOG = "REPRO_POD_WATCHDOG_S"


def pod_watchdog_s() -> float:
    """Collective watchdog budget for one guarded ``pod_flush`` round."""
    raw = os.environ.get(ENV_POD_WATCHDOG, "")
    try:
        return float(raw) if raw else 30.0
    except ValueError:
        return 30.0

_HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


@dataclasses.dataclass(frozen=True)
class PodInfo:
    """What bootstrap() resolved: this process's place in the pod."""

    process_id: int = 0
    num_processes: int = 1
    coordinator: Optional[str] = None

    @property
    def is_multiprocess(self) -> bool:
        return self.num_processes > 1


def _env_int(value, name: str, default: Optional[int]) -> Optional[int]:
    if value is not None:
        return int(value)
    raw = os.environ.get(name)
    return int(raw) if raw else default


def _enable_cpu_collectives() -> None:
    """Switch the CPU client to Gloo collectives (idempotent, pre-init).

    Harmless on TPU/GPU — the flag only affects CPU client creation —
    and guarded so jax versions without the option degrade to their
    default instead of crashing the bootstrap.
    """
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # pre-gloo jax or renamed flag
        pass


def bootstrap(coordinator: Optional[str] = None,
              num_processes: Optional[int] = None,
              process_id: Optional[int] = None,
              local_devices: Optional[int] = None) -> PodInfo:
    """Join the pod described by args/env; single-process is a no-op.

    Must run before anything initializes a jax backend (first device
    query / computation): ``XLA_FLAGS`` partitioning and the distributed
    client cannot be installed afterwards.  Safe to call again once
    initialized — an already-joined pod is returned as-is.
    """
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    num_processes = _env_int(num_processes, ENV_NUM_PROCESSES, 1)
    process_id = _env_int(process_id, ENV_PROCESS_ID, 0)
    local_devices = _env_int(local_devices, ENV_LOCAL_DEVICES, None)
    if local_devices:
        flags = os.environ.get("XLA_FLAGS", "")
        if _HOST_DEVICE_FLAG not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} {_HOST_DEVICE_FLAG}={local_devices}".strip())

    if num_processes <= 1:
        return PodInfo(0, 1, None)

    import jax
    from jax._src import distributed as _dist
    if getattr(_dist.global_state, "client", None) is None:
        if not coordinator:
            raise RuntimeError(
                f"bootstrap: {num_processes} processes requested but no "
                f"coordinator address (set {ENV_COORDINATOR} or pass "
                f"coordinator=)")
        # only flip the collectives flag once we are certain to join a
        # pod: a gloo CPU client without a distributed runtime fails to
        # initialize, which would poison this process's backend
        _enable_cpu_collectives()
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return PodInfo(jax.process_index(), jax.process_count(), coordinator)


# ----------------------------------------------------------- pod state -----

def is_multiprocess() -> bool:
    import jax
    return jax.process_count() > 1


def process_index() -> int:
    import jax
    return jax.process_index()


def process_count() -> int:
    import jax
    return jax.process_count()


def allgather_ints(values: Sequence[int]):
    """Every process's ``values`` as an int64 array [process_count, k].

    The serve path's agreement primitive: every host learns every host's
    pending row count (and row dtype), so all of them derive the same
    per-host slab and global bucket for a cross-host mega-batch.
    Collective — every process must call it at the same point with the
    same ``k``.  Single-process: ``[values]`` without touching the
    collectives stack.
    """
    import numpy as np
    vals = np.asarray([int(v) for v in values], np.int64).reshape(1, -1)
    if not is_multiprocess():
        return vals
    from jax.experimental import multihost_utils
    g = multihost_utils.process_allgather(vals[0].astype(np.int32))
    return np.asarray(g).reshape(process_count(), -1).astype(np.int64)


def allgather_counts(n: int):
    """Per-process values of ``n`` as an int64 array of len process_count."""
    return allgather_ints([n])[:, 0]


def allgather_bytes(data: bytes) -> List[bytes]:
    """Every process's ``data`` blob, ordered by process id.

    Variable-length payloads over the int collective the pod already
    has: the hosts agree on lengths first (one :func:`allgather_ints`),
    pad to the max, gather the padded byte matrix as int32, and slice
    each row back to its real length.  This is the transport under
    ``repro.obs.pod_snapshot`` — spans/metrics serialize to JSON bytes
    and ride it across the pod.  Collective (same contract as
    ``allgather_ints``); single-process returns ``[data]``.
    """
    import numpy as np
    if not is_multiprocess():
        return [bytes(data)]
    lengths = allgather_ints([len(data)])[:, 0]
    m = int(lengths.max())
    if m == 0:
        return [b""] * len(lengths)
    padded = np.zeros((m,), np.int32)
    padded[:len(data)] = np.frombuffer(bytes(data), np.uint8)
    from jax.experimental import multihost_utils
    g = np.asarray(multihost_utils.process_allgather(padded))
    g = g.reshape(process_count(), m).astype(np.uint8)
    return [g[i, :int(lengths[i])].tobytes() for i in range(len(lengths))]


def barrier(tag: str = "repro-pod") -> None:
    """Block until every pod process reaches this point (no-op solo)."""
    if not is_multiprocess():
        return
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices(tag)


# ------------------------------------------------------------ pod health ---

class PodHealth:
    """Dropout bookkeeping for this process's view of the pod.

    Heartbeats piggyback on the ``pod_flush`` transport: every guarded
    flush round calls :meth:`beat`, which bumps the local round counter
    and best-effort publishes ``repro_hb_<pid>_<round>`` through the
    coordinator's key-value store (per-round keys sidestep overwrite
    semantics).  When the collective watchdog fires, :meth:`check_round`
    names the peers whose beat for that round never landed — a host that
    dropped *before* its flush never wrote one — and
    :meth:`mark_degraded` latches local-only serving (gauge
    ``repro_pod_degraded``; healthz reports ``pod:host-<k>``).

    :meth:`try_rejoin` runs a barrier under a timeout and clears the
    degraded latch when every peer answers.  Caveat: after a *torn*
    collective (the watchdog abandoned a live Gloo op to a zombie
    thread) the transport's op sequence numbers may have diverged, so a
    true rejoin generally needs the returning host to restart; the
    barrier succeeding is evidence of health, not a transport repair.

    All jax access is lazy — this module must import jax-free.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._round = 0
        self.degraded = False
        self.degraded_at: Optional[float] = None  # monotonic stamp
        self.offenders: tuple = ()

    @staticmethod
    def _kv_client():
        try:
            from jax._src import distributed as _dist
            return getattr(_dist.global_state, "client", None)
        except Exception:
            return None

    def beat(self) -> int:
        """Start a flush round: bump the counter, publish the heartbeat."""
        with self._lock:
            self._round += 1
            rid = self._round
        client = self._kv_client()
        if client is not None:
            try:
                client.key_value_set(f"repro_hb_{process_index()}_{rid}",
                                     str(time.time()))
            except Exception:
                pass  # heartbeat is best-effort; the watchdog still works
        return rid

    def check_round(self, round_id: int) -> tuple:
        """Peers with no heartbeat for ``round_id`` (empty when the KV
        store is unavailable — degrade generically, name nobody)."""
        client = self._kv_client()
        if client is None or not hasattr(client, "key_value_try_get"):
            return ()
        me = process_index()
        offenders = []
        for k in range(process_count()):
            if k == me:
                continue
            try:
                v = client.key_value_try_get(f"repro_hb_{k}_{round_id}")
            except Exception:  # NOT_FOUND surfaces as an error status
                v = None
            if not v:
                offenders.append(k)
        return tuple(offenders)

    def mark_degraded(self, offenders: Sequence[int] = ()) -> None:
        from repro.obs import metrics as _metrics
        with self._lock:
            already = self.degraded
            self.degraded = True
            if self.degraded_at is None:
                self.degraded_at = time.monotonic()
            self.offenders = tuple(sorted(set(self.offenders)
                                          | set(offenders)))
        _metrics.gauge("repro_pod_degraded",
                       "1 while this host serves local-only").set(1)
        _metrics.counter("repro_pod_watchdog_trips_total",
                         "pod watchdog timeouts").inc(1)
        if not already:
            _metrics.warn_once(
                "pod-degraded",
                f"pod degraded to local-only serving (offenders: "
                f"{list(self.offenders) or 'unknown'})")

    def try_rejoin(self, timeout_s: float = 10.0, *,
                   barrier_fn=None) -> bool:
        """Probe the pod with a barrier under ``timeout_s``; clear the
        degraded latch when every peer answers.  Returns success."""
        fn = barrier_fn or (lambda: barrier("repro-pod-rejoin"))
        done = threading.Event()
        ok: Dict[str, bool] = {}

        def run():
            try:
                fn()
                ok["ok"] = True
            except Exception:
                ok["ok"] = False
            finally:
                done.set()

        t = threading.Thread(target=run, daemon=True,
                             name="repro-pod-rejoin")
        t.start()
        if not (done.wait(timeout_s) and ok.get("ok")):
            return False
        from repro.obs import metrics as _metrics
        with self._lock:
            self.degraded = False
            self.degraded_at = None
            self.offenders = ()
        _metrics.gauge("repro_pod_degraded",
                       "1 while this host serves local-only").set(0)
        return True

    def reset(self) -> None:
        """Forget all state (tests)."""
        with self._lock:
            self._round = 0
            self.degraded = False
            self.degraded_at = None
            self.offenders = ()

    def snapshot(self) -> dict:
        with self._lock:
            return {"round": self._round, "degraded": self.degraded,
                    "offenders": list(self.offenders)}


#: process-wide pod health (what pod_flush and healthz consult)
POD_HEALTH = PodHealth()


# ----------------------------------------------------- local pod harness ---

class PodWorkerError(RuntimeError):
    """One or more spawn_local_pod workers failed; message carries all
    per-process tracebacks."""


def _free_port() -> int:
    s = socket.socket()
    try:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]
    finally:
        s.close()


def _pod_child(conn, env: Dict[str, str], target: str,
               args: tuple, kwargs: dict) -> None:
    """Spawn-side entry: env first, then bootstrap, then the target.

    Top-level so the spawn pickler can import it by reference; the env
    update happens before any jax import, which is why this module must
    stay jax-free at import time.
    """
    os.environ.update(env)
    try:
        from repro.launch.multihost import bootstrap
        bootstrap()
        mod_name, _, fn_name = target.partition(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        conn.send(("ok", fn(*args, **(kwargs or {}))))
    except BaseException:
        try:
            conn.send(("err", traceback.format_exc()))
        except Exception:
            pass
    finally:
        conn.close()


def spawn_local_pod(n: int, target: str, args: tuple = (), *,
                    kwargs: Optional[dict] = None, devices_per_host: int = 1,
                    timeout_s: float = 300.0,
                    extra_env: Optional[Dict[str, str]] = None) -> List[Any]:
    """Run ``target`` ("pkg.mod:fn") in ``n`` fresh pod processes.

    Each child gets ``devices_per_host`` forced host-platform CPU
    devices, joins one ``jax.distributed`` process group over localhost,
    and runs the target with ``args``/``kwargs``.  Returns the targets'
    return values ordered by process id (results must pickle).  Raises
    :class:`PodWorkerError` with every failing process's traceback, or
    ``TimeoutError`` if any child outlives ``timeout_s`` (stragglers are
    killed — a hung collective must not hang CI).
    """
    import multiprocessing as mp
    if n < 1:
        raise ValueError(f"spawn_local_pod needs n >= 1, got {n}")
    port = _free_port()
    ctx = mp.get_context("spawn")
    procs, conns = [], []
    for pid in range(n):
        env = {
            ENV_COORDINATOR: f"127.0.0.1:{port}",
            ENV_NUM_PROCESSES: str(n),
            ENV_PROCESS_ID: str(pid),
            ENV_LOCAL_DEVICES: str(devices_per_host),
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
            # children build their own device view; never inherit the
            # parent's partitioning (dryrun forces 512 devices at import)
            "XLA_FLAGS": f"{_HOST_DEVICE_FLAG}={devices_per_host}",
        }
        env.update(extra_env or {})
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        p = ctx.Process(target=_pod_child,
                        args=(child_conn, env, target, tuple(args),
                              dict(kwargs or {})),
                        name=f"repro-pod-{pid}", daemon=True)
        p.start()
        child_conn.close()
        procs.append(p)
        conns.append(parent_conn)

    from multiprocessing import connection as mp_connection
    results: List[Any] = [None] * n
    errors: List[str] = []
    # one shared deadline (sequential per-process timeouts would stack to
    # n * timeout_s and outlive the CI job's own limit), collected
    # round-robin: a fast failure in any process surfaces immediately
    # instead of hiding behind an earlier pid's hung collective — once a
    # failure lands, surviving peers (likely hung in the now-peerless
    # collective) get a short grace, not the whole budget
    deadline = time.monotonic() + timeout_s
    fail_grace_s = 15.0
    by_conn = {conn: pid for pid, conn in enumerate(conns)}
    pending = dict(enumerate(zip(procs, conns)))
    while pending:
        left = deadline - time.monotonic()
        if left <= 0:
            break
        ready = mp_connection.wait(
            [c for _, c in pending.values()], timeout=left)
        for conn in ready:
            pid = by_conn[conn]
            p, _ = pending.pop(pid)
            try:
                status, payload = conn.recv()
            except (EOFError, OSError):  # a crash, not a hang
                p.join(timeout=5)
                errors.append(f"--- process {pid} exited {p.exitcode} "
                              f"with no result ---")
                continue
            if status == "ok":
                results[pid] = payload
            else:
                errors.append(f"--- process {pid} ---\n{payload}")
        if errors:
            deadline = min(deadline, time.monotonic() + fail_grace_s)
    timed_out = sorted(pending)
    for p in procs:
        p.join(timeout=5 if not timed_out else 0.5)
        if p.is_alive():
            p.terminate()
            p.join(timeout=5)
    if errors:
        if timed_out:
            errors.append(f"--- processes {timed_out} still pending "
                          f"{fail_grace_s}s after the first failure "
                          f"(killed) ---")
        raise PodWorkerError("spawn_local_pod worker failure:\n"
                             + "\n".join(errors))
    if timed_out:
        raise TimeoutError(
            f"spawn_local_pod: processes {timed_out} produced no result "
            f"within {timeout_s}s (killed)")
    return results


# -------------------------------------------------------------- CI smoke ---

def _write_smoke_bundle(path: str, widths=(32, 32)):
    import jax
    from repro.nn import MLP
    from repro.nn.serialize import save_model
    net = MLP((1, 5), list(widths), 1)
    params = net.init(jax.random.PRNGKey(7))
    return save_model(path, net, params)


def _smoke_worker(tmp: str, callers_per_host: int = 3,
                  rows_per_caller: int = 5) -> Dict[str, Any]:
    """One pod process of the cross-host serve round-trip.

    Every host submits its callers' rows to the *same* queue key, all
    hosts pod_flush collectively, and each host checks its callers'
    results bit-identical to single-process (eager, mesh-less) serving
    of the same rows.
    """
    import jax
    import numpy as np

    from repro.core.engine import InferenceEngine
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_pod_mesh
    from repro.serve import FlushPolicy, ServeQueue

    pid, nproc = jax.process_index(), jax.process_count()
    bundle = os.path.join(tmp, "surrogate")
    if pid == 0:
        _write_smoke_bundle(bundle)
    barrier("smoke-bundle-ready")

    # every host sees the same deterministic global caller set and owns
    # a contiguous slice of it
    rng = np.random.default_rng(1234)
    full = rng.standard_normal(
        (nproc * callers_per_host * rows_per_caller, 5)).astype(np.float32)
    mine = full.reshape(nproc, callers_per_host, rows_per_caller, 5)[pid]

    mesh = make_pod_mesh()
    queue = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))  # explicit only
    with use_mesh(mesh, multi_pod=True):
        futs = [queue.submit(bundle, mine[c]) for c in range(callers_per_host)]
        queue.pod_flush(bundle)
    got = [np.asarray(f.result(timeout=120)) for f in futs]

    # single-process reference: the same engine serving eagerly, no mesh
    eng = InferenceEngine.get(bundle)
    ref = [np.asarray(eng(mine[c])) for c in range(callers_per_host)]
    equal = all(np.array_equal(g, r) for g, r in zip(got, ref))

    snap = queue.stats(bundle).snapshot()
    out = {
        "pid": pid,
        "nproc": nproc,
        "equal": bool(equal),
        "local_rows": int(callers_per_host * rows_per_caller),
        "bucket": int(snap["bucket_rows"]),
        "pod_batches": int(snap["pod_batches"]),
        "remote_rows": int(snap["remote_rows"]),
        "global_devices": jax.device_count(),
    }
    from repro.obs import SHADOW, TRACER, pod_snapshot
    if SHADOW.enabled:
        # quality pass: every host shadow-scores its own served rows
        # against the eager single-process reference it already computed
        # (bit-identical -> the drift alert must stay OK; cross-host
        # state rides the pod snapshot below)
        SHADOW.set_budget(bundle, 0.05)
        for c in range(callers_per_host):
            SHADOW.submit(bundle,
                          pred=lambda g=got[c]: g,
                          ref=lambda r=ref[c]: r,
                          region="pod-smoke", rows=rows_per_caller)
        SHADOW.flush(60.0)
        out["quality_state"] = SHADOW.state(bundle)
    if TRACER.enabled:
        # flight-recorder pass: all-gather every host's spans/metrics
        # (collective, so it must run before the final barrier on every
        # host) — each worker returns the merged pod view, letting the
        # parent write one trace artifact without its own jax runtime
        out["obs"] = pod_snapshot()
    barrier("smoke-done")
    return out


def run_smoke(processes: int = 2, devices_per_host: int = 2,
              tmpdir: Optional[str] = None,
              timeout_s: float = 420.0,
              obs_out: Optional[str] = None,
              shadow_rate: Optional[float] = None) -> List[Dict[str, Any]]:
    """The multi-process CI smoke: spawn_local_pod driving a cross-host
    serve round-trip.  Raises on any correctness failure; returns the
    per-process summaries.

    ``obs_out`` turns the pod into a flight recorder: children run with
    tracing on, every host's spans/metrics are all-gathered in-pod
    (``obs.pod_snapshot``), and the merged Chrome trace lands at
    ``obs_out`` (open in Perfetto; each host is one pid track).

    ``shadow_rate`` enables shadow quality scoring in every child
    (defaults to 1.0 when the flight recorder is on); the smoke then
    also requires every host's drift alert to report OK — the served
    rows are bit-identical to the accurate reference, so anything else
    is a monitor bug.
    """
    tmp = tmpdir or tempfile.mkdtemp(prefix="repro_pod_smoke_")
    if shadow_rate is None and obs_out:
        shadow_rate = 1.0
    extra_env: Dict[str, str] = {}
    if obs_out:
        extra_env["REPRO_TRACE"] = "1"
    if shadow_rate:
        extra_env["REPRO_SHADOW_RATE"] = str(shadow_rate)
    res = spawn_local_pod(processes, "repro.launch.multihost:_smoke_worker",
                          (tmp,), devices_per_host=devices_per_host,
                          timeout_s=timeout_s,
                          extra_env=extra_env or None)
    failures = []
    for r in res:
        if not r["equal"]:
            failures.append(f"p{r['pid']}: results diverge from "
                            f"single-process serving")
        if r["pod_batches"] < 1:
            failures.append(f"p{r['pid']}: no pod batch dispatched")
        if processes > 1 and r["remote_rows"] <= 0:
            failures.append(f"p{r['pid']}: mega-batch carried no remote "
                            f"rows — it did not span the pod axis")
        if r["bucket"] <= r["local_rows"]:
            failures.append(f"p{r['pid']}: global bucket {r['bucket']} "
                            f"does not exceed local rows {r['local_rows']}")
        if shadow_rate and r.get("quality_state") != "OK":
            failures.append(
                f"p{r['pid']}: drift alert {r.get('quality_state')!r} on "
                f"bit-identical served rows (expected OK)")
    for r in res:
        q = f" quality={r['quality_state']}" if "quality_state" in r else ""
        print(f"[pod-smoke] p{r['pid']}/{r['nproc']} "
              f"devices={r['global_devices']} bucket={r['bucket']} "
              f"remote_rows={r['remote_rows']} equal={r['equal']}{q}",
              flush=True)
    if failures:
        raise PodWorkerError("pod smoke FAILED:\n" + "\n".join(failures))
    if obs_out:
        # process 0's gathered snapshots already hold every host's view;
        # the merge is jax-free so the parent harness can write it
        from repro.obs import merge_pod_trace, pod_quality_report
        snapshots = (res[0] or {}).get("obs") or []
        merged = merge_pod_trace(snapshots, obs_out)
        print(f"[pod-smoke] obs: merged {len(merged)} events from "
              f"{len(snapshots)} hosts -> {obs_out}", flush=True)
        if shadow_rate:
            print("[pod-smoke] cross-host surrogate quality:", flush=True)
            print(pod_quality_report(snapshots), flush=True)
    print(f"[pod-smoke] OK: {processes} processes, cross-host mega-batch, "
          f"bit-identical to single-process serving", flush=True)
    return res


# ------------------------------------------------------ host-drop drill ---

def _host_drop_worker(tmp: str, callers_per_host: int = 2,
                      rows_per_caller: int = 4) -> Dict[str, Any]:
    """One pod process of the chaos host-drop drill.

    Launched with ``REPRO_FAULTS="pod.flush:drop:pid=1,stall=<s>"`` and a
    short ``REPRO_POD_WATCHDOG_S``: host 1 stalls at ``pod_flush`` entry
    — *before* writing its heartbeat, so it looks exactly like a dropped
    host — and host 0's watchdog must fire, degrade to local-only
    dispatch, and still resolve every future bit-identically.  Host 1,
    on waking, either completes a late pod batch with host 0's abandoned
    collective thread or degrades locally itself; both are correct, and
    first-wins futures keep either race winner exact.

    Two latencies are measured separately because they are bounded by
    different mechanisms.  *Time-to-degrade* (watchdog fires, healthz
    flips, later flushes go local-only) is bounded by the watchdog.
    *Drain time* for the batch that was in flight when the host dropped
    is bounded by the collective transport, not the watchdog: on
    backends with FIFO per-device execution streams (XLA CPU) the torn
    collective pins the devices, so the survivor's local re-dispatch
    executes only once the transport gives up (peer timeout) or the
    straggler limps back — zero requests lost either way.
    """
    import jax
    import numpy as np

    from repro.core.engine import InferenceEngine
    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_pod_mesh
    from repro.serve import FlushPolicy, ServeQueue

    pid, nproc = jax.process_index(), jax.process_count()
    bundle = os.path.join(tmp, "surrogate")
    if pid == 0:
        _write_smoke_bundle(bundle)
    barrier("drill-bundle-ready")

    rng = np.random.default_rng(99)
    full = rng.standard_normal(
        (nproc * callers_per_host * rows_per_caller, 5)).astype(np.float32)
    mine = full.reshape(nproc, callers_per_host, rows_per_caller, 5)[pid]

    mesh = make_pod_mesh()
    queue = ServeQueue(FlushPolicy(max_batch_rows=1 << 30))  # explicit only
    t0 = time.monotonic()
    with use_mesh(mesh, multi_pod=True):
        futs = [queue.submit(bundle, mine[c])
                for c in range(callers_per_host)]
        queue.pod_flush(bundle)
    elapsed = time.monotonic() - t0

    got = [np.asarray(f.result(timeout=120)) for f in futs]
    eng = InferenceEngine.get(bundle)
    ref = [np.asarray(eng(mine[c])) for c in range(callers_per_host)]
    equal = all(np.array_equal(g, r) for g, r in zip(got, ref))

    from repro.obs.server import ObsServer
    _, health = ObsServer().health()
    # no rejoin drill here: after a torn Gloo collective only a process
    # restart truly rejoins (see PodHealth.try_rejoin caveat) — the unit
    # tests cover the rejoin state machine with a stubbed barrier
    degrade_latency = (POD_HEALTH.degraded_at - t0
                       if POD_HEALTH.degraded_at is not None else None)
    return {
        "pid": pid, "nproc": nproc, "equal": bool(equal),
        "resolved": sum(1 for f in futs if f.done()),
        "submitted": len(futs),
        "elapsed_s": float(elapsed),
        "degrade_latency_s": (float(degrade_latency)
                              if degrade_latency is not None else None),
        "degraded": bool(POD_HEALTH.degraded),
        "offenders": list(POD_HEALTH.offenders),
        "critical": list(health["critical"]),
        "watchdog_s": pod_watchdog_s(),
    }


def run_host_drop_drill(processes: int = 2, devices_per_host: int = 2,
                        tmpdir: Optional[str] = None,
                        timeout_s: float = 240.0, stall_s: float = 15.0,
                        watchdog_s: float = 2.0) -> List[Dict[str, Any]]:
    """The chaos-lane drill: drop host 1 mid-flush, require the survivor
    to *degrade* within the watchdog (healthz flips, later flushes go
    local-only) and to *drain* the in-flight batch with zero lost
    requests.  The drain itself is transport-bound, not watchdog-bound —
    see ``_host_drop_worker`` — so it is only required to complete
    promptly once the dropped host's stall ends, never to beat it."""
    if processes < 2:
        raise ValueError("host-drop drill needs >= 2 processes")
    tmp = tmpdir or tempfile.mkdtemp(prefix="repro_pod_drill_")
    extra_env = {
        "REPRO_FAULTS": f"pod.flush:drop:pid=1,stall={stall_s}",
        ENV_POD_WATCHDOG: str(watchdog_s),
    }
    res = spawn_local_pod(
        processes, "repro.launch.multihost:_host_drop_worker", (tmp,),
        devices_per_host=devices_per_host,
        timeout_s=timeout_s, extra_env=extra_env)
    failures = []
    for r in res:
        if r["resolved"] != r["submitted"]:
            failures.append(f"p{r['pid']}: lost "
                            f"{r['submitted'] - r['resolved']} requests")
        if not r["equal"]:
            failures.append(f"p{r['pid']}: results diverge from eager "
                            f"serving")
    r0 = res[0]
    if not r0["degraded"]:
        failures.append("p0: survivor never degraded — the watchdog did "
                        "not fire")
    else:
        if r0["offenders"] and r0["offenders"] != [1]:
            failures.append(f"p0: offenders {r0['offenders']} "
                            f"(expected [1])")
        if r0["offenders"] and "pod:host-1" not in r0["critical"]:
            failures.append(f"p0: healthz critical {r0['critical']} does "
                            f"not name pod:host-1")
        lat = r0["degrade_latency_s"]
        # watchdog + heartbeat/thread spin-up slack; far under the stall
        if lat is None or lat >= min(watchdog_s + 5.0, stall_s):
            failures.append(
                f"p0: degrade latency {lat if lat is None else round(lat, 1)}s"
                f" — the watchdog ({watchdog_s}s) did not flip the pod to "
                f"local-only before the {stall_s}s stall ended")
    if r0["elapsed_s"] >= stall_s + 10.0:
        failures.append(
            f"p0: pod_flush took {r0['elapsed_s']:.1f}s — the in-flight "
            f"batch did not drain promptly after the {stall_s}s stall "
            f"released the transport")
    for r in res:
        lat = r["degrade_latency_s"]
        print(f"[host-drop] p{r['pid']}/{r['nproc']} "
              f"resolved={r['resolved']}/{r['submitted']} "
              f"equal={r['equal']} degraded={r['degraded']} "
              f"degrade_latency="
              f"{'-' if lat is None else format(lat, '.1f') + 's'} "
              f"offenders={r['offenders']} "
              f"flush={r['elapsed_s']:.1f}s", flush=True)
    if failures:
        raise PodWorkerError("host-drop drill FAILED:\n"
                             + "\n".join(failures))
    print(f"[host-drop] OK: host 1 dropped {stall_s}s, survivor flipped "
          f"local-only in {r0['degrade_latency_s']:.1f}s "
          f"(watchdog {watchdog_s}s), zero requests lost", flush=True)
    return res


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="spawn_local_pod cross-host serve round-trip")
    ap.add_argument("--host-drop-drill", action="store_true",
                    help="chaos drill: drop one host mid-pod_flush and "
                         "require degrade-within-watchdog, zero lost "
                         "requests")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-host", type=int, default=2)
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="flight recorder: run the pod with tracing on "
                         "and write the merged Chrome trace to PATH")
    ap.add_argument("--shadow-rate", type=float, default=None,
                    help="shadow-score this fraction of served requests "
                         "in every pod process (default 1.0 with --obs)")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(processes=args.processes,
                  devices_per_host=args.devices_per_host,
                  obs_out=args.obs,
                  shadow_rate=args.shadow_rate)
        return
    if args.host_drop_drill:
        run_host_drop_drill(processes=args.processes,
                            devices_per_host=args.devices_per_host)
        return
    ap.error("nothing to do (pass --smoke or --host-drop-drill)")


if __name__ == "__main__":
    main()
