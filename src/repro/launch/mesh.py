"""Production mesh builders.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(one v5e pod slice); multi-pod stacks a leading ``pod`` axis (2 pods = 512
chips) used for data parallelism across the inter-pod (DCN/ICI-expanded)
links.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API; absent on the pinned 0.4.x
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes):
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # pre-AxisType jax: all mesh axes are implicitly auto
    def _axis_kwargs(n_axes):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)}; run under dryrun.py which forces 512 host "
            f"platform devices")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes, **_axis_kwargs(len(axes)))


def make_pod_mesh(axes=("pod", "data")):
    """Global mesh over every pod process's devices: one ``pod`` row per
    process, that process's local devices along ``data``.

    Device order is process-major (sorted by ``process_index``), which is
    the contract ``Batcher.dispatch_pod`` relies on: the global batch's
    leading dim sharded over ``("pod", "data")`` puts host *h*'s slab of
    rows on host *h*'s devices, so results scatter back without any
    cross-host gather.  Single-process this is a ``1 x n_local`` mesh and
    everything degrades to the ordinary data-parallel path.  Requires a
    bootstrapped pod (``repro.launch.multihost.bootstrap``) when
    ``jax.process_count() > 1``.
    """
    import numpy as np
    procs = jax.process_count()
    devices = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    if len(devices) % procs:
        raise RuntimeError(
            f"make_pod_mesh: {len(devices)} devices do not divide over "
            f"{procs} processes (heterogeneous hosts are unsupported)")
    local = len(devices) // procs
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(procs, local), axes,
        **_axis_kwargs(len(axes)))


def make_local_mesh(shape=None, axes=("data", "model")):
    """Smoke/test mesh over whatever devices exist (usually 1 CPU)."""
    import numpy as np
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:shape[0] * shape[1]]).reshape(shape), axes,
        **_axis_kwargs(len(axes)))
