"""ShapeDtypeStruct stand-ins + sharded step builders for every cell.

``input_specs(cfg, shape)`` provides weak-type-correct, shardable
ShapeDtypeStructs for every model input — no device allocation.  Modality
frontends are stubs per the assignment: whisper gets precomputed frame
embeddings [B, enc_ctx, d_model]; qwen2-vl gets 3-component M-RoPE position
ids alongside the token stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.sharding import (ShardCtx, cache_spec_tree, param_spec_tree)
from repro.models import lm
from repro.train import trainer


def _sds(shape, dtype, mesh, spec):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _attach(shapes, specs, mesh):
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(cfg: ModelConfig, shape: ShapeCell, mesh=None,
                multi_pod: bool = False) -> dict:
    """Batch-input ShapeDtypeStructs for one cell (no params/caches)."""
    ctx = ShardCtx(mesh, multi_pod)
    GB, S = shape.global_batch, shape.seq_len
    tok_spec = ctx.spec_for((GB, S), ("batch", None)) if mesh else P()
    out = {}
    if shape.kind == "train":
        out["tokens"] = _sds((GB, S), jnp.int32, mesh, tok_spec)
        out["targets"] = _sds((GB, S), jnp.int32, mesh, tok_spec)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((GB, S), jnp.int32, mesh, tok_spec)
    else:  # decode
        one = ctx.spec_for((GB, 1), ("batch", None)) if mesh else P()
        out["tokens"] = _sds((GB, 1), jnp.int32, mesh, one)
    Sx = out["tokens"].shape[1]
    if cfg.needs_position_ids:
        pid_spec = ctx.spec_for((3, GB, Sx), (None, "batch", None)) if mesh else P()
        out["position_ids"] = _sds((3, GB, Sx), jnp.int32, mesh, pid_spec)
    if cfg.enc_dec:
        esp = (ctx.spec_for((GB, cfg.enc_ctx, cfg.d_model),
                            ("batch", None, None)) if mesh else P())
        out["enc_embeds"] = _sds((GB, cfg.enc_ctx, cfg.d_model), cfg.jdtype,
                                 mesh, esp)
    return out


def _param_sds(cfg, mesh, multi_pod):
    shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_spec_tree(shapes, cfg, mesh, multi_pod)
    return _attach(shapes, specs, mesh)


def _state_sds(cfg, mesh, multi_pod):
    shapes = jax.eval_shape(
        lambda: trainer.make_train_state(jax.random.PRNGKey(0), cfg))
    specs = param_spec_tree(shapes, cfg, mesh, multi_pod)
    return _attach(shapes, specs, mesh)


def _cache_sds(cfg, shape, params_sds, batch_in, mesh, multi_pod, long_ctx):
    GB, S = shape.global_batch, shape.seq_len

    def build(p, enc):
        enc_out = lm.encode(cfg, p, enc) if cfg.enc_dec else None
        return lm.init_caches(cfg, GB, S, cfg.jdtype, enc_out=enc_out,
                              params=p if cfg.enc_dec else None)

    if cfg.enc_dec:
        shapes = jax.eval_shape(build, params_sds, batch_in["enc_embeds"])
    else:
        shapes = jax.eval_shape(lambda p: build(p, None), params_sds)
    specs = cache_spec_tree(shapes, cfg, mesh, multi_pod, long_ctx=long_ctx)
    return _attach(shapes, specs, mesh)


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh=None,
               multi_pod: bool = False) -> dict:
    """Returns fn/args/donate/out_shardings for jit().lower() of one cell."""
    batch_in = input_specs(cfg, shape, mesh, multi_pod)
    long_ctx = shape.name.startswith("long")

    if shape.kind == "train":
        state = _state_sds(cfg, mesh, multi_pod)

        def fn(st, batch):
            return trainer.train_step(cfg, st, batch)

        out_shardings = None
        if mesh is not None:
            out_shardings = (jax.tree.map(lambda x: x.sharding, state), None)
        return dict(fn=fn, args=(state, batch_in), donate=(0,),
                    out_shardings=out_shardings)

    params = _param_sds(cfg, mesh, multi_pod)
    if shape.kind == "prefill":
        def fn(p, batch):
            return lm.prefill(cfg, p, batch["tokens"],
                              position_ids=batch.get("position_ids"),
                              enc_embeds=batch.get("enc_embeds"))
        return dict(fn=fn, args=(params, batch_in), donate=(), out_shardings=None)

    # decode
    caches = _cache_sds(cfg, shape, params, batch_in, mesh, multi_pod, long_ctx)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(p, c, batch, pos_):
        return lm.serve_step(cfg, p, c, batch["tokens"], pos_,
                             position_ids=batch.get("position_ids"),
                             long_ctx=long_ctx)

    out_shardings = None
    if mesh is not None:
        out_shardings = (None, jax.tree.map(lambda x: x.sharding, caches))
    return dict(fn=fn, args=(params, caches, batch_in, pos), donate=(1,),
                out_shardings=out_shardings)
