"""Binomial Options: CRR lattice pricing of American puts.

Accurate path: backward induction over a 256-step binomial tree per
option (iterative, like the CUDA benchmark).  QoI: option price.
Metric: RMSE.  Surrogate: small MLP on (S, K, T, r, sigma).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_ml, tensor_functor

N_STEPS = 256

_ifn = tensor_functor("bin_in: [i, 0:5] = ([i, 0:5])")
_ofn = tensor_functor("bin_out: [i, 0:1] = ([i, 0:1])")


def make_inputs(n, seed=0):
    """[n, 5] = (S, K, T, r, sigma)."""
    rng = np.random.default_rng(seed)
    S = rng.uniform(5, 30, n)
    K = rng.uniform(1, 100, n)
    T = rng.uniform(0.25, 10, n)
    r = rng.uniform(0.01, 0.06, n)
    sig = rng.uniform(0.05, 0.5, n)
    return jnp.asarray(np.stack([S, K, T, r, sig], 1).astype(np.float32))


def _price_one(opt):
    S, K, T, r, sig = opt[0], opt[1], opt[2], opt[3], opt[4]
    dt = T / N_STEPS
    u = jnp.exp(sig * jnp.sqrt(dt))
    d = 1.0 / u
    p = (jnp.exp(r * dt) - d) / (u - d)
    disc = jnp.exp(-r * dt)
    j = jnp.arange(N_STEPS + 1)
    prices = S * u ** (2 * j - N_STEPS)
    vals = jnp.maximum(K - prices, 0.0)  # american put payoff at expiry

    def step(vals, i):
        cont = disc * (p * vals[1:] + (1 - p) * vals[:-1])
        level = N_STEPS - 1 - i
        j = jnp.arange(N_STEPS)
        spot = S * u ** (2 * j - level)
        ex = jnp.maximum(K - spot, 0.0)
        new = jnp.maximum(cont, ex)
        return jnp.concatenate([new, jnp.zeros(1)]), None

    vals, _ = jax.lax.scan(step, vals, jnp.arange(N_STEPS))
    return vals[0]


@jax.jit
def prices(opts):
    return jax.vmap(_price_one)(opts)


def accurate(opts):
    return {"out": prices(opts)[:, None]}


def make_region(n, mode="collect", model=None, database=None, serving=None):
    rngs = {"i": (0, n)}
    return approx_ml(lambda opts: {"out": prices(opts)[:, None]},
                     name="binomial",
                     inputs={"opts": (_ifn, rngs)},
                     outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, database=database,
                     serving=serving)


def price_chunks_async(opts, region, queue, chunk: int):
    """Price a sweep of option chunks through the serve queue.

    Models the paper's many-caller regime: each chunk of ``chunk``
    options is an independent region invocation (a separate solver
    instance / sweep step); all of a sweep's chunks are enqueued, then
    one flush coalesces them into a single mesh-wide batch.  ``region``
    must be ``make_region(chunk, mode="infer_async", serving=queue)``.
    """
    assert region.mode == "infer_async" and region.serving is queue
    n = opts.shape[0]
    assert n % chunk == 0, (n, chunk)
    handles = [region(opts=opts[i:i + chunk]) for i in range(0, n, chunk)]
    queue.flush(region.model_path, reason="sweep_step")
    return jnp.concatenate([h.result()["out"] for h in handles], axis=0)


def qoi_error(ref, approx):
    ref = np.asarray(ref).reshape(-1)
    approx = np.asarray(approx).reshape(-1)
    return float(np.sqrt(np.mean((ref - approx) ** 2)))


def surrogate_space():
    return {"kind": "mlp", "in_dim": 5, "out_dim": 1,
            "hidden1": (32, 512, "log2"), "hidden2": (0, 512, "log2")}
