"""MiniBUDE (virtual screening): pose -> ligand-protein binding energy.

The accurate path evaluates an empirical forcefield over all ligand x
protein atom pairs for every pose (compute-bound, like the original
mini-app).  QoI: per-pose energy.  Metric: MAPE (paper Table I).

Surrogate: MLP pose[6] -> energy (paper Table IV space: 2-12 hidden
layers, width 64..4096 with a feature multiplier).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_ml, tensor_functor

N_LIG, N_PROT = 16, 64

_ifn = tensor_functor("bude_in: [i, 0:6] = ([i, 0:6])")
_ofn = tensor_functor("bude_out: [i, 0:1] = ([i, 0:1])")


def make_molecule(seed=0):
    rng = np.random.default_rng(seed)
    lig = jnp.asarray(rng.normal(0, 1.0, (N_LIG, 3)).astype(np.float32))
    prot = jnp.asarray(rng.normal(0, 4.0, (N_PROT, 3)).astype(np.float32))
    lq = jnp.asarray(rng.uniform(-1, 1, (N_LIG,)).astype(np.float32))
    pq = jnp.asarray(rng.uniform(-1, 1, (N_PROT,)).astype(np.float32))
    lr = jnp.asarray(rng.uniform(1.0, 2.0, (N_LIG,)).astype(np.float32))
    pr = jnp.asarray(rng.uniform(1.0, 2.0, (N_PROT,)).astype(np.float32))
    return dict(lig=lig, prot=prot, lq=lq, pq=pq, lr=lr, pr=pr)


MOL = make_molecule()


def make_inputs(n, seed=0):
    """Poses: [n, 6] = (rx, ry, rz, tx, ty, tz)."""
    rng = np.random.default_rng(seed)
    rot = rng.uniform(-np.pi, np.pi, (n, 3))
    trans = rng.uniform(-2, 2, (n, 3))
    return jnp.asarray(np.concatenate([rot, trans], 1).astype(np.float32))


def _rot_matrix(r):
    cx, cy, cz = jnp.cos(r[0]), jnp.cos(r[1]), jnp.cos(r[2])
    sx, sy, sz = jnp.sin(r[0]), jnp.sin(r[1]), jnp.sin(r[2])
    Rx = jnp.array([[1, 0, 0], [0, cx, -sx], [0, sx, cx]])
    Ry = jnp.array([[cy, 0, sy], [0, 1, 0], [-sy, 0, cy]])
    Rz = jnp.array([[cz, -sz, 0], [sz, cz, 0], [0, 0, 1]])
    return Rz @ Ry @ Rx


def _pose_energy(pose, mol):
    R = _rot_matrix(pose[:3])
    lig = mol["lig"] @ R.T + pose[3:]
    # soft-core distances (standard forcefield softening): bounds the
    # r^-12 steric wall so energies stay in a learnable range
    d2 = jnp.sum((lig[:, None, :] - mol["prot"][None]) ** 2, axis=-1)
    d = jnp.sqrt(d2 + 0.5)
    elec = mol["lq"][:, None] * mol["pq"][None] / d
    sigma = (mol["lr"][:, None] + mol["pr"][None]) * 0.5
    sr6 = jnp.minimum(sigma / d, 1.4) ** 6
    steric = sr6 * sr6 - sr6
    return (elec + 0.1 * steric).sum()


@jax.jit
def energies(poses):
    """Accurate path: [n, 6] poses -> [n] binding energies."""
    return jax.vmap(lambda p: _pose_energy(p, MOL))(poses)


def accurate(poses):
    return {"out": energies(poses)[:, None]}


def make_region(n, mode="collect", model=None, database=None):
    rngs = {"i": (0, n)}
    return approx_ml(lambda poses: {"out": energies(poses)[:, None]},
                     name="minibude",
                     inputs={"poses": (_ifn, rngs)},
                     outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, database=database)


def qoi_error(ref, approx):
    """MAPE over pose energies."""
    ref = np.asarray(ref).reshape(-1)
    approx = np.asarray(approx).reshape(-1)
    return float(np.mean(np.abs((approx - ref) / (np.abs(ref) + 1e-6)))) * 100


def surrogate_space():
    return {
        "kind": "mlp", "in_dim": 6, "out_dim": 1,
        "n_hidden": (2, 6), "hidden1": (64, 1024, "log2"),
        "feature_mult": (0.1, 0.8),
    }
