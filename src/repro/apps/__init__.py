from repro.apps import binomial, bonds, minibude, miniweather, particlefilter

ALL_APPS = {
    "minibude": minibude,
    "binomial": binomial,
    "bonds": bonds,
    "miniweather": miniweather,
    "particlefilter": particlefilter,
}
