"""Bonds: fixed-rate bond valuation with a flat forward curve.

Accurate path: per-bond loop over coupon periods (masked scan) computing
dirty price and accrued interest.  QoI: accrued interest.  Metric: RMSE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_ml, tensor_functor

MAX_PERIODS = 60  # semiannual coupons, up to 30y

_ifn = tensor_functor("bond_in: [i, 0:4] = ([i, 0:4])")
_ofn = tensor_functor("bond_out: [i, 0:2] = ([i, 0:2])")


def make_inputs(n, seed=0):
    """[n, 4] = (coupon_rate, ytm, years_to_maturity, accrual_frac)."""
    rng = np.random.default_rng(seed)
    coupon = rng.uniform(0.01, 0.09, n)
    ytm = rng.uniform(0.005, 0.10, n)
    years = rng.uniform(0.5, 30.0, n)
    accr = rng.uniform(0.0, 1.0, n)
    return jnp.asarray(np.stack([coupon, ytm, years, accr], 1).astype(np.float32))


def _value_one(bond, face=100.0, freq=2.0):
    coupon, ytm, years, accr = bond[0], bond[1], bond[2], bond[3]
    nper = jnp.floor(years * freq)
    cpn = face * coupon / freq
    per = jnp.arange(1, MAX_PERIODS + 1, dtype=jnp.float32)
    t = (per - accr) / freq
    mask = per <= nper
    df = jnp.exp(-ytm * t)  # flat forward curve, continuous compounding
    pv_coupons = jnp.where(mask, cpn * df, 0.0).sum()
    t_face = (nper - accr) / freq
    pv_face = face * jnp.exp(-ytm * t_face)
    dirty = pv_coupons + pv_face
    accrued = cpn * accr
    return jnp.stack([accrued, dirty])


@jax.jit
def valuations(bonds):
    """[n,4] -> [n,2] = (accrued interest, dirty price)."""
    return jax.vmap(_value_one)(bonds)


def accurate(bonds):
    return {"out": valuations(bonds)}


def make_region(n, mode="collect", model=None, database=None):
    rngs = {"i": (0, n)}
    return approx_ml(lambda bonds: {"out": valuations(bonds)},
                     name="bonds",
                     inputs={"bonds": (_ifn, rngs)},
                     outputs={"out": (_ofn, rngs)},
                     mode=mode, model=model, database=database)


def qoi_error(ref, approx):
    """RMSE over accrued interest (paper's QoI)."""
    ref = np.asarray(ref)[:, 0]
    approx = np.asarray(approx)[:, 0]
    return float(np.sqrt(np.mean((ref - approx) ** 2)))


def surrogate_space():
    return {"kind": "mlp", "in_dim": 4, "out_dim": 2,
            "hidden1": (32, 512, "log2"), "hidden2": (0, 512, "log2")}
