"""ParticleFilter (Rodinia): track an object through noisy video frames.

Accurate path: bootstrap particle filter — propagate, reweight by frame
likelihood, systematic resample, estimate.  It is itself an *algorithmic
approximation* whose RMSE floor is set by measurement noise — the paper's
Observation 1 benchmark (a CNN surrogate beats it on both speed and
accuracy).  QoI: object (x, y) per frame.  Metric: RMSE.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_ml, tensor_functor

H = W = 24
N_PART = 256
NOISE = 0.35

frame_fn = tensor_functor(f"pf_in: [i, 0:{H * W}] = ([i, 0:{H * W}])")
loc_fn = tensor_functor("pf_out: [i, 0:2] = ([i, 0:2])")


def make_video(n_frames, seed=0):
    """Returns (frames [T, H, W], truth [T, 2])."""
    rng = np.random.default_rng(seed)
    pos = np.array([H * 0.3, W * 0.3])
    vel = np.array([0.7, 0.5])
    frames, truth = [], []
    yy, xx = np.mgrid[0:H, 0:W]
    for t in range(n_frames):
        pos = pos + vel + rng.normal(0, 0.15, 2)
        vel = vel * 0.99 + rng.normal(0, 0.05, 2)
        pos = np.clip(pos, 2, H - 3)
        vel = np.where((pos <= 2) | (pos >= H - 3), -vel, vel)
        img = np.exp(-((yy - pos[0]) ** 2 + (xx - pos[1]) ** 2) / 6.0)
        img = img + rng.normal(0, NOISE, img.shape)
        frames.append(img.astype(np.float32))
        truth.append(pos.copy())
    return jnp.asarray(np.stack(frames)), jnp.asarray(
        np.stack(truth).astype(np.float32))


def _pf_step(carry, frame, key):
    parts, vels = carry
    k1, k2, k3 = jax.random.split(key, 3)
    vels = vels * 0.95 + jax.random.normal(k1, vels.shape) * 0.12
    parts = jnp.clip(parts + vels + jax.random.normal(k2, parts.shape) * 0.35,
                     0, H - 1)
    iy = jnp.clip(parts[:, 0].astype(jnp.int32), 1, H - 2)
    ix = jnp.clip(parts[:, 1].astype(jnp.int32), 1, W - 2)
    # 3x3 patch likelihood (template = bright blob center)
    patch = sum(frame[iy + dy, ix + dx]
                for dy in (-1, 0, 1) for dx in (-1, 0, 1)) / 9.0
    w = jax.nn.softmax(patch * 24.0)
    est = (w[:, None] * parts).sum(0)
    # systematic resampling
    cum = jnp.cumsum(w)
    u = (jax.random.uniform(k3) + jnp.arange(N_PART)) / N_PART
    idx = jnp.searchsorted(cum, u)
    return (parts[idx], vels[idx]), est


@functools.partial(jax.jit, static_argnames=())
def track(frames, seed=0):
    """Accurate path: [T, H, W] frames -> [T, 2] estimates."""
    key = jax.random.PRNGKey(seed)
    parts = jnp.full((N_PART, 2), H * 0.3) + \
        jax.random.normal(key, (N_PART, 2)) * 2.0
    vels = jnp.zeros((N_PART, 2))

    def body(carry, xs):
        frame, k = xs
        return _pf_step(carry, frame, k)

    keys = jax.random.split(key, frames.shape[0])
    _, ests = jax.lax.scan(body, (parts, vels), (frames, keys))
    return ests


def accurate(frames):
    return {"loc": track(frames)}


def make_region(n_frames, mode="collect", model=None, database=None):
    """Region input is the flattened video [T, H*W] (tensor-space layout)."""
    rngs = {"i": (0, n_frames)}
    return approx_ml(
        lambda frames: {"loc": track(frames.reshape(-1, H, W))},
        name="particlefilter",
        inputs={"frames": (frame_fn, {"i": (0, n_frames)})},
        outputs={"loc": (loc_fn, rngs)},
        mode=mode, model=model, database=database)


def qoi_error(truth, est):
    t = np.asarray(truth).reshape(-1, 2)
    e = np.asarray(est).reshape(-1, 2)
    return float(np.sqrt(np.mean(np.sum((t - e) ** 2, axis=1))))


def surrogate_space():
    return {"kind": "cnn", "grid": (H, W), "in_ch": 1, "out_ch": 2,
            "conv_k": (2, 8), "stride": (1, 4), "pool": (1, 4),
            "fc2": (0, 128)}
