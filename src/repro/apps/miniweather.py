"""MiniWeather: 2-D atmospheric dynamics (advection + buoyancy + diffusion).

State: [ny, nx, 4] = (density, x-momentum, y-momentum, potential temp).
The accurate timestep is a 5-point-stencil finite-volume update — the
exact shape of the paper's Fig. 2 example, and the app that exercises the
stencil tensor-functor data bridge and the Observation-4 interleaving
(auto-regressive error propagation).

QoI: the state fields.  Metric: RMSE.  Surrogate: CNN grid -> grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx_ml, tensor_functor

NY, NX, NF = 32, 32, 4
DT = 0.02

# 5-point stencil over each of the 4 fields (paper Fig. 2's ifnctr,
# extended with a field axis): 20 features per grid point.
stencil_fn = tensor_functor(
    "mw_in: [i, j, 0:5, 0:4] = "
    "([i-1, j, 0:4], [i+1, j, 0:4], [i, j-1:j+2, 0:4])")
point_fn = tensor_functor("mw_out: [i, j, 0:4] = ([i, j, 0:4])")

RANGES = {"i": (1, NY - 1), "j": (1, NX - 1)}


def init_state(seed=0):
    rng = np.random.default_rng(seed)
    y, x = np.mgrid[0:NY, 0:NX] / NY
    rho = 1.0 + 0.1 * np.exp(-((x - 0.3) ** 2 + (y - 0.5) ** 2) * 40)
    u = 0.1 * np.ones_like(x)
    w = np.zeros_like(x)
    theta = 300.0 + 2.0 * np.exp(-((x - 0.6) ** 2 + (y - 0.4) ** 2) * 30) \
        + 0.01 * rng.normal(size=x.shape)
    s = np.stack([rho, u, w, (theta - 300.0)], -1).astype(np.float32)
    return jnp.asarray(s)


@jax.jit
def timestep(state):
    """One accurate finite-volume-style update (interior points)."""
    s = state
    sN = s[:-2, 1:-1]
    sS = s[2:, 1:-1]
    sW = s[1:-1, :-2]
    sE = s[1:-1, 2:]
    sC = s[1:-1, 1:-1]
    rho, u, w, th = sC[..., 0], sC[..., 1], sC[..., 2], sC[..., 3]
    # upwind-ish advection + diffusion + buoyancy forcing
    ddx = (sE - sW) * 0.5
    ddy = (sS - sN) * 0.5
    lap = sN + sS + sW + sE - 4 * sC
    adv = -(u[..., None] * ddx + w[..., None] * ddy)
    new = sC + DT * (adv + 0.08 * lap)
    buoy = 0.05 * th  # potential-temp anomaly drives vertical momentum
    new = new.at[..., 2].add(DT * buoy)
    new = new.at[..., 3].add(-DT * 0.02 * w * th)
    return state.at[1:-1, 1:-1].set(new)


def accurate(state):
    return {"state": timestep(state)}


def make_region(mode="collect", model=None, database=None, serving=None):
    return approx_ml(lambda state: {"state": timestep(state)},
                     name="miniweather",
                     inputs={"state": (stencil_fn, RANGES)},
                     outputs={"state": (point_fn, RANGES)},
                     mode=mode, model=model, database=database,
                     serving=serving)


def run(state, steps, region=None, interleave=(0, 1), predicate_fn=None):
    """Advance `steps`; interleave = (n_accurate, n_surrogate) per cycle."""
    na, ns = interleave
    cyc = max(1, na + ns)
    for t in range(steps):
        use_ml = (t % cyc) >= na if region is not None else False
        if region is None:
            state = timestep(state)
        else:
            state = region(predicate=use_ml, state=state)["state"]
    return state


def run_ensemble_async(states, steps, region, queue):
    """Advance an ensemble of trajectories through a serve queue.

    A single trajectory is auto-regressive — its surrogate calls cannot
    batch with each other — but an *ensemble* of E members can: every
    sweep step enqueues E one-grid requests (mode="infer_async") that
    the queue coalesces into one mesh-wide batch, so surrogate inference
    is E-way batched even though each member still steps sequentially.
    """
    assert region.mode == "infer_async" and region.serving is queue
    states = list(states)
    for _ in range(steps):
        handles = [region(state=s) for s in states]
        queue.flush(region.model_path, reason="sweep_step")
        states = [h.result()["state"] for h in handles]
    return states


def qoi_error(ref, approx):
    return float(jnp.sqrt(jnp.mean((ref - approx) ** 2)))


def surrogate_space():
    return {"kind": "cnn", "grid": (NY - 2, NX - 2), "in_ch": 20,
            "out_ch": 4, "k1": (2, 8), "ch1": (4, 8), "k2": (0, 6)}
