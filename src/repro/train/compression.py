"""Gradient compression: int8 quantization with error feedback.

At multi-pod scale the cross-pod gradient reduction rides the slowest
links; quantizing the reduced tensor to int8 (per-leaf scale) cuts those
wire bytes 2x vs bf16 / 4x vs f32.  The quantization error is carried in
an error-feedback residual (SGD-with-EF converges at the full-precision
rate for smooth objectives), tested in tests/test_compression.py.

Inside one pjit program the cross-pod reduction is XLA-generated, so the
compressor exposes two forms:
  * ``ef_compress(grads, residual)`` — drop-in grad transform (quantize ->
    dequantize + residual update), modelling end-to-end numerics;
  * ``wire_bytes(grads)`` — the analytic wire saving recorded in §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q_leaf(g, r):
    gf = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def init_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress(grads, residual):
    """Returns (dequantized grads, new residual)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [_q_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def wire_bytes(grads, dtype_bytes=4):
    """(uncompressed, int8) wire bytes for one cross-pod all-reduce."""
    n = sum(x.size for x in jax.tree.leaves(grads))
    return n * dtype_bytes, n * 1 + 4 * len(jax.tree.leaves(grads))
