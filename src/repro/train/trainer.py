"""Production train step: fwd + bwd + clip + AdamW (+ optional microbatch
grad accumulation and int8 gradient compression across the pod axis).

Checkpointing entry points (`save_train_state` / `restore_train_state`)
connect `ckpt.CheckpointManager` to the dist substrate: restore derives
per-leaf NamedShardings from ``dist.sharding.param_spec_tree`` for the
*current* mesh, so a job resumed on a different topology than the writer
lays its state out elastically (the reshard path tested in ckpt)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state, warmup_cosine)


def make_train_state(rng, cfg):
    params = lm.init_params(rng, cfg)
    opt = init_opt_state(params, cfg.opt_policy)
    return {"params": params, "opt": opt}


def state_shardings(cfg, state_like, mesh=None, multi_pod: bool = False):
    """NamedSharding pytree for a train state on the active (or given) mesh.

    Name-driven: optimizer m/v/master mirrors reuse the param rules, the
    step counter and norm scales replicate.  Returns None when no mesh is
    available (eager CPU runs restore unsharded).
    """
    from jax.sharding import NamedSharding
    from repro.dist.sharding import current_ctx, param_spec_tree
    if mesh is None:
        ctx = current_ctx()
        if ctx is None or ctx.mesh is None:
            return None
        mesh, multi_pod = ctx.mesh, ctx.multi_pod
    specs = param_spec_tree(state_like, cfg, mesh, multi_pod)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))


def save_train_state(mgr, step: int, state) -> None:
    """Checkpoint a (possibly sharded) train state.

    The manager gathers every leaf to a global host array, so the written
    checkpoint is topology-free — any later mesh can restore it.
    """
    mgr.save(step, state)


def restore_train_state(mgr, cfg, state_like, step: Optional[int] = None,
                        mesh=None, multi_pod: bool = False):
    """Restore a train state, elastically laid out for the current mesh.

    ``state_like`` gives the tree structure/dtypes (e.g. a fresh
    ``make_train_state`` or its ``jax.eval_shape``); shardings come from
    ``param_spec_tree`` against the active ``use_mesh`` context unless a
    mesh is passed explicitly.  Returns ``(state, step)``.
    """
    shardings = state_shardings(cfg, state_like, mesh, multi_pod)
    return mgr.restore(state_like, step, shardings=shardings)


def compute_grads(cfg, params, batch, *, microbatches: int = 1):
    """Loss + grads, optionally accumulated over microbatches."""
    if microbatches <= 1:
        return jax.value_and_grad(lambda p: lm.train_loss(cfg, p, batch))(params)

    B = batch["tokens"].shape[0]
    assert B % microbatches == 0
    mb = B // microbatches

    def slice_mb(i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb,
                                                   axis=1 if x.ndim == 3 and x.shape[0] == 3 else 0),
            batch)

    def body(carry, i):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(
            lambda p: lm.train_loss(cfg, p, slice_mb(i)))(params)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (loss_acc + loss, g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (0.0, g0), jnp.arange(microbatches))
    grads = jax.tree.map(lambda g: (g / microbatches), grads)
    return loss / microbatches, grads


def train_step(cfg, state, batch, *, step=None, microbatches: int = 1,
               peak_lr=3e-4, total_steps=10000, grad_compress=None):
    """One full optimizer step. Returns (new_state, metrics)."""
    params, opt = state["params"], state["opt"]
    loss, grads = compute_grads(cfg, params, batch, microbatches=microbatches)
    if grad_compress is not None:
        grads = grad_compress(grads)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    lr = warmup_cosine(opt["step"] if step is None else step,
                       peak_lr=peak_lr, total=total_steps)
    new_params, new_opt = adamw_update(params, grads, opt, lr,
                                       policy=cfg.opt_policy)
    return ({"params": new_params, "opt": new_opt},
            {"loss": loss, "grad_norm": gnorm, "lr": lr})
