"""Production train step: fwd + bwd + clip + AdamW (+ optional microbatch
grad accumulation and int8 gradient compression across the pod axis)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state, warmup_cosine)


def make_train_state(rng, cfg):
    params = lm.init_params(rng, cfg)
    opt = init_opt_state(params, cfg.opt_policy)
    return {"params": params, "opt": opt}


def compute_grads(cfg, params, batch, *, microbatches: int = 1):
    """Loss + grads, optionally accumulated over microbatches."""
    if microbatches <= 1:
        return jax.value_and_grad(lambda p: lm.train_loss(cfg, p, batch))(params)

    B = batch["tokens"].shape[0]
    assert B % microbatches == 0
    mb = B // microbatches

    def slice_mb(i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb,
                                                   axis=1 if x.ndim == 3 and x.shape[0] == 3 else 0),
            batch)

    def body(carry, i):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(
            lambda p: lm.train_loss(cfg, p, slice_mb(i)))(params)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (loss_acc + loss, g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (0.0, g0), jnp.arange(microbatches))
    grads = jax.tree.map(lambda g: (g / microbatches), grads)
    return loss / microbatches, grads


def train_step(cfg, state, batch, *, step=None, microbatches: int = 1,
               peak_lr=3e-4, total_steps=10000, grad_compress=None):
    """One full optimizer step. Returns (new_state, metrics)."""
    params, opt = state["params"], state["opt"]
    loss, grads = compute_grads(cfg, params, batch, microbatches=microbatches)
    if grad_compress is not None:
        grads = grad_compress(grads)
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    lr = warmup_cosine(opt["step"] if step is None else step,
                       peak_lr=peak_lr, total=total_steps)
    new_params, new_opt = adamw_update(params, grads, opt, lr,
                                       policy=cfg.opt_policy)
    return ({"params": new_params, "opt": new_opt},
            {"loss": loss, "grad_norm": gnorm, "lr": lr})
