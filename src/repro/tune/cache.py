"""On-disk autotune cache: measured kernel configs keyed by served shape.

One JSON file per kernel under ``artifacts/tune/`` (e.g.
``fused_mlp.json``) maps a shape key to the measured winner:

    key:    "<w0-w1-...-wn>|<dtype>|<backend>|b<bucket>"
    record: {"batch_tile": int, "us": float, "default_us": float,
             "speedup_x": float, "exact": bool, "swept": [...]}

The *bucket* is the serve-path batch bucket (power of two — the only
batch shapes the engine's ``apply_batched`` ever dispatches), so eager
calls of any size hit the same entry their padded bucket would.

Lookups sit on the trace-time hot path (``fused_mlp_op`` consults the
cache while the engine's apply is being traced), so the file is parsed
once and memoized; an mtime fingerprint re-reads it when another
process (``tune.autotune`` warm-up, ``dryrun --tune``) rewrites it.
Writes are atomic (tmp + rename) so a crashed sweep never leaves a
torn file behind.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import threading
from typing import Dict, Iterable, Optional

import numpy as np

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "tune"


def _dtype_name(dtype) -> str:
    """Canonical dtype spelling: jnp.float32 (a type), np.float32, and an
    array's ``.dtype`` must all key identically — str() on the raw type
    yields "<class ...>" and would split the cache between the tuner
    (stores types) and the serving path (looks up array dtypes)."""
    try:
        return str(np.dtype(dtype))
    except TypeError:
        return str(dtype)


def shape_key(widths: Iterable[int], dtype, backend: str, bucket: int) -> str:
    w = "-".join(str(int(v)) for v in widths)
    return f"{w}|{_dtype_name(dtype)}|{backend}|b{int(bucket)}"


class TuneCache:
    """Persistent measured-config store for one kernel family."""

    def __init__(self, kernel: str = "fused_mlp", path=None):
        self.kernel = kernel
        self.path = pathlib.Path(path) if path is not None else (
            ART / f"{kernel}.json")
        self._lock = threading.Lock()
        self._mem: Dict[str, dict] = {}
        self._fingerprint = None  # (mtime_ns, size) of the last read

    # ---------------------------------------------------------- storage ---
    def _file_fingerprint(self):
        try:
            st = os.stat(self.path)
            return (st.st_mtime_ns, st.st_size)
        except OSError:
            return None

    def _refresh_locked(self) -> None:
        fp = self._file_fingerprint()
        if fp == self._fingerprint:
            return
        self._fingerprint = fp
        if fp is None:
            self._mem = {}
            return
        try:
            data = json.loads(self.path.read_text())
            self._mem = data if isinstance(data, dict) else {}
        except (OSError, ValueError):
            # a torn/corrupt cache is a cache miss, never a crash
            self._mem = {}

    def _save_locked(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._mem, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._fingerprint = self._file_fingerprint()

    # -------------------------------------------------------------- api ---
    def lookup(self, widths, dtype, backend: str,
               bucket: int) -> Optional[dict]:
        with self._lock:
            self._refresh_locked()
            return self._mem.get(shape_key(widths, dtype, backend, bucket))

    def store(self, widths, dtype, backend: str, bucket: int,
              record: dict) -> None:
        with self._lock:
            self._refresh_locked()  # merge with concurrent writers' entries
            self._mem[shape_key(widths, dtype, backend, bucket)] = record
            self._save_locked()

    def entries(self) -> Dict[str, dict]:
        with self._lock:
            self._refresh_locked()
            return dict(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            if self.path.exists():
                self.path.unlink()
            self._fingerprint = None


# process-wide default cache (what the serving hot path consults)
_default: Dict[str, TuneCache] = {}
_default_lock = threading.Lock()


def default_cache(kernel: str = "fused_mlp") -> TuneCache:
    with _default_lock:
        c = _default.get(kernel)
        if c is None:
            c = _default[kernel] = TuneCache(kernel)
        return c


def best_tile(widths, dtype, backend: str, batch: int) -> Optional[int]:
    """Tuned ``batch_tile`` for a fused_mlp call, or None when untuned.

    The exact batch is tried first — serve-path dispatches (and the
    per-shard batches inside ``fused_mlp_sharded``) arrive already
    bucket-shaped, including the non-power-of-two buckets a shard-count
    rounding produces — then the power-of-two bucket, which covers
    eager calls of arbitrary size.  Only validated winners are
    returned — the kernel must never pick up a tile that failed the
    exactness check against ref.py.
    """
    from repro.serve.batcher import bucket_size
    cache = default_cache()
    batch = int(batch)
    rec = None
    for bucket in dict.fromkeys((batch, bucket_size(batch))):
        rec = cache.lookup(widths, dtype, backend, bucket)
        if rec is not None:
            break
    if rec is None or not rec.get("exact", False):
        return None
    tile = int(rec["batch_tile"])
    return tile if tile > 0 else None
