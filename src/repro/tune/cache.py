"""On-disk autotune cache: measured kernel configs, kernel-namespaced.

One JSON file per registered kernel under ``artifacts/tune/``
(``fused_mlp.json``, ``flash_attention.json``, ``stencil_gather.json``,
...), schema 2:

    {"schema": 2, "kernel": "<name>", "entries": {key: record}}

Keys are kernel-defined problem strings (``KernelSpec.cache_key``; for
fused_mlp the historical ``"<w0-w1-...>|<dtype>|<backend>|b<bucket>"``
format is preserved).  Records carry the measured winner:

    {"params": {"batch_tile": 64}, "us": float, "default_us": float,
     "speedup_x": float, "exact": bool, "swept": [...]}

plus — for fused_mlp back-compat — the winner's params flattened at the
top level (``"batch_tile": 64``).

**Migration:** schema-1 files were a flat ``{key: record}`` dict with no
envelope and per-record ``batch_tile`` instead of ``params``.  The first
load of a legacy file lifts it into the schema-2 layout (adding
``params`` to each record) and rewrites the file atomically, so deployed
caches and the CI ``actions/cache`` entry survive the registry refactor;
a read-only filesystem just keeps serving the migrated view from memory.

Lookups sit on the trace-time hot path (the registry dispatch consults
the cache while the engine's apply is being traced), so the file is
parsed once and memoized; an mtime fingerprint re-reads it when another
process (``tune.sweep`` warm-up, ``dryrun --tune``) rewrites it.  Writes
are atomic (tmp + rename) so a crashed sweep never leaves a torn file.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import threading
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "tune"

SCHEMA = 2


def _dtype_name(dtype) -> str:
    """Canonical dtype spelling: jnp.float32 (a type), np.float32, and an
    array's ``.dtype`` must all key identically — str() on the raw type
    yields "<class ...>" and would split the cache between the tuner
    (stores types) and the serving path (looks up array dtypes)."""
    try:
        return str(np.dtype(dtype))
    except TypeError:
        return str(dtype)


def shape_key(widths: Iterable[int], dtype, backend: str, bucket: int) -> str:
    """The fused_mlp cache key (kept byte-identical to the schema-1
    format so legacy entries keep hitting after migration)."""
    w = "-".join(str(int(v)) for v in widths)
    return f"{w}|{_dtype_name(dtype)}|{backend}|b{int(bucket)}"


def _migrate_record(rec: dict) -> dict:
    """Schema-1 records carried the winner as a bare ``batch_tile``."""
    if isinstance(rec, dict) and "params" not in rec and "batch_tile" in rec:
        rec = dict(rec, params={"batch_tile": rec["batch_tile"]})
    return rec


class TuneCache:
    """Persistent measured-config store for one kernel family."""

    def __init__(self, kernel: str = "fused_mlp", path=None):
        self.kernel = kernel
        self.path = pathlib.Path(path) if path is not None else (
            ART / f"{kernel}.json")
        self._lock = threading.Lock()
        self._mem: Dict[str, dict] = {}
        self._fingerprint = None  # (mtime_ns, size) of the last read

    # ---------------------------------------------------------- storage ---
    def _file_fingerprint(self):
        try:
            st = os.stat(self.path)
            return (st.st_mtime_ns, st.st_size)
        except OSError:
            return None

    def _refresh_locked(self) -> None:
        fp = self._file_fingerprint()
        if fp == self._fingerprint:
            return
        self._fingerprint = fp
        if fp is None:
            self._mem = {}
            return
        try:
            data = json.loads(self.path.read_text())
        except (OSError, ValueError):
            # a torn/corrupt cache is a cache miss, never a crash
            self._mem = {}
            return
        if not isinstance(data, dict):
            self._mem = {}
            return
        if data.get("schema") == SCHEMA:
            ent = data.get("entries")
            self._mem = ent if isinstance(ent, dict) else {}
            return
        # schema-1 legacy: a flat {key: record} dict — lift it into the
        # namespaced layout and persist the migration atomically
        self._mem = {k: _migrate_record(v) for k, v in data.items()
                     if isinstance(v, dict)}
        try:
            self._save_locked()
        except OSError:
            pass  # read-only checkout: serve the migrated view from memory

    def _save_locked(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": SCHEMA, "kernel": self.kernel,
                           "entries": self._mem}, f, indent=1,
                          sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._fingerprint = self._file_fingerprint()

    # -------------------------------------------------------------- api ---
    def get(self, key: str) -> Optional[dict]:
        """Record for a kernel-defined cache key, or None."""
        with self._lock:
            self._refresh_locked()
            return self._mem.get(key)

    def put(self, key: str, record: dict) -> None:
        with self._lock:
            self._refresh_locked()  # merge with concurrent writers' entries
            self._mem[key] = record
            self._save_locked()

    def lookup(self, widths, dtype, backend: str,
               bucket: int) -> Optional[dict]:
        """fused_mlp-shaped convenience lookup (legacy API)."""
        return self.get(shape_key(widths, dtype, backend, bucket))

    def store(self, widths, dtype, backend: str, bucket: int,
              record: dict) -> None:
        self.put(shape_key(widths, dtype, backend, bucket), record)

    def entries(self) -> Dict[str, dict]:
        with self._lock:
            self._refresh_locked()
            return dict(self._mem)

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            if self.path.exists():
                self.path.unlink()
            self._fingerprint = None


# process-wide default caches (what the serving hot path consults)
_default: Dict[str, TuneCache] = {}
_default_lock = threading.Lock()


def default_cache(kernel: str = "fused_mlp") -> TuneCache:
    with _default_lock:
        c = _default.get(kernel)
        if c is None:
            c = _default[kernel] = TuneCache(kernel)
        return c


def _record_params(rec: Optional[dict]) -> Optional[Dict[str, int]]:
    """Validated winner params of a record, or None.

    Only validated winners are served — the kernel must never pick up a
    config that failed the oracle check.  Schema-1 records that reached
    memory without migration still resolve via ``batch_tile``.
    """
    if rec is None or not rec.get("exact", False):
        return None
    params = rec.get("params")
    if params is None and "batch_tile" in rec:
        params = {"batch_tile": rec["batch_tile"]}
    if not isinstance(params, dict) or not params:
        return None
    try:
        return {k: int(v) for k, v in params.items()}
    except (TypeError, ValueError):
        return None


def best_params(kernel: str, keys: Sequence[str]) -> Optional[Dict[str, int]]:
    """First validated winner along ``keys`` (ordered lookup fallbacks,
    e.g. fused_mlp's exact-batch-then-pow2-bucket chain), or None.

    Outcomes publish to the obs metrics layer: sustained misses mean the
    serving shapes have drifted away from what the sweep tuned, and the
    per-key miss counter is the signal the planned online re-sweep will
    trigger from.
    """
    from repro.obs import metrics as _m
    cache = default_cache(kernel)
    for key in keys:
        params = _record_params(cache.get(key))
        if params is not None:
            _m.counter("repro_tune_cache_lookups_total",
                       "tune-cache lookups by outcome",
                       ("kernel", "outcome")).inc(
                1, kernel=kernel, outcome="hit")
            return params
    _m.counter("repro_tune_cache_lookups_total",
               "tune-cache lookups by outcome",
               ("kernel", "outcome")).inc(1, kernel=kernel, outcome="miss")
    if keys:
        # the most specific key is the serving shape that went untuned —
        # exactly what a drift-triggered re-sweep needs to know
        _m.counter("repro_tune_cache_miss_keys_total",
                   "tune-cache lookup chains that missed, by leading key",
                   ("kernel", "key")).inc(1, kernel=kernel, key=keys[0])
    return None


def best_tile(widths, dtype, backend: str, batch: int) -> Optional[int]:
    """Tuned ``batch_tile`` for a fused_mlp call, or None when untuned.

    The exact batch is tried first — serve-path dispatches (and the
    per-shard batches inside ``fused_mlp_sharded``) arrive already
    bucket-shaped, including the non-power-of-two buckets a shard-count
    rounding produces — then the power-of-two bucket, which covers
    eager calls of arbitrary size.
    """
    from repro.serve.batcher import bucket_size
    batch = int(batch)
    keys = [shape_key(widths, dtype, backend, b)
            for b in dict.fromkeys((batch, bucket_size(batch)))]
    params = best_params("fused_mlp", keys)
    if params is None:
        return None
    tile = params.get("batch_tile")
    return int(tile) if tile and tile > 0 else None
