"""Online kernel re-sweep on serving-shape drift.

The tune cache is warmed at deploy for the shapes the flush policy was
*expected* to produce.  When traffic drifts — a new app submits batches
that coalesce into a bucket nobody tuned — every dispatch of that shape
silently serves the default tile and the
``repro_tune_cache_miss_keys_total`` counter climbs forever.  This
module closes the loop: the batcher reports each completed batch, and
once a (bundle, bucket) has sustained ``REPRO_RESWEEP_AFTER`` real
dispatches with no tune-cache entry for its key, a sweep of that single
cell is enqueued on a low-priority background worker (same discipline
as the shadow scorer: daemon thread, bounded queue, duty-cycle cap —
the sweep's compile storms must never contend with serving).

For a bundle serving the gated int8 tier the worker sweeps the
``fused_mlp_int8`` cell as well as the f32 one: both tiers' ladders
stay warm, so a gate decision never flips the engine onto untuned
tiles.

Off by default; enabled with ``REPRO_RESWEEP=1`` (or programmatically
via ``get_resweeper().enable()``).  Completed sweeps count in
``repro_tune_resweep_total{kernel}``.
"""
from __future__ import annotations

import os
import queue as _queue
import threading
import time
from typing import Optional, Set, Tuple

from repro.obs import TRACER
from repro.obs import metrics as _m

def _acts_from_layers(layers) -> tuple:
    """Per-dense activation names of a bundle's layer specs (the walk
    ``mlp_stack_from_spec`` does, minus the arrays): the re-swept cell
    must key and validate with the acts the bundle actually serves."""
    acts, pending = [], False
    for l in layers:
        kind = l.get("kind")
        if kind == "dense":
            if pending:
                acts.append("identity")
            pending = True
        elif kind == "act":
            acts.append(l.get("name"))
            pending = False
    if pending:
        acts.append("identity")
    return tuple(acts)


_RESWEEPS = _m.counter(
    "repro_tune_resweep_total",
    "drift-triggered background kernel sweeps completed",
    ("kernel",))
_ENQUEUED = _m.counter(
    "repro_tune_resweep_enqueued_total",
    "drift-triggered sweep cells enqueued", ("kernel",))


class ResweepWorker:
    """Drift-triggered background autotuner (one per process)."""

    #: batches a bucket must sustain before its miss triggers a sweep
    DEFAULT_AFTER = 32
    #: worker CPU share cap, same contract as ShadowScorer.DUTY_CYCLE
    DUTY_CYCLE = 0.25

    def __init__(self, after: Optional[int] = None,
                 max_backlog: int = 16):
        env = os.environ.get("REPRO_RESWEEP", "").strip().lower()
        self.enabled = env in ("1", "true", "on")
        if after is None:
            after = int(os.environ.get("REPRO_RESWEEP_AFTER",
                                       self.DEFAULT_AFTER))
        self.after = int(after)
        self.max_backlog = int(max_backlog)
        self._lock = threading.Lock()
        self._q: "_queue.Queue[Optional[tuple]]" = _queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._pending = 0
        # cells already enqueued or swept this process: the trigger must
        # fire once per (kernel, key), not once per batch past threshold
        self._seen: Set[Tuple[str, str]] = set()

    # ---------------------------------------------------------- control ---
    def enable(self, after: Optional[int] = None) -> "ResweepWorker":
        if after is not None:
            self.after = int(after)
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Forget triggered cells (tests)."""
        with self._lock:
            self._seen.clear()

    # ---------------------------------------------------------- trigger ---
    def observe(self, engine, bucket: int, stats) -> bool:
        """One completed batch for ``engine`` at ``bucket`` rows.

        Called by the batcher after ``stats.on_batch``; the fast path
        (disabled, below threshold, or already triggered) is a couple of
        dict probes.  Returns True when a sweep cell was enqueued.
        """
        if not self.enabled:
            return False
        if stats.bucket_batches(bucket) < self.after:
            return False
        import jax

        from repro.tune.cache import best_params, shape_key
        from repro.tune.kernel_tuner import widths_from_spec
        widths = widths_from_spec(engine.spec)
        if widths is None:
            return False  # not the fused kernel's shape: nothing to tune
        dtype = "float32"
        key = shape_key(widths, dtype, jax.default_backend(), int(bucket))
        tiers = [("fused_mlp", key)]
        if getattr(engine, "tier", "f32") == "int8":
            tiers.append(("fused_mlp_int8", key))
        enqueued = False
        for kernel, k in tiers:
            with self._lock:
                if (kernel, k) in self._seen:
                    continue
                if self._pending >= self.max_backlog:
                    break  # bounded backlog: drop, re-trigger next batch
                # suppress only when the *serving* lookup would hit —
                # a gate-fail record (exact=False) still counts as a miss
                if best_params(kernel, [k]) is not None:
                    self._seen.add((kernel, k))
                    continue
                self._seen.add((kernel, k))
                self._pending += 1
                self._ensure_thread_locked()
            self._q.put((kernel, tuple(widths), int(bucket), dtype,
                         _acts_from_layers(engine.spec.get("layers", ()))
                         or None))
            _ENQUEUED.inc(1, kernel=kernel)
            enqueued = True
        return enqueued

    def _ensure_thread_locked(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="repro-tune-resweep", daemon=True)
            self._thread.start()

    # ----------------------------------------------------------- worker ---
    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            kernel, widths, bucket, dtype, acts = item
            t0 = time.monotonic()
            try:
                with TRACER.span("tune.resweep", cat="tune",
                                 args={"kernel": kernel,
                                       "widths": list(widths),
                                       "bucket": bucket}):
                    self._sweep_cell(kernel, widths, bucket, dtype, acts)
                _RESWEEPS.inc(1, kernel=kernel)
            except Exception as e:  # a failed sweep must never kill serving
                _m.warn_once(
                    f"resweep-error:{kernel}:{widths}:{bucket}",
                    f"background re-sweep failed for {kernel} "
                    f"widths={widths} bucket={bucket}: {e!r}")
            finally:
                busy = time.monotonic() - t0
                with self._lock:
                    self._pending -= 1
                self._q.task_done()
                # low priority: a sweep is seconds of compile+measure, so
                # the duty-cycle sleep is capped rather than proportional
                d = self.DUTY_CYCLE
                time.sleep(min(2.0, busy * (1.0 - d) / d))

    @staticmethod
    def _sweep_cell(kernel, widths, bucket, dtype, acts) -> None:
        from repro.tune.kernel_tuner import _acts_for, sweep
        problem = {"widths": tuple(widths),
                   "acts": _acts_for(len(widths) - 1, acts),
                   "batch": int(bucket), "dtype": dtype}
        sweep(kernel, problem)

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until the backlog drains (tests/benches)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self._pending == 0:
                    return True
            time.sleep(0.01)
        return False


_resweeper: Optional[ResweepWorker] = None
_resweeper_lock = threading.Lock()


def get_resweeper() -> ResweepWorker:
    global _resweeper
    with _resweeper_lock:
        if _resweeper is None:
            _resweeper = ResweepWorker()
        return _resweeper
