"""Measurement-driven batch-tile autotuning for the fused_mlp kernel.

``fused_mlp`` tiles the batch over the Pallas grid with a hardcoded 128
unless told otherwise; the right tile depends on the net's widths, the
dtype, and the batch bucket the serve path actually dispatches.  This
module sweeps the candidate tiles that fit VMEM (``fits_vmem`` — exact
accounting, see fused_mlp.py), validates every candidate bit-for-bit
against the ``ref.py`` oracle, and persists winners in the on-disk
:class:`repro.tune.cache.TuneCache` that ``fused_mlp_op`` consults.

Entry points:

  * :func:`sweep_fused_mlp` — one (widths, bucket) cell: measure, pick,
    store.
  * :func:`autotune` — warm-up over the shapes an engine bundle serves
    (the buckets ``InferenceEngine.apply_batched`` can produce), or over
    explicit widths.  Call it once at deploy; the cache makes it free
    afterwards.

Measurements run whatever path the op would take on this backend: the
compiled Pallas kernel on TPU, interpret mode elsewhere (slower in
absolute terms, but the grid/tile tradeoff ranks the same way: fewer,
fatter tiles amortize per-step overhead until VMEM or padding waste
pushes back).
"""
from __future__ import annotations

import functools
import time
from typing import Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_mlp.fused_mlp import fits_vmem, fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref
from repro.tune.cache import TuneCache, default_cache

DEFAULT_TILE = 128
_CANDIDATE_TILES = (16, 32, 64, 128, 256, 512)


def widths_from_spec(spec: dict) -> Optional[List[int]]:
    """Dense widths of a pure-MLP bundle spec, or None if not pure-MLP.

    Mirrors the adapter logic in ``fused_mlp_from_spec``: flatten folds
    trailing dims into the feature dim, acts don't change widths.
    """
    in_shape = spec.get("in_shape") or ()
    feat = 1
    for d in in_shape[1:]:
        feat *= int(d)
    widths = [feat]
    for layer in spec.get("layers", ()):
        kind = layer.get("kind")
        if kind == "dense":
            widths.append(int(layer["features"]))
        elif kind in ("act", "flatten"):
            continue
        else:
            return None  # conv/pool/... : not the fused kernel's shape
    return widths if len(widths) > 1 else None


def _acts_for(n_layers: int, acts=None) -> tuple:
    if acts is not None:
        return tuple(acts)
    return ("relu",) * (n_layers - 1) + ("identity",)


def candidate_tiles(widths: Sequence[int], bucket: int,
                    extra: Iterable[int] = ()) -> List[int]:
    """Tiles worth sweeping for one bucket: the standard ladder clipped
    to the bucket, the bucket itself (grid of 1), and any extras —
    deduped, VMEM-checked, default first so ties keep the default."""
    cands = [DEFAULT_TILE]
    for t in list(_CANDIDATE_TILES) + [bucket] + list(extra):
        t = int(t)
        if t <= 0 or t > bucket or t in cands:
            continue
        cands.append(t)
    return [t for t in cands if fits_vmem(widths, t)]


def _measure_us(fn, reps: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def sweep_fused_mlp(widths: Sequence[int], bucket: int, *,
                    dtype=jnp.float32, acts=None, reps: int = 5,
                    warmup: int = 2, cache: Optional[TuneCache] = None,
                    seed: int = 0, force: bool = False) -> dict:
    """Measure every candidate tile for one (widths, bucket) cell.

    Returns (and persists) the record ``fused_mlp_op`` will consult.
    Candidates whose output is not bit-identical to the ref oracle are
    disqualified — a tuned config must never change serving results.
    """
    widths = [int(w) for w in widths]
    bucket = int(bucket)
    cache = cache or default_cache()
    backend = jax.default_backend()
    cached = None if force else cache.lookup(widths, dtype, backend, bucket)
    if cached is not None:
        return cached

    acts = _acts_for(len(widths) - 1, acts)
    rng = np.random.default_rng(seed)
    ws = [jnp.asarray(rng.normal(size=(a, b)).astype(np.float32) * 0.3,
                      dtype) for a, b in zip(widths[:-1], widths[1:])]
    bs = [jnp.asarray(rng.normal(size=(b,)).astype(np.float32) * 0.1, dtype)
          for b in widths[1:]]
    x = jnp.asarray(rng.normal(size=(bucket, widths[0])).astype(np.float32),
                    dtype)
    # jitted oracle: the serving path always runs compiled, and XLA's
    # eager-vs-compiled dots round differently — compare like with like
    ref = np.asarray(jax.jit(fused_mlp_ref, static_argnames=("acts",))(
        x, ws, bs, acts=acts))
    interpret = backend != "tpu"

    swept = []
    for tile in candidate_tiles(widths, bucket):
        fn = jax.jit(functools.partial(fused_mlp, batch_tile=tile,
                                       interpret=interpret),
                     static_argnames=("acts",))
        try:
            out = np.asarray(fn(x, ws, bs, acts=acts))
            exact = bool(np.array_equal(out, ref))
            us = _measure_us(lambda: fn(x, ws, bs, acts=acts), reps, warmup)
        except Exception as e:  # a tile the backend rejects is just skipped
            swept.append({"batch_tile": tile, "us": None, "exact": False,
                          "error": f"{type(e).__name__}: {e}"[:200]})
            continue
        swept.append({"batch_tile": tile, "us": round(us, 2),
                      "exact": exact})

    valid = [s for s in swept if s["exact"]]
    default = next((s for s in swept
                    if s["batch_tile"] == DEFAULT_TILE and s["us"]), None)
    if valid:
        best = min(valid, key=lambda s: s["us"])
        default_us = default["us"] if default else best["us"]
        rec = {"batch_tile": best["batch_tile"], "us": best["us"],
               "default_us": default_us,
               "speedup_x": round(default_us / best["us"], 3)
               if best["us"] else 1.0,
               "exact": True, "backend": backend, "swept": swept,
               "tuned_at": time.time()}
    else:  # nothing validated: record the failure so we don't re-sweep,
        # but best_tile() will refuse to serve it (exact=False)
        rec = {"batch_tile": DEFAULT_TILE, "us": None,
               "default_us": default["us"] if default else None,
               "speedup_x": 1.0, "exact": False, "backend": backend,
               "swept": swept, "tuned_at": time.time()}
    cache.store(widths, dtype, backend, bucket, rec)
    return rec


def serve_buckets(min_bucket: int = 8, max_batch_rows: int = 1024,
                  n_shards: int = 1) -> List[int]:
    """The batch buckets ``apply_batched`` can actually dispatch for a
    flush policy: powers of two from the (shard-raised) floor up to the
    bucket covering max_batch_rows."""
    from repro.serve.batcher import bucket_for
    lo = bucket_for(1, min_bucket, n_shards)
    hi = bucket_for(max_batch_rows, min_bucket, n_shards)
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


def autotune(target, buckets: Optional[Sequence[int]] = None, *,
             dtype=jnp.float32, policy=None, n_shards: int = 1,
             reps: int = 5, warmup: int = 2,
             cache: Optional[TuneCache] = None,
             force: bool = False, verbose: bool = False) -> List[dict]:
    """Warm the tune cache for everything an engine will serve.

    ``target`` is a bundle path (widths derived from its spec.json) or
    an explicit widths sequence.  ``buckets`` defaults to the serve-path
    buckets for ``policy`` (a FlushPolicy, or the default policy).
    Returns the per-bucket records; after this, every
    ``InferenceEngine.apply_batched`` shape hits a tuned tile.
    """
    if isinstance(target, (list, tuple)):
        widths = [int(w) for w in target]
    else:
        import json
        import pathlib
        spec = json.loads(
            (pathlib.Path(str(target)) / "spec.json").read_text())
        widths = widths_from_spec(spec)
        if widths is None:
            raise ValueError(f"bundle {target!r} is not a pure MLP; "
                             "fused_mlp autotuning does not apply")
    if buckets is None:
        if policy is None:
            from repro.serve.queue import FlushPolicy
            policy = FlushPolicy()
        buckets = serve_buckets(policy.min_bucket, policy.max_batch_rows,
                                n_shards)
    buckets = set(int(b) for b in buckets)
    if n_shards > 1:
        # under shard_map the kernel sees the *per-shard* batch; warm
        # those shapes too so the sharded path hits tuned tiles
        buckets |= {b // n_shards for b in buckets
                    if b % n_shards == 0 and b // n_shards >= 1}
    recs = []
    for b in sorted(buckets):
        rec = sweep_fused_mlp(widths, b, dtype=dtype, reps=reps,
                              warmup=warmup, cache=cache, force=force)
        recs.append(rec)
        if verbose:
            print(f"[tune] widths={widths} bucket={b}: "
                  f"tile={rec['batch_tile']} "
                  f"{rec['us']}us vs default {rec['default_us']}us "
                  f"({rec['speedup_x']}x) exact={rec['exact']}",
                  flush=True)
    return recs
