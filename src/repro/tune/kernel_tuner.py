"""Measurement-driven autotuning for every registered Pallas kernel.

Each kernel declares its tunables via a
:class:`repro.kernels.registry.KernelSpec` (candidate ladders, VMEM cost
model, jitted ref oracle); :func:`sweep` measures every candidate that
fits the device's VMEM budget, validates each against the oracle
(bit-for-bit where the spec declares ``tol=None`` — fused_mlp,
stencil_gather — or to the spec's tolerance where the block structure
legitimately changes rounding, e.g. flash attention's online softmax),
and persists winners in the kernel-namespaced on-disk
:class:`repro.tune.cache.TuneCache` the registry dispatch consults at
trace time.

Entry points:

  * :func:`sweep` — one (kernel, problem) cell: measure, pick, store.
  * :func:`sweep_fused_mlp` — the historical fused_mlp-shaped wrapper.
  * :func:`autotune` — warm-up over the shapes an engine bundle serves
    (the buckets ``InferenceEngine.apply_batched`` can produce), or over
    explicit widths.  Call it once at deploy; the cache makes it free
    afterwards.
  * :func:`autotune_registered` — pre-populate every registered kernel's
    representative problems (what ``dryrun --tune`` runs at deploy).

Measurements run whatever path the op would take on this backend: the
compiled Pallas kernel on TPU, interpret mode elsewhere (slower in
absolute terms, but the grid/tile tradeoff ranks the same way: fewer,
fatter tiles amortize per-step overhead until VMEM or padding waste
pushes back).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.fused_mlp.ops import DEFAULT_TILE, candidate_tiles
from repro.tune.cache import TuneCache, _dtype_name, default_cache

__all__ = ["DEFAULT_TILE", "autotune", "autotune_registered",
           "candidate_tiles", "serve_buckets", "sweep", "sweep_fused_mlp",
           "widths_from_spec"]


def widths_from_spec(spec: dict) -> Optional[List[int]]:
    """Dense widths of a pure-MLP bundle spec, or None if not pure-MLP.

    Mirrors the adapter logic in ``fused_mlp_from_spec``: flatten folds
    trailing dims into the feature dim, acts don't change widths.
    """
    in_shape = spec.get("in_shape") or ()
    feat = 1
    for d in in_shape[1:]:
        feat *= int(d)
    widths = [feat]
    for layer in spec.get("layers", ()):
        kind = layer.get("kind")
        if kind == "dense":
            widths.append(int(layer["features"]))
        elif kind in ("act", "flatten"):
            continue
        else:
            return None  # conv/pool/... : not the fused kernel's shape
    return widths if len(widths) > 1 else None


def _acts_for(n_layers: int, acts=None) -> tuple:
    if acts is not None:
        return tuple(acts)
    return ("relu",) * (n_layers - 1) + ("identity",)


def _measure_us(fn, reps: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _outputs_match(spec, out, ref) -> bool:
    """Spec-declared comparison: bit-identity unless the spec carries a
    tolerance (an output may be a pytree, e.g. rwkv6's (o, state))."""
    a_leaves = jax.tree.leaves(out)
    b_leaves = jax.tree.leaves(ref)
    if len(a_leaves) != len(b_leaves):
        return False
    for a, b in zip(a_leaves, b_leaves):
        a, b = np.asarray(a), np.asarray(b)
        if spec.tol is None:
            if not np.array_equal(a, b):
                return False
        else:
            rtol, atol = spec.tol
            if not np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol):
                return False
    return True


def sweep(kernel, problem: dict, *, reps: int = 5, warmup: int = 2,
          cache: Optional[TuneCache] = None, seed: int = 0,
          force: bool = False) -> dict:
    """Measure every candidate config for one (kernel, problem) cell.

    Returns (and persists) the record the registry dispatch will
    consult.  Candidates whose output fails the spec's oracle check are
    disqualified — a tuned config must never change serving results.
    The spec's defaults are always ``candidates[0]``, so the winner's
    ``speedup_x`` is measured against the exact config dispatch would
    use untuned.
    """
    spec = registry.get_spec(kernel) if isinstance(kernel, str) else kernel
    problem = dict(problem)
    problem["dtype"] = _dtype_name(problem.get("dtype", "float32"))
    cache = cache or default_cache(spec.name)
    backend = jax.default_backend()
    key = spec.cache_key(problem, backend)
    if not force:
        cached = cache.get(key)
        if cached is not None:
            return cached

    rng = np.random.default_rng(seed)
    arrays = spec.make_call(problem, rng)
    # jitted oracle: the serving path always runs compiled, and XLA's
    # eager-vs-compiled ops round differently — compare like with like
    ref = jax.jit(lambda *a: spec.ref_call(problem, a))(*arrays)
    ref = jax.tree.map(np.asarray, ref)
    interpret = backend != "tpu"
    defaults = spec.defaults()

    swept = []
    for params in spec.candidates(problem):
        fn = jax.jit(lambda *a, _p=dict(params): spec.run_call(
            problem, a, _p, interpret=interpret))
        entry = {"params": dict(params)}
        try:
            out = fn(*arrays)
            entry["exact"] = _outputs_match(spec, out, ref)
            entry["us"] = round(
                _measure_us(lambda: fn(*arrays), reps, warmup), 2)
        except Exception as e:  # a config the backend rejects is skipped
            entry.update(us=None, exact=False,
                         error=f"{type(e).__name__}: {e}"[:200])
        swept.append(entry)

    valid = [s for s in swept if s["exact"]]
    default = next((s for s in swept
                    if s["params"] == defaults and s["us"]), None)
    if valid:
        best = min(valid, key=lambda s: s["us"])
        default_us = default["us"] if default else best["us"]
        rec = {"params": dict(best["params"]), "us": best["us"],
               "default_us": default_us,
               "speedup_x": round(default_us / best["us"], 3)
               if best["us"] else 1.0,
               "exact": True, "backend": backend, "swept": swept,
               "tuned_at": time.time()}
    else:  # nothing validated: record the failure so we don't re-sweep,
        # but the dispatch path will refuse to serve it (exact=False)
        rec = {"params": dict(defaults), "us": None,
               "default_us": default["us"] if default else None,
               "speedup_x": 1.0, "exact": False, "backend": backend,
               "swept": swept, "tuned_at": time.time()}
    rec.update(rec["params"])  # flattened winner params (legacy readers)
    cache.put(key, rec)
    return rec


def sweep_fused_mlp(widths: Sequence[int], bucket: int, *,
                    dtype=jnp.float32, acts=None, reps: int = 5,
                    warmup: int = 2, cache: Optional[TuneCache] = None,
                    seed: int = 0, force: bool = False) -> dict:
    """One fused_mlp (widths, bucket) cell through the generic sweep."""
    widths = tuple(int(w) for w in widths)
    problem = {"widths": widths, "acts": _acts_for(len(widths) - 1, acts),
               "batch": int(bucket), "dtype": _dtype_name(dtype)}
    return sweep("fused_mlp", problem, reps=reps, warmup=warmup,
                 cache=cache, seed=seed, force=force)


def serve_buckets(min_bucket: int = 8, max_batch_rows: int = 1024,
                  n_shards: int = 1) -> List[int]:
    """The batch buckets ``apply_batched`` can actually dispatch for a
    flush policy: powers of two from the (shard-raised) floor up to the
    bucket covering max_batch_rows."""
    from repro.serve.batcher import bucket_for
    lo = bucket_for(1, min_bucket, n_shards)
    hi = bucket_for(max_batch_rows, min_bucket, n_shards)
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


def autotune(target, buckets: Optional[Sequence[int]] = None, *,
             dtype=jnp.float32, policy=None, n_shards: int = 1,
             reps: int = 5, warmup: int = 2,
             cache: Optional[TuneCache] = None,
             force: bool = False, verbose: bool = False) -> List[dict]:
    """Warm the fused_mlp tune cache for everything an engine will serve.

    ``target`` is a bundle path (widths derived from its spec.json) or
    an explicit widths sequence.  ``buckets`` defaults to the serve-path
    buckets for ``policy`` (a FlushPolicy, or the default policy).
    Returns the per-bucket records; after this, every
    ``InferenceEngine.apply_batched`` shape hits a tuned tile.
    """
    if isinstance(target, (list, tuple)):
        widths = [int(w) for w in target]
    else:
        import json
        import pathlib
        spec = json.loads(
            (pathlib.Path(str(target)) / "spec.json").read_text())
        widths = widths_from_spec(spec)
        if widths is None:
            raise ValueError(f"bundle {target!r} is not a pure MLP; "
                             "fused_mlp autotuning does not apply")
    if buckets is None:
        if policy is None:
            from repro.serve.queue import FlushPolicy
            policy = FlushPolicy()
        buckets = serve_buckets(policy.min_bucket, policy.max_batch_rows,
                                n_shards)
    buckets = set(int(b) for b in buckets)
    if n_shards > 1:
        # under shard_map the kernel sees the *per-shard* batch; warm
        # those shapes too so the sharded path hits tuned tiles
        buckets |= {b // n_shards for b in buckets
                    if b % n_shards == 0 and b // n_shards >= 1}
    recs = []
    for b in sorted(buckets):
        rec = sweep_fused_mlp(widths, b, dtype=dtype, reps=reps,
                              warmup=warmup, cache=cache, force=force)
        recs.append(rec)
        if verbose:
            print(f"[tune] widths={widths} bucket={b}: "
                  f"tile={rec['params'].get('batch_tile')} "
                  f"{rec['us']}us vs default {rec['default_us']}us "
                  f"({rec['speedup_x']}x) exact={rec['exact']}",
                  flush=True)
    return recs


def autotune_registered(kernels: Optional[Sequence[str]] = None, *,
                        reps: int = 5, warmup: int = 2,
                        force: bool = False,
                        verbose: bool = False) -> List[dict]:
    """Pre-populate every registered kernel's representative problems.

    Kernels with no tunable params (rwkv6_chunk) are skipped — there is
    nothing to pick.  ``dryrun --tune`` calls this after the
    bundle-aware fused_mlp warm-up so a deploy tunes the whole kernel
    surface, not just the surrogate MLP.
    """
    recs = []
    names = list(kernels) if kernels else [
        s.name for s in registry.all_specs()]
    for name in names:
        spec = registry.get_spec(name)
        if not spec.params:
            continue
        for problem in spec.default_problems:
            rec = sweep(spec, problem, reps=reps, warmup=warmup, force=force)
            recs.append(rec)
            if verbose:
                print(f"[tune] {spec.name} {spec.cache_key(dict(problem), jax.default_backend())}: "
                      f"params={rec['params']} {rec['us']}us vs default "
                      f"{rec['default_us']}us ({rec['speedup_x']}x) "
                      f"exact={rec['exact']}", flush=True)
    return recs
