"""Measurement-driven autotuning for the inference hot path.

Two closed loops (see README.md):

  * **kernel tuning** — sweep ``fused_mlp`` batch tiles over the shapes
    the engine serves, validate against the ref oracle, persist winners
    (``kernel_tuner`` + ``cache``); the kernel op consults the cache
    instead of its hardcoded default.
  * **flush control** — pick the serve queue's deadline and batch
    target from the observed arrival rate and the roofline-predicted
    batch latency (``controller``), degrading to the static policy
    while stats are cold.
"""
from repro.tune.cache import TuneCache, best_tile, default_cache, shape_key
from repro.tune.controller import (AdaptiveFlushController, mlp_resources,
                                   predict_batch_latency_s)
from repro.tune.kernel_tuner import (autotune, candidate_tiles, serve_buckets,
                                     sweep_fused_mlp, widths_from_spec)

__all__ = ["AdaptiveFlushController", "TuneCache", "autotune", "best_tile",
           "candidate_tiles", "default_cache", "mlp_resources",
           "predict_batch_latency_s", "serve_buckets", "shape_key",
           "sweep_fused_mlp", "widths_from_spec"]
