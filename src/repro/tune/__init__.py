"""Measurement-driven autotuning for the inference hot path.

Two closed loops (see README.md):

  * **kernel tuning** — every Pallas kernel registers a
    :class:`repro.kernels.registry.KernelSpec`; ``sweep`` measures its
    candidate ladder over the shapes the engine serves, validates
    against the ref oracle, and persists winners per kernel
    (``kernel_tuner`` + ``cache``); the registry dispatch consults the
    cache instead of hardcoded defaults.
  * **flush control** — pick the serve queue's deadline and batch
    target from the observed arrival rate and the batch-latency model
    (``controller``): measured per-bucket ``ServeStats`` latencies once
    warm, the roofline prediction as the cold-start prior, degrading to
    the static policy while stats are cold.
"""
from repro.tune.cache import (TuneCache, best_params, best_tile,
                              default_cache, shape_key)
from repro.tune.controller import (AdaptiveFlushController, mlp_resources,
                                   predict_batch_latency_s)
from repro.tune.kernel_tuner import (autotune, autotune_registered,
                                     candidate_tiles, serve_buckets, sweep,
                                     sweep_fused_mlp, widths_from_spec)

__all__ = ["AdaptiveFlushController", "TuneCache", "autotune",
           "autotune_registered", "best_params", "best_tile",
           "candidate_tiles", "default_cache", "mlp_resources",
           "predict_batch_latency_s", "serve_buckets", "shape_key",
           "sweep", "sweep_fused_mlp", "widths_from_spec"]
