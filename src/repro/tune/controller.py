"""Adaptive flush control: close the loop between arrival rate and the
cost of serving a batch.

A static ``FlushPolicy.max_delay_s`` is wrong at both ends: at high
arrival rates it waits long after an efficient batch has accumulated; at
low rates it parks a lone caller for the full deadline even though the
mesh could serve it in microseconds.  The paper's Observation 2 frames
the underlying tradeoff — small-batch surrogate calls waste the
hardware — so the controller picks, per serving key:

  * a **bucket target** B*: the smallest power-of-two batch whose
    per-row latency is within ``amortize_eps`` of the large-batch
    asymptote (past B*, fatter batches barely help);
  * a **deadline**: the time the observed arrival rate needs to
    accumulate B* rows, capped at ``service_factor`` x the service time
    of B* (waiting much longer than a batch costs to serve buys
    nothing) and clamped to ``[min_delay_s, max_delay_s]``.

The batch-latency model is **closed-loop**: once ``ServeStats`` has
recorded ``measured_min_batches`` dispatches of a bucket, that bucket's
measured EWMA wall time supersedes the roofline prediction in the
latency model (measured wins once warm); buckets not yet observed use
the roofline prediction scaled by the correction factor of the nearest
*measured* bucket — one warm bucket recalibrates the whole curve, which
matters because the roofline's fixed ``overhead_s`` is a guess that can
be off by an order of magnitude across backends.  The measured model
feeds two decisions differently:

  * the **bucket target** uses it symmetrically — it is a shape
    question (where does batching stop paying?) and the measured curve
    answers it better in both directions;
  * the **deadline cap** uses it to *tighten only*: the prior cap
    (``service_factor`` x roofline) is the policy's bound on worthwhile
    waiting, and a measured service time below it proves even that wait
    was pointless (the x4 pad covered model uncertainty that no longer
    exists), so the cap shrinks to ``measured_service_factor`` x
    measured.  A measured time *above* the prior must never inflate the
    deadline — holding callers longer because serving got slower would
    compound a slowdown into queueing delay, the classic unstable
    feedback a latency-biased queue must avoid.

``use_measured=False`` reverts to the PR-3 open-loop controller (the
benchmark baseline the CI gate compares against).

Degradation stays graceful and layered: the roofline term needs only
the net's widths, so it applies from the very first request; the
arrival rate needs warm stats, so the fill term stays out of the
decision until ``warmup_requests`` submits have been observed; measured
latencies need completed batches, so the roofline remains the cold-start
prior.  A key whose widths cannot be derived from its bundle (not a
pure MLP, missing spec) falls all the way back to the static policy
values, so a queue with a controller can never behave worse than its
``FlushPolicy``.

The roofline prior reuses :class:`repro.dist.hlo_analysis.Roofline` with
the fused-MLP resource counts (weights stream once per batch, the
intermediate activations stay in VMEM) plus a fixed dispatch overhead —
the measured floor of a jit'd apply, which dominates for the small nets
the NAS space emits.
"""
from __future__ import annotations

import json
import math
import pathlib
import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.dist.hlo_analysis import HBM_BW, PEAK_FLOPS, Roofline
from repro.obs import metrics as _m

_DECISIONS = _m.counter(
    "repro_controller_decisions_total",
    "adaptive flush decisions by latency-model source",
    ("key", "source"))


def mlp_resources(widths, batch: int, dtype_bytes: int = 4,
                  weight_dtype_bytes: Optional[int] = None):
    """(flops, hbm_bytes) for one fused-MLP batch of ``batch`` rows.

    ``weight_dtype_bytes`` prices the weight stream at its own width
    when it differs from the activation dtype — the int8 tier quarters
    the weight bytes (1 vs 4) while activations stay f32.  The scale
    vectors the quantized layers add (one f32 per output channel) ride
    along in the bias term, which already counts one f32 per output
    channel; the model keeps them at f32 whatever the weights are.
    """
    if weight_dtype_bytes is None:
        weight_dtype_bytes = dtype_bytes
    wsum = sum(a * b for a, b in zip(widths[:-1], widths[1:]))
    flops = batch * (2.0 * wsum + sum(widths[1:]))  # dots + bias adds
    weight_bytes = (wsum * weight_dtype_bytes
                    + sum(widths[1:]) * dtype_bytes)  # + biases/scales
    io_bytes = batch * (widths[0] + widths[-1]) * dtype_bytes
    return flops, weight_bytes + io_bytes


def predict_batch_latency_s(widths, batch: int, *, chips: int = 1,
                            dtype_bytes: int = 4,
                            weight_dtype_bytes: Optional[int] = None,
                            overhead_s: float = 150e-6,
                            peak_flops: float = PEAK_FLOPS,
                            hbm_bw: float = HBM_BW) -> float:
    """Roofline-predicted wall time to serve one batch of ``batch`` rows."""
    flops, hbm = mlp_resources(widths, batch, dtype_bytes,
                               weight_dtype_bytes)
    roof = Roofline(flops_global=flops, hbm_bytes_global=hbm,
                    coll_bytes_global=0.0, chips=chips, model_flops=flops,
                    peak_flops=peak_flops, hbm_bw=hbm_bw)
    return roof.step_time_s + overhead_s


def _default_widths_for(key: str):
    """Derive fused-MLP widths from a bundle path (the serve-queue key)."""
    from repro.tune.kernel_tuner import widths_from_spec
    spec = json.loads((pathlib.Path(key) / "spec.json").read_text())
    return widths_from_spec(spec)


class AdaptiveFlushController:
    """Per-key closed-loop (deadline, bucket-target) policy.

    Plug into a queue with ``ServeQueue(policy, controller=ctrl)``; the
    queue consults :meth:`delay_for` wherever it used the static
    ``policy.max_delay_s`` and :meth:`batch_rows_for` for the max-batch
    trigger.  Both run under the queue lock, so they are kept cheap:
    widths resolve once per key ever (spec.json is read on first touch
    and the result — including failure — is cached), and full delay /
    bucket-target decisions are memoized for ``decision_ttl_s`` so a
    dispatcher that wakes every few hundred microseconds re-prices a
    key at most once per TTL window (the TTL is also what lets fresh
    measured latencies flow back into the decision).
    """

    def __init__(self, policy=None, *,
                 widths_for: Optional[Callable] = None,
                 chips: int = 1,
                 min_delay_s: float = 2e-4,
                 max_delay_s: float = 0.05,
                 warmup_requests: int = 8,
                 service_factor: float = 4.0,
                 measured_service_factor: float = 1.5,
                 amortize_eps: float = 0.1,
                 overhead_s: float = 150e-6,
                 decision_ttl_s: float = 0.01,
                 use_measured: bool = True,
                 measured_min_batches: int = 2,
                 correction_clamp: float = 20.0,
                 peak_flops: float = PEAK_FLOPS,
                 hbm_bw: float = HBM_BW,
                 tenancy=None):
        if policy is None:
            from repro.serve.queue import FlushPolicy
            policy = FlushPolicy()
        self.policy = policy
        self.chips = chips
        self.min_delay_s = min_delay_s
        self.max_delay_s = max_delay_s
        self.warmup_requests = warmup_requests
        self.service_factor = service_factor
        self.measured_service_factor = measured_service_factor
        self.amortize_eps = amortize_eps
        self.overhead_s = overhead_s
        self.decision_ttl_s = decision_ttl_s
        self.use_measured = use_measured
        self.measured_min_batches = measured_min_batches
        self.correction_clamp = correction_clamp
        self.peak_flops = peak_flops
        self.hbm_bw = hbm_bw
        # tenancy board (repro.serve.tenancy.TenantBoard): a key bound
        # to a QoS tier gets that tier's deadline target as a per-key
        # bound — latency tenants cap the wait, throughput tenants may
        # wait past the static policy to build fat batches.  ServeQueue
        # wires this automatically when both are attached.
        self.tenancy = tenancy
        self._widths_for = widths_for or _default_widths_for
        self._lock = threading.Lock()
        self._widths: Dict[str, Optional[list]] = {}
        self._memo: Dict[str, Tuple[float, Optional[float]]] = {}
        self._target_memo: Dict[str, Tuple[float, int]] = {}
        self.last_decision: Dict[str, dict] = {}  # observability, per key

    # ------------------------------------------------------------ model ---
    def _widths_cached(self, key: str):
        with self._lock:
            if key in self._widths:
                return self._widths[key]
        try:
            w = self._widths_for(key)
        except Exception as exc:
            w = None  # unknown bundle shape -> degrade to static policy
            _m.note_static_fallback(key, "unknown-widths", repr(exc))
        with self._lock:
            self._widths[key] = w
        return w

    def predict_latency_s(self, widths, batch: int) -> float:
        """Open-loop roofline prior (no observations consulted)."""
        return predict_batch_latency_s(
            widths, batch, chips=self.chips, overhead_s=self.overhead_s,
            peak_flops=self.peak_flops, hbm_bw=self.hbm_bw)

    def latency_s(self, widths, batch: int, stats,
                  pred: Optional[float] = None) -> Tuple[float, str]:
        """Closed-loop batch latency: (seconds, source).

        Source is ``"measured"`` when the exact bucket is warm in
        ``stats``, ``"corrected"`` when another bucket's measured /
        predicted ratio recalibrates the roofline, ``"roofline"`` when
        stats are cold (or ``use_measured`` is off).  Any stats access
        failure degrades to the roofline prior — the controller must
        never raise into the queue.  Callers that already evaluated the
        roofline for ``batch`` pass it as ``pred`` (these run under the
        queue lock, so redundant model evaluations are real cost).
        """
        if pred is None:
            pred = self.predict_latency_s(widths, batch)
        if not self.use_measured or stats is None:
            return pred, "roofline"
        try:
            meas = stats.batch_latency_s(batch, self.measured_min_batches)
            if meas is not None and meas > 0.0:
                return meas, "measured"
            warm = [(b, e) for b, (e, n) in stats.batch_latencies().items()
                    if n >= self.measured_min_batches and e > 0.0 and b > 0]
        except Exception:
            return pred, "roofline"
        if not warm:
            return pred, "roofline"
        # nearest warm bucket (log-scale) recalibrates the prediction:
        # the roofline's shape is right, its constants may not be
        b0, e0 = min(warm, key=lambda be: abs(math.log(be[0] / max(batch, 1))))
        corr = e0 / max(self.predict_latency_s(widths, b0), 1e-12)
        corr = min(max(corr, 1.0 / self.correction_clamp),
                   self.correction_clamp)
        return pred * corr, "corrected"

    def _bucket_target(self, key: str, widths, stats) -> int:
        """Smallest power-of-two bucket within amortize_eps of the
        asymptotic per-row latency — past it, bigger batches mostly add
        queueing delay, not throughput.  Re-derived per TTL window so
        measured latencies reshape the curve as they warm."""
        now = time.monotonic()
        with self._lock:
            memo = self._target_memo.get(key)
            if memo is not None and now - memo[0] < self.decision_ttl_s:
                return memo[1]
        from repro.serve.batcher import bucket_size
        lo = bucket_size(1, self.policy.min_bucket)
        hi = bucket_size(self.policy.max_batch_rows, self.policy.min_bucket)
        asymptote = self.latency_s(widths, hi, stats)[0] / hi
        target = hi
        b = lo
        while b <= hi:
            if self.latency_s(widths, b, stats)[0] / b <= \
                    (1.0 + self.amortize_eps) * asymptote:
                target = b
                break
            b *= 2
        with self._lock:
            self._target_memo[key] = (now, target)
        return target

    # ---------------------------------------------------- queue contract ---
    def delay_for(self, key: str, stats) -> Optional[float]:
        """Deadline for ``key``'s oldest pending request.

        Two terms, different information sources:

          * the **service cap** (``service_factor`` x batch latency)
            comes from the closed-loop latency model — roofline-only
            from the first request, measured once batches have
            completed;
          * the **fill time** (bucket target / arrival rate) needs warm
            stats; until ``warmup_requests`` submits it is infinite and
            the cap governs.

        Only a key whose widths cannot be derived (non-MLP bundle,
        missing spec) degrades all the way to the static policy value.
        """
        now = time.monotonic()
        memo = self._memo.get(key)
        if memo is not None and now - memo[0] < self.decision_ttl_s:
            return memo[1]
        static = self.policy.max_delay_s
        widths = self._widths_cached(key)
        if not widths:
            self._memo[key] = (now, static)
            return static
        target = self._bucket_target(key, widths, stats)
        # the service cap prices the batch *already pending* (waiting
        # longer than it costs to serve what is queued buys nothing —
        # more rows may never come), not the aspirational target bucket
        from repro.serve.batcher import bucket_size
        pending = max(int(getattr(stats, "queue_depth_rows", 0) or 0), 1)
        cap_bucket = bucket_size(pending, self.policy.min_bucket)
        if self.use_measured and stats is not None:
            # the batcher's dispatch buckets are shard-rounded
            # (bucket_for), not always powers of two — prefer the
            # smallest bucket actually *observed* covering the pending
            # rows, or the exact-measured lookup below never hits on a
            # non-pow2 shard count
            try:
                observed = [b for b, (_, n) in stats.batch_latencies()
                            .items()
                            if n >= self.measured_min_batches
                            and b >= pending]
                if observed:
                    cap_bucket = min(cap_bucket, min(observed))
            except Exception:
                pass
        pred = self.predict_latency_s(widths, cap_bucket)
        t_serve, source = self.latency_s(widths, cap_bucket, stats, pred)
        rate = 0.0
        if stats is not None and \
                stats.requests_enqueued >= self.warmup_requests:
            rate = stats.arrival_rate_rows_s()
        fill_s = target / rate if rate > 0.0 else float("inf")
        # Measured latency TIGHTENS the cap, never loosens it.  The
        # prior cap (service_factor x roofline) is the policy's bound on
        # worthwhile waiting; a measured service time *below* it proves
        # even that wait was pointless, so the bound shrinks (with the
        # tight measured factor — the x4 pad covered model uncertainty
        # that no longer exists).  A measured time *above* it must not
        # inflate the deadline: holding callers longer because serving
        # got slower turns a slowdown into compounding queueing delay —
        # exactly the feedback loop a latency-biased queue must avoid.
        cap = self.service_factor * pred
        if source != "roofline":
            cap = min(cap, self.measured_service_factor * t_serve)
        delay = min(fill_s, cap)
        hi = static if static is not None else self.max_delay_s
        # QoS tier bound: a latency-tier tenant's target *caps* how long
        # its key may wait (an SLO, not a hint); a throughput-tier
        # target *raises* the ceiling so fat batches can fill even when
        # the static policy is tighter.  Board failures degrade to the
        # tier-free decision — the controller must never raise into the
        # queue.
        tier = target_s = None
        if self.tenancy is not None:
            try:
                tier, target_s = self.tenancy.qos_for_key(key)
            except Exception:
                tier = target_s = None
        if target_s is not None:
            hi = min(hi, target_s) if tier == "latency" \
                else max(hi, target_s)
        delay = max(self.min_delay_s, min(delay, hi))
        self.last_decision[key] = {
            "arrival_rate_rows_s": rate, "bucket_target": target,
            "cap_bucket": cap_bucket,
            "batch_latency_s": t_serve, "latency_source": source,
            "predicted_batch_latency_s": pred,
            "fill_s": fill_s, "delay_s": delay,
            "qos_tier": tier, "qos_target_s": target_s}
        _DECISIONS.inc(1, key=key, source=source)
        self._memo[key] = (now, delay)
        return delay

    def batch_rows_for(self, key: str, stats) -> int:
        """Adaptive max-batch trigger: flush once the efficient bucket
        has accumulated instead of waiting for the static cap.  Model-
        driven from the first request; measured latencies sharpen the
        target as batches complete."""
        cap = self.policy.max_batch_rows
        widths = self._widths_cached(key)
        if not widths:
            return cap
        return min(cap, self._bucket_target(key, widths, stats))
