"""Adaptive flush control: close the loop between arrival rate and the
roofline-predicted cost of serving a batch.

A static ``FlushPolicy.max_delay_s`` is wrong at both ends: at high
arrival rates it waits long after an efficient batch has accumulated; at
low rates it parks a lone caller for the full deadline even though the
mesh could serve it in microseconds.  The paper's Observation 2 frames
the underlying tradeoff — small-batch surrogate calls waste the
hardware — so the controller picks, per serving key:

  * a **bucket target** B*: the smallest power-of-two batch whose
    roofline-predicted per-row latency is within ``amortize_eps`` of the
    large-batch asymptote (past B*, fatter batches barely help);
  * a **deadline**: the time the observed arrival rate needs to
    accumulate B* rows, capped at ``service_factor`` x the predicted
    service time of B* (waiting much longer than a batch costs to serve
    buys nothing) and clamped to ``[min_delay_s, max_delay_s]``.

Degradation is graceful and layered: the roofline term needs only the
net's widths, so it applies from the very first request; the arrival
rate needs warm stats, so the fill term stays out of the decision until
``warmup_requests`` submits have been observed.  A key whose widths
cannot be derived from its bundle (not a pure MLP, missing spec) falls
all the way back to the static policy values, so a queue with a
controller can never behave worse than its ``FlushPolicy``.

The latency model reuses :class:`repro.dist.hlo_analysis.Roofline` with
the fused-MLP resource counts (weights stream once per batch, the
intermediate activations stay in VMEM) plus a fixed dispatch overhead —
the measured floor of a jit'd apply, which dominates for the small nets
the NAS space emits.
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
from typing import Callable, Dict, Optional, Tuple

from repro.dist.hlo_analysis import HBM_BW, PEAK_FLOPS, Roofline


def mlp_resources(widths, batch: int, dtype_bytes: int = 4):
    """(flops, hbm_bytes) for one fused-MLP batch of ``batch`` rows."""
    wsum = sum(a * b for a, b in zip(widths[:-1], widths[1:]))
    flops = batch * (2.0 * wsum + sum(widths[1:]))  # dots + bias adds
    weight_bytes = (wsum + sum(widths[1:])) * dtype_bytes
    io_bytes = batch * (widths[0] + widths[-1]) * dtype_bytes
    return flops, weight_bytes + io_bytes


def predict_batch_latency_s(widths, batch: int, *, chips: int = 1,
                            dtype_bytes: int = 4,
                            overhead_s: float = 150e-6,
                            peak_flops: float = PEAK_FLOPS,
                            hbm_bw: float = HBM_BW) -> float:
    """Roofline-predicted wall time to serve one batch of ``batch`` rows."""
    flops, hbm = mlp_resources(widths, batch, dtype_bytes)
    roof = Roofline(flops_global=flops, hbm_bytes_global=hbm,
                    coll_bytes_global=0.0, chips=chips, model_flops=flops,
                    peak_flops=peak_flops, hbm_bw=hbm_bw)
    return roof.step_time_s + overhead_s


def _default_widths_for(key: str):
    """Derive fused-MLP widths from a bundle path (the serve-queue key)."""
    from repro.tune.kernel_tuner import widths_from_spec
    spec = json.loads((pathlib.Path(key) / "spec.json").read_text())
    return widths_from_spec(spec)


class AdaptiveFlushController:
    """Per-key closed-loop (deadline, bucket-target) policy.

    Plug into a queue with ``ServeQueue(policy, controller=ctrl)``; the
    queue consults :meth:`delay_for` wherever it used the static
    ``policy.max_delay_s`` and :meth:`batch_rows_for` for the max-batch
    trigger.  Both run under the queue lock, so they are kept cheap:
    widths resolve once per key ever (spec.json is read on first touch
    and the result — including failure — is cached), bucket targets are
    cached per key, and full delay decisions are memoized for
    ``decision_ttl_s`` so a dispatcher that wakes every few hundred
    microseconds re-prices a key at most once per TTL window.
    """

    def __init__(self, policy=None, *,
                 widths_for: Optional[Callable] = None,
                 chips: int = 1,
                 min_delay_s: float = 2e-4,
                 max_delay_s: float = 0.05,
                 warmup_requests: int = 8,
                 service_factor: float = 4.0,
                 amortize_eps: float = 0.1,
                 overhead_s: float = 150e-6,
                 decision_ttl_s: float = 0.01,
                 peak_flops: float = PEAK_FLOPS,
                 hbm_bw: float = HBM_BW):
        if policy is None:
            from repro.serve.queue import FlushPolicy
            policy = FlushPolicy()
        self.policy = policy
        self.chips = chips
        self.min_delay_s = min_delay_s
        self.max_delay_s = max_delay_s
        self.warmup_requests = warmup_requests
        self.service_factor = service_factor
        self.amortize_eps = amortize_eps
        self.overhead_s = overhead_s
        self.decision_ttl_s = decision_ttl_s
        self.peak_flops = peak_flops
        self.hbm_bw = hbm_bw
        self._widths_for = widths_for or _default_widths_for
        self._lock = threading.Lock()
        self._widths: Dict[str, Optional[list]] = {}
        self._targets: Dict[str, int] = {}
        self._memo: Dict[str, Tuple[float, Optional[float]]] = {}
        self.last_decision: Dict[str, dict] = {}  # observability, per key

    # ------------------------------------------------------------ model ---
    def _widths_cached(self, key: str):
        with self._lock:
            if key in self._widths:
                return self._widths[key]
        try:
            w = self._widths_for(key)
        except Exception:
            w = None  # unknown bundle shape -> degrade to static policy
        with self._lock:
            self._widths[key] = w
        return w

    def predict_latency_s(self, widths, batch: int) -> float:
        return predict_batch_latency_s(
            widths, batch, chips=self.chips, overhead_s=self.overhead_s,
            peak_flops=self.peak_flops, hbm_bw=self.hbm_bw)

    def _bucket_target(self, key: str, widths) -> int:
        """Smallest power-of-two bucket within amortize_eps of the
        asymptotic per-row latency — past it, bigger batches mostly add
        queueing delay, not throughput."""
        with self._lock:
            if key in self._targets:
                return self._targets[key]
        from repro.serve.batcher import bucket_size
        lo = bucket_size(1, self.policy.min_bucket)
        hi = bucket_size(self.policy.max_batch_rows, self.policy.min_bucket)
        asymptote = self.predict_latency_s(widths, hi) / hi
        target = hi
        b = lo
        while b <= hi:
            if self.predict_latency_s(widths, b) / b <= \
                    (1.0 + self.amortize_eps) * asymptote:
                target = b
                break
            b *= 2
        with self._lock:
            self._targets[key] = target
        return target

    # ---------------------------------------------------- queue contract ---
    def delay_for(self, key: str, stats) -> Optional[float]:
        """Deadline for ``key``'s oldest pending request.

        Two terms, different information sources:

          * the **service cap** (``service_factor`` x predicted batch
            latency) comes from the roofline model alone — available
            from the first request, no observation needed;
          * the **fill time** (bucket target / arrival rate) needs warm
            stats; until ``warmup_requests`` submits it is infinite and
            the cap governs.

        Only a key whose widths cannot be derived (non-MLP bundle,
        missing spec) degrades all the way to the static policy value.
        """
        now = time.monotonic()
        memo = self._memo.get(key)
        if memo is not None and now - memo[0] < self.decision_ttl_s:
            return memo[1]
        static = self.policy.max_delay_s
        widths = self._widths_cached(key)
        if not widths:
            self._memo[key] = (now, static)
            return static
        target = self._bucket_target(key, widths)
        t_serve = self.predict_latency_s(widths, target)
        rate = 0.0
        if stats is not None and \
                stats.requests_enqueued >= self.warmup_requests:
            rate = stats.arrival_rate_rows_s()
        fill_s = target / rate if rate > 0.0 else float("inf")
        delay = min(fill_s, self.service_factor * t_serve)
        hi = static if static is not None else self.max_delay_s
        delay = max(self.min_delay_s, min(delay, hi))
        self.last_decision[key] = {
            "arrival_rate_rows_s": rate, "bucket_target": target,
            "predicted_batch_latency_s": t_serve, "fill_s": fill_s,
            "delay_s": delay}
        self._memo[key] = (now, delay)
        return delay

    def batch_rows_for(self, key: str, stats) -> int:
        """Adaptive max-batch trigger: flush once the efficient bucket
        has accumulated instead of waiting for the static cap.  Pure
        model (no observed stats needed), so it applies from the first
        request."""
        del stats
        cap = self.policy.max_batch_rows
        widths = self._widths_cached(key)
        if not widths:
            return cap
        return min(cap, self._bucket_target(key, widths))
