"""Model bundles: spec.json + params.npz — the TorchScript-file analogue.

The HPAC-ML runtime loads a bundle by path (the paper's ``model("...")``
clause); ``save_model``/``load_model`` round-trip exactly.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np


def _flatten(params):
    flat, treedef = jax.tree.flatten(params)
    return flat, treedef


def save_model(path, net, params, extra: dict | None = None):
    """net: Sequential; params: its param pytree."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    spec = net.spec()
    if extra:
        spec["extra"] = extra
    (path / "spec.json").write_text(json.dumps(spec, indent=1))
    flat, _ = _flatten(params)
    np.savez(path / "params.npz",
             **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})
    return str(path)


def load_model(path):
    """Returns (net, params, spec)."""
    from repro.nn.layers import from_spec
    path = pathlib.Path(path)
    spec = json.loads((path / "spec.json").read_text())
    net = from_spec(spec)
    z = np.load(path / "params.npz")
    flat = [jax.numpy.asarray(z[f"p{i}"]) for i in range(len(z.files))]
    # only the treedef is needed to unflatten the saved leaves: trace the
    # init abstractly instead of running it.  A real init executes device
    # RNG, which queues behind any in-flight collective — a degraded pod
    # host must be able to load a bundle while a torn collective is still
    # pending on its devices (see ServeQueue._dispatch_pod_guarded)
    ref = jax.eval_shape(net.init, jax.random.PRNGKey(0))
    _, treedef = jax.tree.flatten(ref)
    params = jax.tree.unflatten(treedef, flat)
    return net, params, spec
