from repro.nn.layers import (CNN, MLP, Activation, Conv2D, Dense, Flatten,
                             LayerNorm, MaxPool2D, Sequential, from_spec)
from repro.nn.serialize import load_model, save_model
