"""Minimal NN module system for surrogate models (flax is not available
offline).  Every layer is a (init, apply, spec) triple; ``Sequential``
composes them; ``from_spec`` rebuilds a network from its JSON spec — the
analogue of loading a TorchScript module in the paper's runtime.

The NAS search space of the paper (Table IV) is expressible with exactly
these layers: Dense stacks with feature multipliers (MiniBUDE, Binomial
Options, Bonds) and small CNNs (MiniWeather, ParticleFilter).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

_ACTS = {
    "relu": jax.nn.relu, "gelu": jax.nn.gelu, "tanh": jnp.tanh,
    "silu": jax.nn.silu, "sigmoid": jax.nn.sigmoid, "identity": lambda x: x,
}


class Layer:
    def init(self, rng, in_shape):
        raise NotImplementedError

    def apply(self, params, x, train=False):
        raise NotImplementedError

    def out_shape(self, in_shape):
        raise NotImplementedError

    def spec(self):
        raise NotImplementedError


class Dense(Layer):
    def __init__(self, features: int, use_bias: bool = True):
        self.features = features
        self.use_bias = use_bias

    def init(self, rng, in_shape):
        fan_in = in_shape[-1]
        w = jax.random.normal(rng, (fan_in, self.features)) * math.sqrt(2.0 / fan_in)
        p = {"w": w.astype(jnp.float32)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.features,), jnp.float32)
        return p

    def apply(self, params, x, train=False):
        y = x @ params["w"]
        return y + params["b"] if self.use_bias else y

    def out_shape(self, in_shape):
        return in_shape[:-1] + (self.features,)

    def spec(self):
        return {"kind": "dense", "features": self.features,
                "use_bias": self.use_bias}


class Conv2D(Layer):
    """NHWC conv; SAME or VALID padding, optional stride."""

    def __init__(self, features, kernel, stride=1, padding="SAME",
                 use_bias=True):
        self.features, self.kernel = features, kernel
        self.stride, self.padding, self.use_bias = stride, padding, use_bias

    def init(self, rng, in_shape):
        cin = in_shape[-1]
        k = self.kernel
        fan_in = cin * k * k
        w = jax.random.normal(rng, (k, k, cin, self.features)) * math.sqrt(2.0 / fan_in)
        p = {"w": w.astype(jnp.float32)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.features,), jnp.float32)
        return p

    def apply(self, params, x, train=False):
        y = jax.lax.conv_general_dilated(
            x, params["w"], (self.stride, self.stride), self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + params["b"] if self.use_bias else y

    def out_shape(self, in_shape):
        n, h, w, _ = in_shape
        if self.padding == "SAME":
            oh, ow = -(-h // self.stride), -(-w // self.stride)
        else:
            oh = (h - self.kernel) // self.stride + 1
            ow = (w - self.kernel) // self.stride + 1
        return (n, oh, ow, self.features)

    def spec(self):
        return {"kind": "conv2d", "features": self.features,
                "kernel": self.kernel, "stride": self.stride,
                "padding": self.padding, "use_bias": self.use_bias}


class MaxPool2D(Layer):
    def __init__(self, window, stride=None):
        self.window = window
        self.stride = stride or window

    def init(self, rng, in_shape):
        return {}

    def apply(self, params, x, train=False):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, self.window, self.window, 1),
            (1, self.stride, self.stride, 1), "VALID")

    def out_shape(self, in_shape):
        n, h, w, c = in_shape
        oh = (h - self.window) // self.stride + 1
        ow = (w - self.window) // self.stride + 1
        return (n, oh, ow, c)

    def spec(self):
        return {"kind": "maxpool2d", "window": self.window,
                "stride": self.stride}


class Activation(Layer):
    def __init__(self, name: str):
        assert name in _ACTS, name
        self.name = name

    def init(self, rng, in_shape):
        return {}

    def apply(self, params, x, train=False):
        return _ACTS[self.name](x)

    def out_shape(self, in_shape):
        return in_shape

    def spec(self):
        return {"kind": "act", "name": self.name}


class Dropout(Layer):
    """Train-time dropout (inference is identity; rng via params['rng'])."""

    def __init__(self, rate: float):
        self.rate = rate

    def init(self, rng, in_shape):
        return {}

    def apply(self, params, x, train=False, rng=None):
        if not train or self.rate <= 0 or rng is None:
            return x
        keep = jax.random.bernoulli(rng, 1 - self.rate, x.shape)
        return jnp.where(keep, x / (1 - self.rate), 0)

    def out_shape(self, in_shape):
        return in_shape

    def spec(self):
        return {"kind": "dropout", "rate": self.rate}


class Flatten(Layer):
    def init(self, rng, in_shape):
        return {}

    def apply(self, params, x, train=False):
        return x.reshape(x.shape[0], -1)

    def out_shape(self, in_shape):
        n = 1
        for s in in_shape[1:]:
            n *= s
        return (in_shape[0], n)

    def spec(self):
        return {"kind": "flatten"}


class LayerNorm(Layer):
    def init(self, rng, in_shape):
        return {"scale": jnp.ones((in_shape[-1],), jnp.float32),
                "bias": jnp.zeros((in_shape[-1],), jnp.float32)}

    def apply(self, params, x, train=False):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-6) * params["scale"] + params["bias"]

    def out_shape(self, in_shape):
        return in_shape

    def spec(self):
        return {"kind": "layernorm"}


class Sequential:
    def __init__(self, layers: Sequence[Layer], in_shape: Sequence[int]):
        self.layers = list(layers)
        self.in_shape = tuple(in_shape)

    def init(self, rng):
        params, shape = [], self.in_shape
        for i, l in enumerate(self.layers):
            params.append(l.init(jax.random.fold_in(rng, i), shape))
            shape = l.out_shape(shape)
        return params

    def apply(self, params, x, train=False, rng=None):
        for i, (l, p) in enumerate(zip(self.layers, params)):
            if isinstance(l, Dropout):
                r = jax.random.fold_in(rng, i) if rng is not None else None
                x = l.apply(p, x, train=train, rng=r)
            else:
                x = l.apply(p, x, train=train)
        return x

    def out_shape(self):
        shape = self.in_shape
        for l in self.layers:
            shape = l.out_shape(shape)
        return shape

    def spec(self):
        return {"in_shape": list(self.in_shape),
                "layers": [l.spec() for l in self.layers]}

    def n_params(self, params):
        return sum(x.size for x in jax.tree.leaves(params))


_KINDS = {}


def _register(kind):
    def deco(fn):
        _KINDS[kind] = fn
        return fn
    return deco


_register("dense")(lambda s: Dense(s["features"], s.get("use_bias", True)))
_register("conv2d")(lambda s: Conv2D(s["features"], s["kernel"], s["stride"],
                                     s["padding"], s.get("use_bias", True)))
_register("maxpool2d")(lambda s: MaxPool2D(s["window"], s["stride"]))
_register("act")(lambda s: Activation(s["name"]))
_register("dropout")(lambda s: Dropout(s["rate"]))
_register("flatten")(lambda s: Flatten())
_register("layernorm")(lambda s: LayerNorm())


def from_spec(spec: dict) -> Sequential:
    layers = [_KINDS[l["kind"]](l) for l in spec["layers"]]
    return Sequential(layers, tuple(spec["in_shape"]))


def MLP(in_shape, hidden: Sequence[int], out_features: int, act="relu",
        dropout: float = 0.0) -> Sequential:
    layers = []
    for h in hidden:
        layers += [Dense(h), Activation(act)]
        if dropout:
            layers.append(Dropout(dropout))
    layers.append(Dense(out_features))
    return Sequential(layers, in_shape)


def CNN(in_shape, convs, dense: Sequence[int], out_features: int,
        act="relu", pool: Optional[int] = None) -> Sequential:
    """convs: list of (features, kernel, stride)."""
    layers = []
    for f, k, s in convs:
        layers += [Conv2D(f, k, s), Activation(act)]
    if pool:
        layers.append(MaxPool2D(pool))
    layers.append(Flatten())
    for h in dense:
        layers += [Dense(h), Activation(act)]
    layers.append(Dense(out_features))
    return Sequential(layers, in_shape)
