"""The 10 assigned architectures, exactly as specified by the assignment.

Sources ([tier]): whisper-medium [arXiv:2212.04356], rwkv6-1.6b
[arXiv:2404.05892], qwen1.5-{32b,110b} [hf:Qwen/Qwen1.5-*], llama3.2-3b
[hf:meta-llama], qwen3-4b [hf:Qwen/Qwen3-*], jamba-v0.1-52b
[arXiv:2403.19887], qwen2-vl-7b [arXiv:2409.12191], deepseek-v2-lite-16b
[arXiv:2405.04434], grok-1-314b [hf:xai-org/grok-1].
"""
from repro.configs.base import LayerSpec, ModelConfig, register

A = LayerSpec  # shorthand

register(ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    pattern=(A(mixer="gqa", mlp="gelu", cross_attn=True),),
    enc_dec=True, enc_layers=24, enc_ctx=1500,
    enc_pattern=(A(mixer="gqa", mlp="gelu"),),
    qkv_bias=True, rope="none", norm="layernorm", act="gelu",
))

register(ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    pattern=(A(mixer="rwkv6", mlp="rwkv_cm"),),
    rope="none", norm="layernorm",
    rwkv_head_size=64, subquadratic=True,
))

register(ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    pattern=(A(),), qkv_bias=True, rope_theta=1e6,
))

register(ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    pattern=(A(),), rope_theta=5e5, tie_embeddings=True,
))

register(ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    pattern=(A(),), qk_norm=True, rope_theta=1e6, tie_embeddings=True,
))

register(ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064,
    pattern=(A(),), qkv_bias=True, rope_theta=1e6,
))

# Jamba: attn:mamba 1:7 interleave (attn at slot 4 of an 8-layer period),
# MoE every other layer (even slots), 16 experts top-2.
register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    pattern=(
        A(mixer="mamba", mlp="moe"), A(mixer="mamba", mlp="swiglu"),
        A(mixer="mamba", mlp="moe"), A(mixer="mamba", mlp="swiglu"),
        A(mixer="gqa", mlp="moe"), A(mixer="mamba", mlp="swiglu"),
        A(mixer="mamba", mlp="moe"), A(mixer="mamba", mlp="swiglu"),
    ),
    n_experts=16, top_k=2, moe_d_ff=14336,
    rope="none",  # jamba uses no positional encoding
    subquadratic=True,
))

register(ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    pattern=(A(),), qkv_bias=True, rope="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), needs_position_ids=True,
))

# DeepSeek-V2-Lite: MLA (kv_lora 512), first layer dense (d_ff 10944),
# remaining 26 layers MoE: 64 routed top-6 + 2 shared experts, expert ff 1408.
register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944, vocab_size=102400,
    prefix=(A(mixer="mla", mlp="swiglu"),),
    pattern=(A(mixer="mla", mlp="moe"),),
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
))

register(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    pattern=(A(mixer="gqa", mlp="moe"),),
    n_experts=8, top_k=2, moe_d_ff=32768, act="gelu",
    opt_policy="lean",
))

ARCH_NAMES = [
    "whisper-medium", "rwkv6-1.6b", "qwen1.5-32b", "llama3.2-3b",
    "qwen3-4b", "qwen1.5-110b", "jamba-v0.1-52b", "qwen2-vl-7b",
    "deepseek-v2-lite-16b", "grok-1-314b",
]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        n_layers=len(cfg.prefix) + 2 * len(cfg.pattern),
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab_size=256,
        rwkv_head_size=16, kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        mamba_dt_rank=8, moe_d_ff=32 if cfg.n_experts else 0,
        n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
        enc_layers=2 if cfg.enc_dec else 0, enc_ctx=16,
        attn_chunk=32, opt_policy="full", max_pos=128,
        name=cfg.name + "-smoke",
    )
    if cfg.rope == "mrope":
        kw["mrope_sections"] = (2, 3, 3)  # scaled to head_dim 16 (half=8)
    if cfg.n_experts:
        # no capacity drops in smoke tests -> train/decode paths match exactly
        kw["capacity_factor"] = float(min(cfg.n_experts, 4))
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    return cfg.replace(**kw)
