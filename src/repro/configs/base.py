"""Model/config system.

Every assigned architecture is expressed as a :class:`ModelConfig` built from
a *layer pattern*: a period ``P`` of :class:`LayerSpec` slots repeated ``R``
times (``n_layers = len(prefix) + P*R``).  Homogeneous archs have ``P=1``;
hybrids (jamba) encode their interleave in the pattern; deepseek's first
dense layer lives in ``prefix``.  The pattern-scan keeps HLO size constant in
depth, which matters for 1-core dry-run compiles and mirrors how production
frameworks (MaxText et al.) scan over layers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerSpec:
    """One slot in the layer pattern."""

    mixer: str = "gqa"  # gqa | mla | rwkv6 | mamba
    mlp: str = "swiglu"  # swiglu | gelu | moe | rwkv_cm
    cross_attn: bool = False  # enc-dec decoder layers


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    # --- layer pattern ---
    pattern: Sequence[LayerSpec] = (LayerSpec(),)
    prefix: Sequence[LayerSpec] = ()
    # --- attention options ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 1e4
    mrope_sections: Sequence[int] = (16, 24, 24)
    # --- norm / act ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu (swiglu) | gelu
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- mamba ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0  # 0 -> d_model // 16
    # --- rwkv ---
    rwkv_head_size: int = 64
    rwkv_lora_dim: int = 32
    # --- enc-dec (whisper) ---
    enc_dec: bool = False
    enc_layers: int = 0
    enc_ctx: int = 1500
    enc_pattern: Sequence[LayerSpec] = ()
    # --- vlm ---
    needs_position_ids: bool = False
    # --- numerics / memory policy ---
    dtype: str = "bfloat16"
    # optimizer state policy: "full"   = fp32 master + fp32 (m, v)
    #                         "lean"   = no master, bf16 (m, v)  (giant models)
    opt_policy: str = "full"
    remat: bool = True
    attn_chunk: int = 1024  # flash/chunked attention KV block
    scan_layers: bool = True
    max_pos: int = 32768  # learned-pos table length (rope='none' archs)
    kv_cache_dtype: str = "bfloat16"  # 'int8' -> quantized KV cache (decode)
    # paper technique in the LM: serve-time FFN surrogate (approx-ml region
    # inlined as a first-class config; interleave accurate/surrogate decode
    # steps like MiniWeather timesteps in paper Observation 4)
    ffn_surrogate_dim: int = 0
    unroll_inner: bool = False  # unroll inner chunk scans (dry-run calibration)
    # --- which shape cells support sub-quadratic long ctx ---
    subquadratic: bool = False

    # ----- derived -----
    @property
    def pattern_repeats(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} layers not divisible by pattern period "
            f"{len(self.pattern)}"
        )
        return body // len(self.pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, self.d_model // 16)

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so logits shard 16-ways (and to a lane multiple)."""
        mult = 128
        return ((self.vocab_size + mult - 1) // mult) * mult

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (analytic; used for roofline MODEL_FLOPS) ----
    def param_counts(self) -> dict:
        """Returns dict with 'total' and 'active' (per-token) param counts."""
        d, hd = self.d_model, self.head_dim
        total = 0
        active = 0

        def mixer_params(spec: LayerSpec) -> int:
            if spec.mixer == "gqa":
                q = d * self.n_heads * hd + (self.n_heads * hd if self.qkv_bias else 0)
                kv = 2 * (d * self.n_kv_heads * hd + (self.n_kv_heads * hd if self.qkv_bias else 0))
                o = self.n_heads * hd * d
                qkn = 2 * hd if self.qk_norm else 0
                return q + kv + o + qkn
            if spec.mixer == "mla":
                r = self.kv_lora_rank
                q = d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                dkv = d * r + d * self.qk_rope_dim
                ukv = r * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                return q + dkv + ukv + o
            if spec.mixer == "rwkv6":
                ld = self.rwkv_lora_dim
                proj = 5 * d * d  # r k v g o  (w via lora)
                lora = d * ld * 6 + ld * d * 6 + 2 * d  # shift/decay loras + w0/u
                return proj + lora
            if spec.mixer == "mamba":
                di, ds, dc = self.mamba_d_inner, self.mamba_d_state, self.mamba_d_conv
                inp = d * 2 * di
                conv = di * dc
                xproj = di * (self.dt_rank + 2 * ds)
                dtp = self.dt_rank * di
                out = di * d
                ssm = di * ds + di  # A_log, D
                return inp + conv + xproj + dtp + out + ssm
            raise ValueError(spec.mixer)

        def mlp_params(spec: LayerSpec):
            if spec.mlp == "swiglu":
                return 3 * d * self.d_ff, 3 * d * self.d_ff
            if spec.mlp == "gelu":
                return 2 * d * self.d_ff, 2 * d * self.d_ff
            if spec.mlp == "rwkv_cm":
                return 2 * d * self.d_ff, 2 * d * self.d_ff
            if spec.mlp == "moe":
                e_ff = self.moe_d_ff or self.d_ff
                per_e = 3 * d * e_ff
                tot = self.n_experts * per_e + self.n_shared_experts * per_e + d * self.n_experts
                act = (self.top_k + self.n_shared_experts) * per_e + d * self.n_experts
                return tot, act
            raise ValueError(spec.mlp)

        layers = list(self.prefix) + list(self.pattern) * self.pattern_repeats
        for spec in layers:
            m = mixer_params(spec)
            mt, ma = mlp_params(spec)
            x = 0
            if spec.cross_attn:
                x = 2 * d * self.n_kv_heads * hd + d * self.n_heads * hd + self.n_heads * hd * d
            total += m + mt + x + 2 * d  # + norms
            active += m + ma + x + 2 * d
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else d * self.vocab_size
        total += emb + head + d
        active += emb + head + d
        if self.enc_dec:
            enc = 0
            for spec in self.enc_pattern * (self.enc_layers // max(1, len(self.enc_pattern))):
                enc += mixer_params(spec) + mlp_params(spec)[0] + 2 * d
            total += enc
            active += enc
        return {"total": int(total), "active": int(active)}


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_configs() -> dict:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all() -> None:
    # importing the modules registers their configs
    from repro.configs import archs  # noqa: F401


def cell_supported(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Assignment rules: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention)"
    return True, ""


def with_repeats(cfg: ModelConfig, repeats: int) -> ModelConfig:
    """Shrink the pattern-repeat count (dry-run cost calibration)."""
    kw = dict(n_layers=len(cfg.prefix) + len(cfg.pattern) * repeats)
    if cfg.enc_dec:
        kw["enc_layers"] = len(cfg.enc_pattern) * repeats
    return cfg.replace(**kw)
