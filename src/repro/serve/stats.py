"""Per-region serving statistics.

One :class:`ServeStats` per bundle path (the multiplexing key of the
serve queue).  Counters answer the capacity questions the paper's
Observation 2 raises — is the hardware actually fed? — for a *service*
rather than a single call:

  * queue depth (rows waiting right now),
  * batch occupancy (real rows / bucket rows — how much of each
    dispatched mega-batch was useful work vs padding),
  * request latency percentiles (enqueue -> future resolved),
  * achieved rows/s over dispatch busy time.

All mutation goes through the queue/batcher under this object's own
lock, so stats stay consistent when a dispatcher thread and caller
threads flush concurrently.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from typing import Deque, Dict, Optional, Tuple

from repro.obs import metrics as _m


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = q * (len(sorted_vals) - 1)
    lo = int(idx)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = idx - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


class ServeStats:
    """Counters for one serving key; thread-safe; cheap to snapshot."""

    #: EWMA weight for per-bucket batch-latency observations — high
    #: enough to track a drifting service time within a few batches,
    #: low enough that one noisy dispatch doesn't whipsaw the
    #: controller's deadline.
    BATCH_LATENCY_ALPHA = 0.25

    def __init__(self, key: str, latency_window: int = 2048):
        self.key = key
        self._lock = threading.Lock()
        self.latency_window = int(latency_window)
        # obs metric families, bound once per key (label resolution off
        # the hot path); mutation below publishes into these so a scrape
        # sees the same numbers snapshot() reports, across all queues
        self._m_rows_enq = _m.counter(
            "repro_serve_rows_enqueued_total",
            "rows submitted to the serve queue", ("key",))
        self._m_reqs_enq = _m.counter(
            "repro_serve_requests_enqueued_total",
            "requests submitted to the serve queue", ("key",))
        self._m_rows_done = _m.counter(
            "repro_serve_rows_completed_total",
            "rows served back to callers", ("key",))
        self._m_reqs_done = _m.counter(
            "repro_serve_requests_completed_total",
            "requests resolved successfully", ("key",))
        self._m_rows_failed = _m.counter(
            "repro_serve_rows_failed_total",
            "rows whose dispatch raised", ("key",))
        self._m_batches = _m.counter(
            "repro_serve_batches_total",
            "dispatched mega-batches by flush reason", ("key", "reason"))
        self._m_batches_failed = _m.counter(
            "repro_serve_batches_failed_total",
            "dispatches that raised", ("key",))
        self._m_padded = _m.counter(
            "repro_serve_padded_rows_total",
            "bucket rows that were padding, not work", ("key",))
        self._m_remote = _m.counter(
            "repro_serve_remote_rows_total",
            "rows served for other pod hosts in shared mega-batches",
            ("key",))
        self._m_depth_rows = _m.gauge(
            "repro_serve_queue_depth_rows",
            "rows waiting in the queue right now", ("key",))
        self._m_depth_reqs = _m.gauge(
            "repro_serve_queue_depth_requests",
            "requests waiting in the queue right now", ("key",))
        self._m_occupancy = _m.gauge(
            "repro_serve_batch_occupancy",
            "real rows / bucket rows over all dispatches", ("key",))
        self._m_batch_lat = _m.histogram(
            "repro_serve_batch_latency_seconds",
            "wall time of one dispatched mega-batch", ("key",))
        self._m_req_lat = _m.histogram(
            "repro_serve_request_latency_seconds",
            "enqueue -> future-resolved latency per request", ("key",))
        self.requests_enqueued = 0
        self.rows_enqueued = 0
        self.requests_completed = 0
        self.rows_completed = 0
        self.batches = 0
        self.batches_failed = 0
        self.requests_failed = 0
        self.rows_failed = 0
        self.bucket_rows = 0      # sum of dispatched (padded) batch sizes
        self.padded_rows = 0
        # pod-scale serving: batches this key co-served with other hosts,
        # and how many of those batches' real rows belonged to them.
        # Local counters stay local-only (rows_completed is what THIS
        # host's callers got back), so occupancy folds remote rows in —
        # a well-fed cross-host mega-batch must not read as padding.
        self.pod_batches = 0
        self.remote_rows = 0
        self.queue_depth_rows = 0
        self.queue_depth_requests = 0
        self.flush_reasons: Counter = Counter()
        self.busy_s = 0.0         # wall time spent inside dispatches
        self._lat: Deque[float] = deque(maxlen=latency_window)
        # (monotonic time, latency_s, ok) per resolved request — the SLO
        # monitor's windowed burn-rate input.  Failures land with NaN
        # latency (they never resolved, so they miss any latency target).
        self._events: Deque[Tuple[float, float, bool]] = deque(
            maxlen=max(4096, latency_window))
        # (monotonic time, rows) of recent submits: the adaptive flush
        # controller reads the observed arrival rate from this window
        self._arrivals: Deque[Tuple[float, int]] = deque(maxlen=256)
        # bucket -> [ewma_busy_s, n_batches]: measured wall time of one
        # dispatched batch per bucket size.  The adaptive flush
        # controller blends these back into its latency model (measured
        # wins once warm; the roofline prediction is the cold-start
        # prior).  Failed dispatches never land here — an exception path
        # timing says nothing about healthy service time.
        self._bucket_lat: Dict[int, list] = {}

    # ------------------------------------------------------------ hooks ---
    def on_enqueue(self, rows: int) -> None:
        with self._lock:
            self.requests_enqueued += 1
            self.rows_enqueued += rows
            self.queue_depth_rows += rows
            self.queue_depth_requests += 1
            self._arrivals.append((time.monotonic(), rows))
            depth_rows, depth_reqs = \
                self.queue_depth_rows, self.queue_depth_requests
        self._m_reqs_enq.inc(1, key=self.key)
        self._m_rows_enq.inc(rows, key=self.key)
        self._m_depth_rows.set(depth_rows, key=self.key)
        self._m_depth_reqs.set(depth_reqs, key=self.key)

    def on_failure(self, *, requests: int, rows: int, reason: str,
                   busy_s: float) -> None:
        """A dispatch failed: its requests left the queue unserved.

        Kept apart from the completed counters so rows/s and occupancy
        reflect only work the mesh actually served — a key failing every
        batch must look broken on a dashboard, not healthy.
        """
        now = time.monotonic()
        with self._lock:
            self.batches_failed += 1
            self.requests_failed += requests
            self.rows_failed += rows
            self.queue_depth_rows -= rows
            self.queue_depth_requests -= requests
            self.flush_reasons[reason] += 1
            self.busy_s += busy_s
            nan = float("nan")
            for _ in range(requests):
                self._events.append((now, nan, False))
            depth_rows, depth_reqs = \
                self.queue_depth_rows, self.queue_depth_requests
        self._m_batches_failed.inc(1, key=self.key)
        self._m_rows_failed.inc(rows, key=self.key)
        self._m_depth_rows.set(depth_rows, key=self.key)
        self._m_depth_reqs.set(depth_reqs, key=self.key)

    def on_batch(self, *, requests: int, rows: int, bucket: int,
                 reason: str, busy_s: float, latencies_s,
                 remote_rows: int = 0) -> None:
        with self._lock:
            self.batches += 1
            self.requests_completed += requests
            self.rows_completed += rows
            self.bucket_rows += bucket
            # remote hosts' real rows in a pod mega-batch are useful
            # work, not padding
            self.padded_rows += bucket - rows - remote_rows
            if reason == "pod" or remote_rows:
                self.pod_batches += 1
                self.remote_rows += remote_rows
            self.queue_depth_rows -= rows
            self.queue_depth_requests -= requests
            self.flush_reasons[reason] += 1
            self.busy_s += busy_s
            self._lat.extend(latencies_s)
            now = time.monotonic()
            for lat in latencies_s:
                self._events.append((now, float(lat), True))
            ewma = self._bucket_lat.get(bucket)
            if ewma is None:
                self._bucket_lat[bucket] = [float(busy_s), 1]
            elif ewma[1] == 1:
                # the first dispatch of a bucket pays its one-time jit
                # compile; blending it in would leave the EWMA orders of
                # magnitude high for dozens of batches, so the second
                # observation replaces it outright
                ewma[0] = float(busy_s)
                ewma[1] = 2
            else:
                ewma[0] += self.BATCH_LATENCY_ALPHA * (busy_s - ewma[0])
                ewma[1] += 1
            occ = ((self.rows_completed + self.remote_rows)
                   / self.bucket_rows if self.bucket_rows else 0.0)
            depth_rows, depth_reqs = \
                self.queue_depth_rows, self.queue_depth_requests
        self._m_batches.inc(1, key=self.key, reason=reason)
        self._m_reqs_done.inc(requests, key=self.key)
        self._m_rows_done.inc(rows, key=self.key)
        self._m_padded.inc(max(0, bucket - rows - remote_rows), key=self.key)
        if remote_rows:
            self._m_remote.inc(remote_rows, key=self.key)
        self._m_occupancy.set(occ, key=self.key)
        self._m_depth_rows.set(depth_rows, key=self.key)
        self._m_depth_reqs.set(depth_reqs, key=self.key)
        self._m_batch_lat.observe(busy_s, key=self.key)
        for lat in latencies_s:
            self._m_req_lat.observe(lat, key=self.key)

    def batch_latency_s(self, bucket: int,
                        min_batches: int = 1) -> Optional[float]:
        """Measured EWMA wall time of one dispatched batch of ``bucket``
        rows, or None until at least ``min_batches`` batches of that
        bucket have completed (callers treat None as "cold: use the
        model prior")."""
        with self._lock:
            ewma = self._bucket_lat.get(int(bucket))
            if ewma is None or ewma[1] < min_batches:
                return None
            return ewma[0]

    def batch_latencies(self) -> Dict[int, Tuple[float, int]]:
        """Snapshot of every bucket's (ewma_s, n_batches)."""
        with self._lock:
            return {b: (e[0], e[1]) for b, e in self._bucket_lat.items()}

    def bucket_batches(self, bucket: int) -> int:
        """Completed-batch count for one bucket size — the drift
        re-sweep trigger reads this to decide a bucket is *sustained*
        (N real dispatches), not a one-off eager call."""
        with self._lock:
            ewma = self._bucket_lat.get(int(bucket))
            return 0 if ewma is None else int(ewma[1])

    def request_events(self, window_s: Optional[float] = None,
                       now: Optional[float] = None):
        """Recent per-request ``(t_monotonic, latency_s, ok)`` outcomes,
        oldest first — the SLO monitor's burn-rate input.  ``window_s``
        keeps only events newer than ``now - window_s``."""
        with self._lock:
            events = list(self._events)
        if window_s is None:
            return events
        cutoff = (time.monotonic() if now is None else now) - window_s
        return [e for e in events if e[0] >= cutoff]

    def arrival_rate_rows_s(self, now: float = None) -> float:
        """Observed submit rate (rows/s) over the recent arrival window.

        0.0 until at least two submits have landed — callers (the
        adaptive flush controller) treat that as "stats cold" and fall
        back to their static policy.  The rate decays naturally when a
        key goes quiet: the window's span stretches to ``now``.
        """
        with self._lock:
            return self._arrival_rate_locked(now)

    # --------------------------------------------------------- snapshot ---
    def snapshot(self) -> Dict:
        with self._lock:
            # copy only — sorting a full 2048-entry window under the
            # lock stalled every on_batch/on_enqueue racing a dashboard
            # poll; the sort happens on the snapshotter's own time below
            lat = list(self._lat)
            occ = ((self.rows_completed + self.remote_rows)
                   / self.bucket_rows if self.bucket_rows else 0.0)
            rows_per_s = (self.rows_completed / self.busy_s
                          if self.busy_s > 0 else 0.0)
            snap = {
                "key": self.key,
                "requests_enqueued": self.requests_enqueued,
                "rows_enqueued": self.rows_enqueued,
                "requests_completed": self.requests_completed,
                "rows_completed": self.rows_completed,
                "batches": self.batches,
                "batches_failed": self.batches_failed,
                "requests_failed": self.requests_failed,
                "rows_failed": self.rows_failed,
                "bucket_rows": self.bucket_rows,
                "padded_rows": self.padded_rows,
                "pod_batches": self.pod_batches,
                "remote_rows": self.remote_rows,
                "queue_depth_rows": self.queue_depth_rows,
                "queue_depth_requests": self.queue_depth_requests,
                "batch_occupancy": occ,
                "flush_reasons": dict(self.flush_reasons),
                "rows_per_s": rows_per_s,
                "arrival_rate_rows_s": self._arrival_rate_locked(),
                "batch_latency_ewma_ms": {
                    b: round(e[0] * 1e3, 4)
                    for b, e in sorted(self._bucket_lat.items())},
                "batch_latency_batches": {
                    b: e[1] for b, e in sorted(self._bucket_lat.items())},
            }
        lat.sort()
        snap["latency_p50_ms"] = _percentile(lat, 0.50) * 1e3
        snap["latency_p99_ms"] = _percentile(lat, 0.99) * 1e3
        return snap

    def _arrival_rate_locked(self, now: float = None) -> float:
        if len(self._arrivals) < 2:
            return 0.0
        span = (time.monotonic() if now is None else now) \
            - self._arrivals[0][0]
        if span <= 0:
            return 0.0
        # rows after the window's first submit, over the span since it:
        # the first submit opens the window, it doesn't fill it
        rows = sum(r for _, r in self._arrivals) - self._arrivals[0][1]
        return rows / span

    def __repr__(self):  # pragma: no cover - debugging aid
        s = self.snapshot()
        return (f"ServeStats({self.key!r}, depth={s['queue_depth_rows']}, "
                f"batches={s['batches']}, occ={s['batch_occupancy']:.2f}, "
                f"p50={s['latency_p50_ms']:.2f}ms, "
                f"rows/s={s['rows_per_s']:.0f})")
