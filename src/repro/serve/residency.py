"""HBM weight-residency manager: LRU over loaded bundles, byte budget.

The engine cache assumed every bundle's params stay resident forever;
with hundreds of tenants that over-commits HBM.  This manager meters
bytes per loaded bundle (params + the int8 residency when quantized),
keeps an LRU over them, and evicts past a configurable budget
(``REPRO_RESIDENCY_BYTES``, 0 = unlimited).

Eviction deliberately shares one path with retrain invalidation: an
evicted bundle is dropped from the process-wide ``InferenceEngine``
cache exactly like ``invalidate()`` after a NAS rewrite, so the next
request reloads from disk through the same mtime-staleness machinery —
there is exactly one reload path to keep correct, not two.

Admission-time prefetch: ``prefetch(path)`` warms a bundle on a
background daemon thread so a newly admitted tenant's first request
does not pay the load; the warm touches the LRU like any serve would.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.obs import metrics as _m

ENV_BUDGET = "REPRO_RESIDENCY_BYTES"


def _env_budget() -> int:
    try:
        return max(0, int(os.environ.get(ENV_BUDGET, "0")))
    except ValueError:
        return 0


class ResidencyManager:
    """LRU byte accounting over the engine's loaded bundles.

    The engine calls :meth:`note_load` from ``_load()`` (bytes enter)
    and :meth:`touch` from ``get()`` (recency); both may run with the
    engine's cache lock held, so eviction defers the actual cache drop
    to the caller: :meth:`note_load` *returns* the victim paths and the
    engine drops them under its own lock — the manager never calls back
    into the engine, keeping the lock order acyclic.
    """

    def __init__(self, budget_bytes: Optional[int] = None):
        self._budget = budget_bytes
        self._lock = threading.Lock()
        self._resident: "OrderedDict[str, int]" = OrderedDict()
        self.evictions = 0
        self.prefetches = 0
        self.peak_bytes = 0
        self._prefetch_inflight: set = set()
        self._m_bytes = _m.gauge(
            "repro_residency_bytes",
            "bytes of bundle params resident right now")
        self._m_budget = _m.gauge(
            "repro_residency_budget_bytes",
            "configured residency byte budget (0 = unlimited)")
        self._m_evict = _m.counter(
            "repro_residency_evictions_total",
            "bundles evicted to fit the byte budget")
        self._m_prefetch = _m.counter(
            "repro_residency_prefetch_total",
            "bundles warmed ahead of first request")

    # ----------------------------------------------------------- budget ---
    @property
    def budget_bytes(self) -> int:
        """0 means unlimited (the pre-tenancy behavior)."""
        b = self._budget if self._budget is not None else _env_budget()
        return max(0, int(b))

    def set_budget(self, budget_bytes: Optional[int]) -> None:
        self._budget = budget_bytes
        self._m_budget.set(self.budget_bytes)

    def reset_stats(self) -> None:
        """Zero the watermark/counters (benchmarks gate a scenario's own
        peak, not whatever an earlier unlimited phase left behind)."""
        with self._lock:
            self.evictions = 0
            self.prefetches = 0
            self.peak_bytes = sum(self._resident.values())

    # -------------------------------------------------------- LRU hooks ---
    def note_load(self, path: str, nbytes: int) -> List[str]:
        """A bundle's params just materialized: account them, return the
        LRU victims the caller must drop to get back under budget.  The
        just-loaded bundle is never its own victim — a bundle larger
        than the whole budget serves anyway (and everything else
        evicts), mirroring the queue's oversized-request admission."""
        budget = self.budget_bytes
        victims: List[str] = []
        with self._lock:
            self._resident.pop(path, None)
            self._resident[path] = int(nbytes)
            total = sum(self._resident.values())
            if budget > 0:
                for cand in list(self._resident):
                    if total <= budget:
                        break
                    if cand == path:
                        continue
                    total -= self._resident.pop(cand)
                    victims.append(cand)
            self.evictions += len(victims)
            self.peak_bytes = max(self.peak_bytes, total)
            resident = total
        if victims:
            self._m_evict.inc(len(victims))
        self._m_bytes.set(resident)
        self._m_budget.set(budget)
        return victims

    def touch(self, path: str) -> None:
        with self._lock:
            if path in self._resident:
                self._resident.move_to_end(path)

    def drop(self, path: Optional[str] = None) -> None:
        """Bundle(s) left the engine cache (invalidate/evict): release
        their bytes.  Idempotent — retrain invalidation and eviction
        both land here."""
        with self._lock:
            if path is None:
                self._resident.clear()
            else:
                self._resident.pop(str(path), None)
            resident = sum(self._resident.values())
        self._m_bytes.set(resident)

    # --------------------------------------------------------- prefetch ---
    def prefetch(self, path: str) -> Optional[threading.Thread]:
        """Warm a bundle off the caller's thread (admission-time).

        Returns the warming thread (joinable by tests) or None when the
        bundle is already resident or a warm is in flight."""
        path = str(path)
        with self._lock:
            if path in self._resident or path in self._prefetch_inflight:
                return None
            self._prefetch_inflight.add(path)

        def warm():
            try:
                from repro.core.engine import InferenceEngine
                InferenceEngine.get(path)
                with self._lock:
                    self.prefetches += 1
                self._m_prefetch.inc(1)
            except Exception:
                pass  # a missing bundle fails at first real request
            finally:
                with self._lock:
                    self._prefetch_inflight.discard(path)

        t = threading.Thread(target=warm, daemon=True,
                             name="repro-residency-prefetch")
        t.start()
        return t

    # --------------------------------------------------------- snapshot ---
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._resident.values())

    def resident(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._resident)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            resident = dict(self._resident)
            evictions, prefetches = self.evictions, self.prefetches
            peak = self.peak_bytes
        return {
            "budget_bytes": self.budget_bytes,
            "resident_bytes": sum(resident.values()),
            "peak_bytes": peak,
            "resident_bundles": len(resident),
            "evictions": evictions,
            "prefetches": prefetches,
            "lru": list(resident),  # oldest first
        }


#: process-wide manager, mirroring the process-wide engine cache it meters
RESIDENCY = ResidencyManager()
