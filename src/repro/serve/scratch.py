"""Pooled host staging buffers for the batcher's gather/scatter.

Every flush used to allocate fresh numpy arrays twice: once to assemble
the mega-batch (concat) and once to land the device->host result.  At
serving rates that is allocator traffic and page-fault noise on the hot
path.  :class:`ScratchPool` keeps a small set of flat byte buffers and
hands out typed views; a buffer is reused only when **no view of it is
still alive** (checked via the base array's refcount), so result rows
scattered to callers stay valid for as long as the caller holds them —
reuse safety is structural, not a usage convention.

The pool is intentionally dumb: first-fit over capacity, buffers only
grow, at most ``max_buffers`` retained.  In steady state (callers
consume results promptly) every flush is a pool hit; a caller that
parks its rows forever merely costs one buffer, never corruption.
"""
from __future__ import annotations

import sys
import threading
from typing import Tuple

import numpy as np

from repro.obs import metrics as _m

_POOL_REQS = _m.counter("repro_scratch_pool_requests_total",
                        "scratch-buffer takes by outcome", ("outcome",))


class ScratchPool:
    """Reusable pinned host buffers, refcount-guarded against live views."""

    def __init__(self, max_buffers: int = 16, min_bytes: int = 4096):
        self.max_buffers = max_buffers
        self.min_bytes = min_bytes
        self._lock = threading.Lock()
        self._bufs: list = []
        self.hits = 0
        self.misses = 0

    def take(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        """A writable ndarray view of ``shape``/``dtype`` on pooled memory.

        The view pins its backing buffer (refcount) until dropped, so
        callers just let it go out of scope — there is no ``release``.
        Contents are uninitialized; callers overwrite every row they
        hand out (the batcher zero-fills only the padding tail).
        """
        dtype = np.dtype(dtype)
        n = 1
        for d in shape:
            n *= int(d)
        if n == 0:
            return np.empty(shape, dtype)
        nbytes = n * dtype.itemsize
        with self._lock:
            for buf in self._bufs:
                # refs while idle: the pool's list slot, the loop var,
                # and getrefcount's own argument -> 3.  Any live view
                # holds the base chain and pushes this past 3.
                if buf.nbytes >= nbytes and sys.getrefcount(buf) <= 3:
                    self.hits += 1
                    _POOL_REQS.inc(1, outcome="hit")
                    return buf[:nbytes].view(dtype).reshape(shape)
            self.misses += 1
            _POOL_REQS.inc(1, outcome="miss")
            buf = np.empty((max(nbytes, self.min_bytes),), np.uint8)
            self._bufs.append(buf)
            if len(self._bufs) > self.max_buffers:
                # dropping a busy buffer is safe: outstanding views keep
                # it alive, it just stops being pool-managed
                self._bufs.pop(0)
            return buf[:nbytes].view(dtype).reshape(shape)

    def stats(self) -> dict:
        with self._lock:
            return {"buffers": len(self._bufs),
                    "bytes": sum(b.nbytes for b in self._bufs),
                    "hits": self.hits, "misses": self.misses}
