"""Multi-tenant serving control plane: admission, QoS tiers, fair share.

The serve queue multiplexes every region's traffic over shared mesh
capacity; without a control plane, one tenant's burst monopolizes the
batcher and every other tenant's deadline blows.  This module adds the
three pieces a shared inference service needs (the coupling layer Jha et
al. flag as the AI-HPC scaling bottleneck):

  * **admission control** — each tenant declares a token bucket
    (``rate_rows_per_s`` + ``burst_rows``); ``ServeQueue.submit`` asks
    the board before enqueueing, so a runaway producer throttles at the
    door instead of growing the queue.  Per-tenant pending caps bound
    how much of the shared ``max_pending_rows`` budget one tenant may
    hold.
  * **QoS tiers** — a tenant is ``latency`` or ``throughput`` tier;
    the tier's deadline target feeds :class:`AdaptiveFlushController`
    as a per-key bound: latency tenants cap how long the queue may hold
    their rows, throughput tenants permit waiting past the static
    policy to build fat batches.
  * **weighted fair share** — under overload (pending rows exceed one
    batch of capacity) flush order is picked by deficit-round-robin
    over tenant weights instead of FIFO, so a heavy tenant's backlog
    cannot starve a light tenant's key.

All counters publish through :mod:`repro.obs.metrics` labeled by
``tenant`` and surface in ``ServeQueue.snapshot()`` (hence ``/varz``);
``/healthz`` names misbehaving tenants as ``tenant:<id>`` offenders.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.obs import metrics as _m
from repro.serve.stats import _percentile

#: QoS tiers and their default deadline targets (seconds).  A latency
#: tenant's rows may wait at most this long before a deadline flush; a
#: throughput tenant's rows may wait *up to* this long so batches run
#: fat.  ``TenantSpec.deadline_target_s`` overrides per tenant.
LATENCY = "latency"
THROUGHPUT = "throughput"
TIER_DEADLINE_S = {LATENCY: 2e-3, THROUGHPUT: 5e-2}

DEFAULT_TENANT = "default"


class TenantThrottled(RuntimeError):
    """Admission denied: the tenant's token bucket is empty (and the
    queue's policy says raise rather than wait for refill)."""

    def __init__(self, tenant: str, rows: int, wait_s: float):
        super().__init__(
            f"tenant {tenant!r} throttled: {rows} rows exceed the "
            f"admission bucket (refill in ~{wait_s * 1e3:.1f}ms)")
        self.tenant, self.rows, self.wait_s = tenant, rows, wait_s


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's declared contract with the serving control plane."""

    tenant: str = DEFAULT_TENANT
    tier: str = THROUGHPUT          # LATENCY | THROUGHPUT
    weight: float = 1.0             # fair-share weight (rows per DRR round)
    rate_rows_per_s: float = float("inf")  # admission refill rate
    burst_rows: Optional[int] = None       # bucket capacity (None: 1s of rate)
    max_pending_rows: Optional[int] = None  # per-tenant backpressure cap
    deadline_target_s: Optional[float] = None  # overrides the tier default

    def __post_init__(self):
        if self.tier not in (LATENCY, THROUGHPUT):
            raise ValueError(f"tenant {self.tenant!r}: tier must be "
                             f"{LATENCY!r} or {THROUGHPUT!r}, got "
                             f"{self.tier!r}")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.tenant!r}: weight must be > 0 "
                             f"(zero-weight tenants would starve by design)")

    @property
    def target_s(self) -> float:
        if self.deadline_target_s is not None:
            return float(self.deadline_target_s)
        return TIER_DEADLINE_S[self.tier]


class TokenBucket:
    """Thread-safe token bucket over an injectable monotonic clock.

    Refill is **monotonic**: the level between two ``take`` calls never
    decreases (a clock that steps backwards is ignored rather than
    draining the bucket), and never exceeds ``burst``.  A request larger
    than the burst is admitted against a *full* bucket and drives the
    level negative (debt) — otherwise an oversized-but-legitimate batch
    could never be admitted at all and a blocking submit would deadlock.
    """

    def __init__(self, rate_per_s: float, burst: float,
                 clock=time.monotonic):
        if burst <= 0:
            raise ValueError(f"burst must be > 0, got {burst}")
        self.rate = float(rate_per_s)
        self.burst = float(burst)
        self._clock = clock
        self._lock = threading.Lock()
        self._level = float(burst)      # start full: no cold-start penalty
        self._last = clock()

    def _refill_locked(self, now: float) -> None:
        if now <= self._last:
            return  # non-monotonic clock tick: never drain on refill
        if self.rate == float("inf"):
            self._level = self.burst
        else:
            self._level = min(self.burst,
                              self._level + (now - self._last) * self.rate)
        self._last = now

    def level(self) -> float:
        with self._lock:
            self._refill_locked(self._clock())
            return self._level

    def take(self, n: float) -> bool:
        """Admit ``n`` tokens now, or leave the bucket untouched."""
        with self._lock:
            self._refill_locked(self._clock())
            if self._level >= min(float(n), self.burst):
                self._level -= float(n)
                return True
            return False

    def wait_s(self, n: float) -> float:
        """Seconds of refill until ``take(n)`` could succeed (0 = now)."""
        with self._lock:
            self._refill_locked(self._clock())
            need = min(float(n), self.burst) - self._level
            if need <= 0:
                return 0.0
            if self.rate <= 0:
                return float("inf")
            return need / self.rate


class DeficitRoundRobin:
    """Weighted fair flush ordering over tenants.

    Each scheduling round credits every *backlogged* tenant ``quantum``
    rows of deficit; serving a tenant's key charges the served rows
    back **scaled by 1/weight** (a weight-2 tenant pays half price per
    served row, so it sustains twice the service share).  Keys order by
    descending deficit, ties breaking least-recently-served.

    The charge-side weighting is what makes starvation impossible even
    when capacity admits only one key per round: a losing tenant accrues
    the full quantum every round uncharged, while every winner pays per
    served row, so the loser's deficit eventually tops the board.
    (Crediting ``quantum * weight`` instead — the textbook-adjacent
    shape — lets a heavy tenant's credit outpace its charge forever and
    starve the light one.  tests/test_tenancy.py proves the property
    under the hypothesis shim.)
    """

    def __init__(self, quantum_rows: float = 64.0):
        self.quantum = float(quantum_rows)
        self._lock = threading.Lock()
        self._deficit: Dict[str, float] = {}
        self._weight: Dict[str, float] = {}
        self._last_served: Dict[str, int] = {}
        self._serve_seq = 0

    def order(self, items: Sequence[Tuple[str, str, int]],
              weights: Dict[str, float]) -> List[str]:
        """DRR order of ``(key, tenant, pending_rows)`` triples."""
        if not items:
            return []
        with self._lock:
            active = {t for _, t, rows in items if rows > 0}
            for t in active:
                self._weight[t] = max(float(weights.get(t, 1.0)), 1e-9)
                self._deficit[t] = self._deficit.get(t, 0.0) + self.quantum
            return [k for k, _, _ in sorted(
                items,
                key=lambda it: (-self._deficit.get(it[1], 0.0),
                                self._last_served.get(it[1], -1),
                                it[0]))]

    def charge(self, tenant: str, rows: int) -> None:
        with self._lock:
            self._serve_seq += 1
            w = self._weight.get(tenant, 1.0)
            self._deficit[tenant] = self._deficit.get(tenant, 0.0) \
                - rows / w
            self._last_served[tenant] = self._serve_seq

    def deficit(self, tenant: str) -> float:
        with self._lock:
            return self._deficit.get(tenant, 0.0)


class _TenantState:
    """Mutable per-tenant accounting behind the board's lock."""

    __slots__ = ("spec", "bucket", "pending_rows", "admitted_rows",
                 "served_rows", "dropped_rows", "dropped_requests",
                 "throttled_total", "last_drop_t", "lat")

    def __init__(self, spec: TenantSpec, clock, latency_window: int):
        self.spec = spec
        burst = spec.burst_rows
        if burst is None:
            rate = spec.rate_rows_per_s
            burst = max(1.0, rate if rate != float("inf") else 1.0)
        self.bucket = TokenBucket(spec.rate_rows_per_s, burst, clock)
        self.pending_rows = 0
        self.admitted_rows = 0
        self.served_rows = 0
        self.dropped_rows = 0
        self.dropped_requests = 0
        self.throttled_total = 0
        self.last_drop_t: Optional[float] = None
        self.lat: Deque[float] = deque(maxlen=latency_window)


class TenantBoard:
    """The control plane: tenant registry + admission + fair share.

    One board per :class:`ServeQueue` (pass ``tenancy=board``); the
    queue calls in under its own lock, the board takes its own lock
    second and never calls back out, so the lock order is acyclic.
    """

    #: tenants that dropped rows within this window are /healthz offenders
    OFFENDER_WINDOW_S = 60.0

    def __init__(self, specs: Sequence[TenantSpec] = (), *,
                 default_spec: Optional[TenantSpec] = None,
                 drr_quantum_rows: float = 64.0,
                 latency_window: int = 2048,
                 clock=time.monotonic):
        self._clock = clock
        self._default_spec = default_spec or TenantSpec()
        self._lock = threading.Lock()
        self._states: Dict[str, _TenantState] = {}
        self._key_tenant: Dict[str, str] = {}
        self.latency_window = int(latency_window)
        self.drr = DeficitRoundRobin(drr_quantum_rows)
        self._m_admitted = _m.counter(
            "repro_tenant_admitted_rows_total",
            "rows admitted past the tenant token bucket", ("tenant",))
        self._m_throttled = _m.counter(
            "repro_tenant_throttled_total",
            "admission attempts denied by the token bucket", ("tenant",))
        self._m_served = _m.counter(
            "repro_tenant_served_rows_total",
            "rows resolved back to the tenant's callers", ("tenant",))
        self._m_dropped = _m.counter(
            "repro_tenant_dropped_rows_total",
            "rows whose dispatch failed (tenant-attributed)", ("tenant",))
        self._m_pending = _m.gauge(
            "repro_tenant_pending_rows",
            "rows the tenant holds in the queue right now", ("tenant",))
        self._m_lat = _m.histogram(
            "repro_tenant_request_latency_seconds",
            "enqueue -> resolve latency per tenant", ("tenant",))
        for spec in specs:
            self.register(spec)

    # --------------------------------------------------------- registry ---
    def register(self, spec: TenantSpec) -> TenantSpec:
        with self._lock:
            self._states[spec.tenant] = _TenantState(
                spec, self._clock, self.latency_window)
        return spec

    def _state_locked(self, tenant: str) -> _TenantState:
        st = self._states.get(tenant)
        if st is None:
            spec = dataclasses.replace(self._default_spec, tenant=tenant)
            st = self._states[tenant] = _TenantState(
                spec, self._clock, self.latency_window)
        return st

    def spec_for(self, tenant: str) -> TenantSpec:
        with self._lock:
            return self._state_locked(tenant).spec

    def tenants(self) -> List[str]:
        with self._lock:
            return sorted(self._states)

    # -------------------------------------------------------- admission ---
    def admit(self, tenant: str, rows: int, *, block: bool = True,
              timeout_s: float = 30.0) -> None:
        """Charge ``rows`` against the tenant's token bucket.

        Raises :class:`TenantThrottled` when the bucket is empty and
        ``block`` is False (or the refill wait would exceed
        ``timeout_s``).  Blocking waits sleep outside every lock — refill
        is wall-clock, not queue-drain, so there is nothing to be
        notified by.
        """
        with self._lock:
            st = self._state_locked(tenant)
        deadline = self._clock() + timeout_s
        while True:
            if st.bucket.take(rows):
                return
            wait = st.bucket.wait_s(rows)
            with self._lock:
                st.throttled_total += 1
            self._m_throttled.inc(1, tenant=tenant)
            if not block or self._clock() + wait > deadline:
                raise TenantThrottled(tenant, rows, wait)
            time.sleep(min(wait, 0.05) if wait > 0 else 1e-4)

    def has_room(self, tenant: str, rows: int) -> bool:
        """Per-tenant backpressure: may this tenant hold ``rows`` more?

        A tenant with no pending rows is always admitted (oversized
        requests flush as their own batch — same no-deadlock rule the
        queue applies globally)."""
        with self._lock:
            st = self._state_locked(tenant)
            cap = st.spec.max_pending_rows
            if cap is None or st.pending_rows == 0:
                return True
            return st.pending_rows + rows <= cap

    # ------------------------------------------------------- accounting ---
    def on_enqueue(self, tenant: str, key: str, rows: int) -> None:
        with self._lock:
            st = self._state_locked(tenant)
            st.pending_rows += rows
            st.admitted_rows += rows
            self._key_tenant[key] = tenant
            pending = st.pending_rows
        self._m_admitted.inc(rows, tenant=tenant)
        self._m_pending.set(pending, tenant=tenant)

    def on_dispatch(self, tenant: str, rows: int) -> None:
        """Rows left the queue for the engine: release pending, charge
        the DRR deficit (dispatch IS the service the scheduler meters)."""
        with self._lock:
            st = self._state_locked(tenant)
            st.pending_rows = max(0, st.pending_rows - rows)
            pending = st.pending_rows
        self.drr.charge(tenant, rows)
        self._m_pending.set(pending, tenant=tenant)

    def on_served(self, tenant: str, rows: int,
                  latencies_s: Sequence[float] = ()) -> None:
        with self._lock:
            st = self._state_locked(tenant)
            st.served_rows += rows
            st.lat.extend(float(x) for x in latencies_s)
        self._m_served.inc(rows, tenant=tenant)
        for lat in latencies_s:
            self._m_lat.observe(float(lat), tenant=tenant)

    def on_dropped(self, tenant: str, requests: int, rows: int) -> None:
        with self._lock:
            st = self._state_locked(tenant)
            st.dropped_rows += rows
            st.dropped_requests += requests
            st.last_drop_t = self._clock()
        self._m_dropped.inc(rows, tenant=tenant)

    # ------------------------------------------------------- fair share ---
    def tenant_for_key(self, key: str) -> str:
        with self._lock:
            return self._key_tenant.get(key, DEFAULT_TENANT)

    def order_keys(self, pending: Sequence[Tuple[str, int]]) -> List[str]:
        """DRR flush order for ``(key, pending_rows)`` pairs."""
        with self._lock:
            items = [(k, self._key_tenant.get(k, DEFAULT_TENANT), rows)
                     for k, rows in pending]
            weights = {t: st.spec.weight for t, st in self._states.items()}
        return self.drr.order(items, weights)

    # ------------------------------------------------------ QoS / obs ----
    def qos_for_key(self, key: str) -> Tuple[Optional[str], Optional[float]]:
        """(tier, deadline_target_s) of the tenant bound to ``key``, or
        (None, None) for keys no tenant has touched."""
        with self._lock:
            tenant = self._key_tenant.get(key)
            if tenant is None:
                return None, None
            spec = self._state_locked(tenant).spec
        return spec.tier, spec.target_s

    def offenders(self) -> List[str]:
        """Tenant ids misbehaving *right now* — dropped rows within the
        offender window, or pending past their declared cap (stuck
        backlog).  ``/healthz`` prefixes these ``tenant:``."""
        now = self._clock()
        out = []
        with self._lock:
            for t, st in sorted(self._states.items()):
                if st.last_drop_t is not None and \
                        now - st.last_drop_t <= self.OFFENDER_WINDOW_S:
                    out.append(t)
                elif st.spec.max_pending_rows is not None and \
                        st.pending_rows > st.spec.max_pending_rows:
                    out.append(t)
        return out

    def p99_ms(self, tenant: str) -> float:
        with self._lock:
            st = self._states.get(tenant)
            lat = sorted(st.lat) if st is not None else []
        return _percentile(lat, 0.99) * 1e3

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            states = dict(self._states)
            served_total = sum(st.served_rows for st in states.values())
        out = {}
        for t, st in sorted(states.items()):
            with self._lock:
                lat = sorted(st.lat)
                snap = {
                    "tier": st.spec.tier,
                    "weight": st.spec.weight,
                    "deadline_target_s": st.spec.target_s,
                    "pending_rows": st.pending_rows,
                    "admitted_rows": st.admitted_rows,
                    "served_rows": st.served_rows,
                    "dropped_rows": st.dropped_rows,
                    "dropped_requests": st.dropped_requests,
                    "throttled_total": st.throttled_total,
                    "bucket_level": round(st.bucket.level(), 3),
                    "drr_deficit": round(self.drr.deficit(t), 3),
                }
            snap["occupancy"] = (st.served_rows / served_total
                                 if served_total else 0.0)
            snap["latency_p50_ms"] = _percentile(lat, 0.50) * 1e3
            snap["latency_p99_ms"] = _percentile(lat, 0.99) * 1e3
            out[t] = snap
        return out
