"""Async batched surrogate serving: queue, coalescing batcher, stats.

The paper's speedups come from replacing accurate regions with surrogate
inference; at scale the surrogate is a *service*, not a function call.
This package turns ``MLRegion`` invocations into queued requests that
coalesce into mesh-wide padded mega-batches (see README.md).
"""
from repro.serve.batcher import Batcher, bucket_for, bucket_size
from repro.serve.queue import (Backpressure, FlushPolicy, ServeFuture,
                               ServeQueue)
from repro.serve.scratch import ScratchPool
from repro.serve.stats import ServeStats

__all__ = ["Backpressure", "Batcher", "FlushPolicy", "ScratchPool",
           "ServeFuture", "ServeQueue", "ServeStats", "bucket_for",
           "bucket_size"]
