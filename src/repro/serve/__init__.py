"""Async batched surrogate serving: queue, coalescing batcher, stats.

The paper's speedups come from replacing accurate regions with surrogate
inference; at scale the surrogate is a *service*, not a function call.
This package turns ``MLRegion`` invocations into queued requests that
coalesce into mesh-wide padded mega-batches (see README.md).  The
multi-tenant control plane (:mod:`repro.serve.tenancy`) adds per-tenant
admission, QoS tiers and weighted fair share on top; the residency
manager (:mod:`repro.serve.residency`) meters loaded bundles against an
HBM byte budget.
"""
from repro.serve.batcher import Batcher, bucket_for, bucket_size
from repro.serve.queue import (Backpressure, FlushPolicy, ServeFuture,
                               ServeQueue)
from repro.serve.residency import RESIDENCY, ResidencyManager
from repro.serve.scratch import ScratchPool
from repro.serve.stats import ServeStats
from repro.serve.tenancy import (DeficitRoundRobin, TenantBoard, TenantSpec,
                                 TenantThrottled, TokenBucket)

__all__ = ["Backpressure", "Batcher", "DeficitRoundRobin", "FlushPolicy",
           "RESIDENCY", "ResidencyManager", "ScratchPool", "ServeFuture",
           "ServeQueue", "ServeStats", "TenantBoard", "TenantSpec",
           "TenantThrottled", "TokenBucket", "bucket_for", "bucket_size"]
