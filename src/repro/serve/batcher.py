"""Batcher: coalesce queued region requests into bucket-shaped mega-batches.

The queue hands the batcher a FIFO run of requests for one bundle path;
the batcher concatenates their rows, dispatches them through the
engine's :meth:`InferenceEngine.apply_batched` (which pads to the
power-of-two bucket, places the batch over the ``data`` axis of the
active mesh, and slices the padding back off), then scatters per-request
row slices into the callers' futures.

Row-wise surrogates make this exact rather than approximate: each output
row depends only on its input row, so a request's rows come back
bit-identical to what a synchronous ``MLRegion._infer`` of the same
inputs produces, regardless of which mega-batch they rode in (asserted
by tests/test_serve.py).
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.scratch import ScratchPool
from repro.serve.stats import ServeStats


def bucket_size(n: int, min_bucket: int = 8) -> int:
    """Smallest power-of-two >= max(n, min_bucket).

    Power-of-two buckets bound the jit cache to log2(max batch) shapes
    per sharding context.
    """
    b = max(int(min_bucket), 1)
    while b < n:
        b <<= 1
    return b


def bucket_for(n: int, min_bucket: int, n_shards: int = 1) -> int:
    """Dispatch bucket: power-of-two floor, rounded up to a multiple of
    the data-shard count — a bucket smaller than (or not dividing) the
    shard count would make `spec_for` drop the data axis and silently
    replicate the whole batch on every device.
    """
    b = bucket_size(n, max(min_bucket, n_shards))
    if n_shards > 1 and b % n_shards:
        b += -b % n_shards
    return b


class Batcher:
    """Stateless dispatch: concat -> padded apply -> scatter.

    ``engine_for`` maps a queue key (bundle path) to an engine-like
    object exposing ``apply_batched``; the default resolves through the
    process-wide :class:`InferenceEngine` cache, so retrained bundles
    are picked up between batches exactly like synchronous serving.
    """

    def __init__(self, *, min_bucket: int = 8,
                 engine_for: Optional[Callable] = None,
                 scratch: Optional[ScratchPool] = None):
        self.min_bucket = min_bucket
        self.scratch = scratch or ScratchPool()
        if engine_for is None:
            def engine_for(key):
                from repro.core.engine import InferenceEngine
                return InferenceEngine.get(key)
        self._engine_for = engine_for

    def _gather(self, requests, n: int, bucket: int):
        """Assemble the mega-batch.

        A lone request rides through untouched (the engine pads it);
        multiple requests gather into a pooled scratch buffer already
        padded to the bucket, so the engine skips its own concat+pad
        and the resulting device array is batcher-owned — safe to
        donate to the compiled apply.
        """
        if len(requests) == 1:
            return requests[0].x, False
        feat = requests[0].x.shape[1:]
        buf = self.scratch.take((bucket,) + tuple(feat),
                                np.dtype(requests[0].x.dtype))
        off = 0
        for r in requests:
            buf[off:off + r.n] = np.asarray(r.x)
            off += r.n
        buf[off:] = 0  # zero padding: same rows a jnp pad would produce
        return jnp.asarray(buf), True

    def _to_host(self, Y) -> np.ndarray:
        """One device->host gather for the whole mega-batch, landed in a
        pooled scratch buffer (per-shard zero-copy reads on host-mesh
        arrays) instead of a fresh allocation per flush.  Futures get
        row views of the buffer; the pool will not reuse it while any
        view is alive."""
        try:
            shards = list(Y.addressable_shards)
        except Exception:
            return np.asarray(Y)
        out = self.scratch.take(tuple(Y.shape), np.dtype(Y.dtype))
        for s in shards:
            if getattr(s, "replica_id", 0) == 0:
                out[s.index] = np.asarray(s.data)
        return out

    @staticmethod
    def _request_ctx(requests):
        """Install the submitters' ShardCtx around the batched apply.

        Sharding contexts are thread-local; a deadline/max-batch flush
        runs on the dispatcher thread, which would otherwise serve the
        mega-batch unsharded.  The submit-time ctx governs even when it
        is None (a no-mesh submit flushed inline from inside someone
        else's ``use_mesh`` must not pick up that ambient mesh, or the
        engine's bucket would diverge from the one stats recorded).
        Requests queued under different meshes never coalesce
        meaningfully, so the first request's ctx speaks for the batch
        (they arrived FIFO on one key).
        """
        from repro.dist.sharding import use_mesh
        ctx = requests[0].ctx
        if ctx is None:
            return use_mesh(None)
        return use_mesh(ctx.mesh, ctx.multi_pod)

    def dispatch(self, key: str, requests: List, stats: ServeStats,
                 reason: str) -> None:
        """Serve one coalesced batch and resolve every request future."""
        if not requests:
            return
        # monotonic throughout: latencies subtract submit-time stamps
        # taken with time.monotonic(), and mixing clocks is undefined
        t0 = time.monotonic()
        try:
            n = sum(r.n for r in requests)
            ctx = requests[0].ctx
            shards = (ctx.axis_size("data")
                      if ctx is not None and ctx.mesh is not None else 1)
            bucket = bucket_for(n, self.min_bucket, shards)
            X, owned = self._gather(requests, n, bucket)
            eng = self._engine_for(key)
            with self._request_ctx(requests):
                Y = eng.apply_batched(X, min_bucket=self.min_bucket,
                                      donate=owned, prepadded=owned)
            # one device->host gather for the whole mega-batch: scattering
            # zero-copy numpy row views is ~1000x cheaper than slicing a
            # mesh-sharded array once per caller (each such slice is a
            # cross-device gather of its own)
            Y = self._to_host(jax.block_until_ready(Y))
        except Exception as e:  # engine/load failure fails the whole batch
            for r in requests:
                r.future.set_exception(e)
            stats.on_failure(requests=len(requests),
                             rows=sum(r.n for r in requests), reason=reason,
                             busy_s=time.monotonic() - t0)
            return
        t1 = time.monotonic()
        off = 0
        lats = []
        for r in requests:
            r.future.set_result(Y[off:off + r.n])
            off += r.n
            lats.append(t1 - r.t_enqueue)
        stats.on_batch(requests=len(requests), rows=n, bucket=bucket,
                       reason=reason, busy_s=t1 - t0, latencies_s=lats)
