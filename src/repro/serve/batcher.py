"""Batcher: coalesce queued region requests into bucket-shaped mega-batches.

The queue hands the batcher a FIFO run of requests for one bundle path;
the batcher concatenates their rows, dispatches them through the
engine's :meth:`InferenceEngine.apply_batched` (which pads to the
power-of-two bucket, places the batch over the ``data`` axis of the
active mesh, and slices the padding back off), then scatters per-request
row slices into the callers' futures.

Row-wise surrogates make this exact rather than approximate: each output
row depends only on its input row, so a request's rows come back
bit-identical to what a synchronous ``MLRegion._infer`` of the same
inputs produces, regardless of which mega-batch they rode in (asserted
by tests/test_serve.py).
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import TRACER
from repro.obs import metrics as _metrics
from repro.resilience.breaker import BREAKERS
from repro.resilience.faults import FAULTS
from repro.resilience.retry import DEFAULT_RETRY, RetryPolicy
from repro.serve.scratch import ScratchPool
from repro.serve.stats import ServeStats

# process-wide dispatch sequence: ties a request's spans to the batch
# that served it in a trace without threading ids through call sites
_BATCH_IDS = itertools.count()

_RETRIES = _metrics.counter(
    "repro_resilience_retries_total",
    "dispatch attempts retried after a transient failure", ("key",))
_SPLITS = _metrics.counter(
    "repro_resilience_split_retries_total",
    "batches bisected to isolate a poisoned request", ("key",))
_NONFINITE = _metrics.counter(
    "repro_resilience_nonfinite_total",
    "output rows screened as NaN/Inf before scatter", ("key",))


class NonFiniteOutput(RuntimeError):
    """A request's output rows contained NaN/Inf and were withheld.

    Screened before scatter: non-finite surrogate output is a failure
    (the caller falls back to the accurate path via its future's
    exception), never a silently returned value.
    """

    def __init__(self, key: str, rows: int):
        super().__init__(f"non-finite surrogate output for {key!r} "
                         f"({rows} rows withheld)")
        self.key, self.rows = key, rows


def bucket_size(n: int, min_bucket: int = 8) -> int:
    """Smallest power-of-two >= max(n, min_bucket).

    Power-of-two buckets bound the jit cache to log2(max batch) shapes
    per sharding context.
    """
    b = max(int(min_bucket), 1)
    while b < n:
        b <<= 1
    return b


def bucket_for(n: int, min_bucket: int, n_shards: int = 1) -> int:
    """Dispatch bucket: power-of-two floor, rounded up to a multiple of
    the data-shard count — a bucket smaller than (or not dividing) the
    shard count would make `spec_for` drop the data axis and silently
    replicate the whole batch on every device.
    """
    b = bucket_size(n, max(min_bucket, n_shards))
    if n_shards > 1 and b % n_shards:
        b += -b % n_shards
    return b


class Batcher:
    """Stateless dispatch: concat -> padded apply -> scatter.

    ``engine_for`` maps a queue key (bundle path) to an engine-like
    object exposing ``apply_batched``; the default resolves through the
    process-wide :class:`InferenceEngine` cache, so retrained bundles
    are picked up between batches exactly like synchronous serving.
    """

    def __init__(self, *, min_bucket: int = 8,
                 engine_for: Optional[Callable] = None,
                 scratch: Optional[ScratchPool] = None,
                 retry: Optional[RetryPolicy] = None):
        self.min_bucket = min_bucket
        self.scratch = scratch or ScratchPool()
        self.retry = retry or DEFAULT_RETRY
        # ServeQueue attaches its TenantBoard here so per-request
        # outcomes (served rows + latencies, drops) land on the tenant
        # that submitted them; None = tenancy-free queue, zero overhead
        self.tenancy = None
        if engine_for is None:
            def engine_for(key):
                from repro.core.engine import InferenceEngine
                return InferenceEngine.get(key)
        self._engine_for = engine_for

    @staticmethod
    def _device_resident(x) -> bool:
        """True when ``x`` is a committed, fully-addressable jax.Array on
        a non-host backend.  There the mega-batch should assemble with an
        on-device concat — the host scratch gather would be a D2H
        round-trip per request followed by one H2D of the whole batch.
        On CPU the pooled host gather IS the fast path (measured in PR 3),
        so plain numpy inputs and CPU arrays keep using it."""
        if not isinstance(x, jax.Array):
            return False
        try:
            if not x.is_fully_addressable:
                return False
            dev = next(iter(x.devices()))
        except Exception:
            return False
        return dev.platform != "cpu"

    def _gather(self, requests, n: int, bucket: int):
        """Assemble the mega-batch.

        A lone request rides through untouched (the engine pads it).
        Device-resident inputs concatenate on device — no D2H round-trip;
        the concat output is batcher-owned and safe to donate.  Host
        inputs gather into a pooled scratch buffer already padded to the
        bucket, so the engine skips its own concat+pad.
        """
        if len(requests) == 1:
            return requests[0].x, False
        feat = tuple(requests[0].x.shape[1:])
        if all(self._device_resident(r.x) for r in requests):
            parts = [r.x for r in requests]
            if bucket > n:
                parts.append(jnp.zeros((bucket - n,) + feat,
                                       requests[0].x.dtype))
            return jnp.concatenate(parts, axis=0), True
        buf = self.scratch.take((bucket,) + feat,
                                np.dtype(requests[0].x.dtype))
        off = 0
        for r in requests:
            buf[off:off + r.n] = np.asarray(r.x)
            off += r.n
        buf[off:] = 0  # zero padding: same rows a jnp pad would produce
        return jnp.asarray(buf), True

    def _to_host(self, Y, *, rows=None) -> np.ndarray:
        """One device->host gather of rows ``[rows[0], rows[1])`` (default:
        all) landed in a pooled scratch buffer (per-shard zero-copy reads
        on host-mesh arrays).  Futures get row views of the buffer; the
        pool will not reuse it while any view is alive.

        Only *addressable* shards can be read, and that is now enforced:
        if the local shards do not cover every requested element, this
        raises instead of returning a buffer whose missing rows are
        uninitialized pool memory.  Multi-process dispatches must either
        ask only for the rows this host owns (``dispatch_pod`` passes its
        slab range) or gather explicitly before landing.
        """
        try:
            shards = list(Y.addressable_shards)
        except Exception:  # plain numpy/eager arrays: everything is local
            arr = np.asarray(Y)
            return arr if rows is None else arr[rows[0]:rows[1]]
        n_rows = int(Y.shape[0])
        start, stop = (0, n_rows) if rows is None else \
            (int(rows[0]), int(rows[1]))
        out = self.scratch.take((stop - start,) + tuple(Y.shape[1:]),
                                np.dtype(Y.dtype))
        filled = 0
        for s in shards:
            if getattr(s, "replica_id", 0) != 0:
                continue
            idx = tuple(s.index)
            i0 = idx[0] if idx else slice(None)
            s0 = 0 if i0.start is None else int(i0.start)
            e0 = n_rows if i0.stop is None else int(i0.stop)
            lo, hi = max(s0, start), min(e0, stop)
            if lo >= hi:
                continue
            block = np.asarray(s.data)[lo - s0:hi - s0]
            out[(slice(lo - start, hi - start),) + idx[1:]] = block
            filled += block.size
        if filled != out.size:
            raise RuntimeError(
                f"_to_host: addressable shards cover {filled}/{out.size} "
                f"elements of rows [{start}, {stop}) of a {Y.shape} "
                f"output — the rest is owned by other processes.  A "
                f"multi-process dispatch must read only its own slab "
                f"(ServeQueue.pod_flush / Batcher.dispatch_pod) or "
                f"gather the array before landing it.")
        return out

    @staticmethod
    def _request_ctx(requests):
        """Install the submitters' ShardCtx around the batched apply.

        Sharding contexts are thread-local; a deadline/max-batch flush
        runs on the dispatcher thread, which would otherwise serve the
        mega-batch unsharded.  The submit-time ctx governs even when it
        is None (a no-mesh submit flushed inline from inside someone
        else's ``use_mesh`` must not pick up that ambient mesh, or the
        engine's bucket would diverge from the one stats recorded).
        Requests queued under different meshes never coalesce
        meaningfully, so the first request's ctx speaks for the batch
        (they arrived FIFO on one key).
        """
        from repro.dist.sharding import use_mesh
        ctx = requests[0].ctx
        if ctx is None:
            return use_mesh(None)
        return use_mesh(ctx.mesh, ctx.multi_pod)

    def _fail_all(self, requests, exc, stats, reason, busy_s, *,
                  record_breaker_key=None):
        for r in requests:
            r.future.set_exception(exc)
        self._note_dropped(requests)
        stats.on_failure(requests=len(requests),
                         rows=sum(r.n for r in requests), reason=reason,
                         busy_s=busy_s)
        if record_breaker_key is not None:
            BREAKERS.record_failure(record_breaker_key)

    # ------------------------------------------------ tenant attribution ---
    def _note_dropped(self, requests) -> None:
        board = self.tenancy
        if board is None or not requests:
            return
        agg = {}
        for r in requests:
            t = getattr(r, "tenant", None)
            if t is not None:
                c = agg.setdefault(t, [0, 0])
                c[0] += 1
                c[1] += r.n
        for t, (n_req, n_rows) in agg.items():
            board.on_dropped(t, n_req, n_rows)

    def _note_served(self, requests, bad, lats) -> None:
        """Attribute a scattered batch's outcomes per tenant.  ``lats``
        aligns with the non-``bad`` requests in order (exactly how the
        scatter loops build it)."""
        board = self.tenancy
        if board is None or not requests:
            return
        self._note_dropped([r for i, r in enumerate(requests) if i in bad])
        li = 0
        agg = {}
        for i, r in enumerate(requests):
            if i in bad:
                continue
            lat = lats[li]
            li += 1
            t = getattr(r, "tenant", None)
            if t is None:
                continue
            c = agg.setdefault(t, [0, []])
            c[0] += r.n
            c[1].append(lat)
        for t, (rows, ls) in agg.items():
            board.on_served(t, rows, ls)

    @staticmethod
    def _screen_nonfinite(requests, Y) -> tuple:
        """Indices of requests whose output rows contain NaN/Inf.

        Cheap whole-batch check first; the per-request scan only runs
        when the batch is known dirty, so the healthy path pays one
        vectorized ``isfinite`` reduce over host memory.
        """
        if not np.issubdtype(Y.dtype, np.inexact) \
                or bool(np.isfinite(Y).all()):
            return ()
        bad, off = [], 0
        for i, r in enumerate(requests):
            if not np.isfinite(Y[off:off + r.n]).all():
                bad.append(i)
            off += r.n
        return tuple(bad)

    def dispatch(self, key: str, requests: List, stats: ServeStats,
                 reason: str, *, _attempts: Optional[int] = None) -> None:
        """Serve one coalesced batch and resolve every request future.

        Failure handling, in order:

        1. Engine *load* failures (missing/corrupt bundle) are
           deterministic — fail the whole batch once, no retry, no split.
        2. Compute/landing failures retry up to ``retry.max_attempts``
           with capped exponential backoff (the mega-batch is re-gathered
           each attempt — a donated buffer is dead after a failed apply).
        3. A multi-request batch that exhausts its retries is bisected
           (split-retry): each half re-dispatches with a single attempt,
           recursing down to singles, so one poisoned request cannot fail
           its siblings — only the request that actually fails does.
        4. Non-finite output rows are screened before scatter and
           converted to per-request :class:`NonFiniteOutput` failures,
           never silently returned.

        Every outcome feeds the per-key circuit breaker.
        """
        if not requests:
            return
        # monotonic throughout: latencies subtract submit-time stamps
        # taken with time.monotonic(), and mixing clocks is undefined
        t0 = time.monotonic()
        tr = TRACER
        traced = tr.enabled
        bid = next(_BATCH_IDS)
        try:
            eng = self._engine_for(key)
        except Exception as e:
            # bundle-load failures are batch-independent: retrying or
            # splitting would re-fail identically request by request
            tr.instant("batch.error", cat="batch",
                       args={"key": key, "batch": bid, "error": repr(e)})
            self._fail_all(requests, e, stats, reason,
                           time.monotonic() - t0, record_breaker_key=key)
            return
        n = sum(r.n for r in requests)
        ctx = requests[0].ctx
        shards = (ctx.axis_size("data")
                  if ctx is not None and ctx.mesh is not None else 1)
        bucket = bucket_for(n, self.min_bucket, shards)
        attempts = self.retry.max_attempts if _attempts is None \
            else max(1, _attempts)
        Y = None
        last_exc: Optional[Exception] = None
        for attempt in range(attempts):
            try:
                with tr.span("batch.gather", cat="batch",
                             args={"key": key, "batch": bid, "rows": n,
                                   "bucket": bucket,
                                   "requests": len(requests)}):
                    X, owned = self._gather(requests, n, bucket)
                with tr.span("batch.apply", cat="batch",
                             args={"key": key, "batch": bid,
                                   "bucket": bucket, "reason": reason,
                                   "attempt": attempt}):
                    with self._request_ctx(requests):
                        Y = eng.apply_batched(X, min_bucket=self.min_bucket,
                                              donate=owned, prepadded=owned)
                    Y = jax.block_until_ready(Y)
                # one device->host gather for the whole mega-batch:
                # scattering zero-copy numpy row views is ~1000x cheaper
                # than slicing a mesh-sharded array once per caller (each
                # such slice is a cross-device gather of its own)
                with tr.span("batch.to_host", cat="batch",
                             args={"key": key, "batch": bid}):
                    Y = self._to_host(Y)
                break
            except Exception as e:
                Y, last_exc = None, e
                tr.instant("batch.error", cat="batch",
                           args={"key": key, "batch": bid,
                                 "attempt": attempt, "error": repr(e)})
                if attempt + 1 < attempts:
                    _RETRIES.inc(1, key=key)
                    time.sleep(self.retry.delay_for(attempt))
        if Y is None:
            if len(requests) > 1:
                # split-retry: bisect so a poisoned request fails alone;
                # children get one attempt each (the backoff budget was
                # already spent above) and recurse down to singles
                _SPLITS.inc(1, key=key)
                tr.instant("batch.split", cat="batch",
                           args={"key": key, "batch": bid,
                                 "requests": len(requests)})
                mid = len(requests) // 2
                self.dispatch(key, requests[:mid], stats, reason,
                              _attempts=1)
                self.dispatch(key, requests[mid:], stats, reason,
                              _attempts=1)
                return
            self._fail_all(requests, last_exc, stats, reason,
                           time.monotonic() - t0, record_breaker_key=key)
            return
        if FAULTS.enabled:
            rule = FAULTS.fire("batcher.scatter", key=key)
            if rule is not None and rule.mode in ("nan", "inf"):
                Y = np.array(Y)  # writable copy on the injected path only
                Y[:requests[0].n] = rule.value
        bad = self._screen_nonfinite(requests, Y)
        t1 = time.monotonic()
        off = 0
        lats = []
        bad_rows = 0
        # per-request span [enqueue, future resolved]: with queue.submit
        # it tiles the request's whole enqueue->resolve window, so
        # coverage audits close; queued time is recoverable inside it as
        # (batch.gather.ts - this span's ts).  One args dict serves every
        # request of the batch (rec() documents shared-args safety).
        rargs = {"key": key, "batch": bid, "reason": reason} if traced \
            else None
        for i, r in enumerate(requests):
            if i in bad:
                r.future.set_exception(NonFiniteOutput(key, r.n))
                bad_rows += r.n
                off += r.n
                continue
            r.future.set_result(Y[off:off + r.n])
            off += r.n
            lats.append(t1 - r.t_enqueue)
            if traced:
                tr.rec("serve.request", "serve", r.t_enqueue,
                       time.monotonic(), r.trace, rargs)
        if traced:
            tr.record("batch.scatter", t1, time.monotonic(), cat="batch",
                      args={"key": key, "batch": bid,
                            "requests": len(requests)})
        self._note_served(requests, bad, lats)
        if bad:
            _NONFINITE.inc(bad_rows, key=key)
            tr.instant("batch.nonfinite", cat="batch",
                       args={"key": key, "batch": bid,
                             "requests": len(bad), "rows": bad_rows})
            stats.on_failure(requests=len(bad), rows=bad_rows,
                             reason=reason, busy_s=0.0)
            BREAKERS.record_failure(key)
        else:
            BREAKERS.record_success(key)
        if len(bad) < len(requests):
            stats.on_batch(requests=len(requests) - len(bad),
                           rows=n - bad_rows, bucket=bucket, reason=reason,
                           busy_s=t1 - t0, latencies_s=lats)
            # drift re-sweep trigger: a sustained bucket with no tune
            # entry enqueues a background sweep of that exact cell.
            # Lazy import + disabled fast path keep this a no-op unless
            # REPRO_RESWEEP is on.
            from repro.tune.resweep import get_resweeper
            rs = get_resweeper()
            if rs.enabled:
                rs.observe(eng, bucket, stats)

    @staticmethod
    def _dtype_from_num(num: int):
        """np.dtype for a type number gathered from a pod peer.

        Type numbers are the only dtype spelling that travels through an
        integer all-gather; builtins have stable numbers, and extension
        dtypes (bfloat16) get consistent ones on identical software
        stacks (CI pins the stack)."""
        for name in ("float32", "float64", "float16", "int8", "int16",
                     "int32", "int64", "uint8", "uint16", "uint32",
                     "uint64", "bool_", "complex64", "complex128"):
            dt = np.dtype(getattr(np, name))
            if dt.num == num:
                return dt
        try:
            import ml_dtypes
            dt = np.dtype(ml_dtypes.bfloat16)
            if dt.num == num:
                return dt
        except ImportError:
            pass
        raise ValueError(f"dispatch_pod: unknown dtype num {num} gathered "
                         f"from a pod peer")

    def _slab_layout(self, requests, eng, agreed_num: int = -1):
        """(feature shape, dtype) of one slab row.

        From the local requests when this host has any; an idle host
        derives the feature shape from the engine's bundle spec and the
        dtype from the pod-agreed type number — every process must hand
        ``make_array_from_process_local_data`` the same dtype or the
        global array's avals diverge across the pod."""
        if requests:
            return (tuple(requests[0].x.shape[1:]),
                    np.dtype(requests[0].x.dtype))
        dtype = (self._dtype_from_num(agreed_num) if agreed_num >= 0
                 else np.dtype(np.float32))
        return tuple(eng.spec["in_shape"][1:]), dtype

    def dispatch_pod(self, key: str, requests: List, stats: ServeStats, *,
                     ctx=None, reason: str = "pod") -> None:
        """Serve one cross-host mega-batch (collective).

        Every process in the pod must call this at the same point for the
        same key — it contains collectives.  The hosts agree on a common
        per-host slab via an all-gather of their pending row counts; each
        host assembles its slab (its callers' rows + zero padding, sized
        ``bucket_for(max(counts))`` so slabs match), the slabs form one
        global batch whose leading dim is sharded over ``("pod", "data")``
        (``ShardCtx.make_global``), and after the batched apply each host
        reads back *only its own slab* — which is addressable by
        construction, so no cross-host result gather ever happens.

        A host with nothing pending still participates (zero slab, no
        futures): collectives cannot be skipped unilaterally.  ``ctx``
        overrides the serving ShardCtx for exactly that case — with no
        local requests there is no submit-time ctx to recover.
        """
        from repro.dist.sharding import current_ctx, use_mesh
        from repro.launch import multihost
        t0 = time.monotonic()
        tr = TRACER
        traced = tr.enabled
        bid = next(_BATCH_IDS)
        if ctx is None:
            ctx = requests[0].ctx if requests else current_ctx()
        local_n = sum(r.n for r in requests)
        my_num = int(np.dtype(requests[0].x.dtype).num) if requests else -1
        # pod.agree: the count/dtype all-gather is where a straggling
        # host shows up — every peer's span stretches to the slowest one
        with tr.span("pod.agree", cat="pod",
                     args={"key": key, "batch": bid, "local_rows": local_n}):
            gathered = multihost.allgather_ints([local_n, my_num])
        counts, dtype_nums = gathered[:, 0], gathered[:, 1]
        total = int(counts.sum())
        if total == 0:
            return
        pid, nproc = multihost.process_index(), len(counts)
        try:
            if nproc > 1 and (ctx is None or ctx.mesh is None):
                raise RuntimeError(
                    "dispatch_pod: cross-process serving needs a pod mesh "
                    "— submit under use_mesh(make_pod_mesh(), "
                    "multi_pod=True) or pass ctx=")
            # hosts with rows must agree on the row dtype; idle hosts
            # adopt it so every process assembles the same global aval
            active = {int(c) for c, k in zip(dtype_nums, counts) if k > 0}
            if len(active) > 1:
                raise ValueError(
                    f"dispatch_pod: pod hosts submitted mixed row dtypes "
                    f"for {key!r} (type nums {sorted(active)})")
            eng = self._engine_for(key)
            feat, dtype = self._slab_layout(requests, eng,
                                            next(iter(active), -1))
            local_shards = (ctx.local_axis_size("data")
                            if ctx is not None and ctx.mesh is not None
                            else 1)
            per_slab = bucket_for(int(counts.max()), self.min_bucket,
                                  local_shards)
            bucket = per_slab * nproc
            slab = self.scratch.take((per_slab,) + feat, dtype)
            off = 0
            for r in requests:
                slab[off:off + r.n] = np.asarray(r.x)
                off += r.n
            slab[off:] = 0
            if ctx is not None and ctx.mesh is not None:
                X = ctx.make_global(slab, ("data",) + (None,) * len(feat),
                                    global_shape=(bucket,) + feat)
            else:
                X = jnp.asarray(slab)
            with tr.span("batch.apply", cat="pod",
                         args={"key": key, "batch": bid, "bucket": bucket,
                               "pid": pid, "nproc": nproc,
                               "local_rows": local_n, "total_rows": total}):
                with (use_mesh(ctx.mesh, ctx.multi_pod) if ctx is not None
                      else use_mesh(None)):
                    Y = eng.apply_batched(X, min_bucket=self.min_bucket,
                                          prepadded=True)
                Y = jax.block_until_ready(Y)
            if requests:
                base = pid * per_slab
                with tr.span("batch.to_host", cat="pod",
                             args={"key": key, "batch": bid}):
                    Yh = self._to_host(Y, rows=(base, base + local_n))
        except Exception as e:
            tr.instant("batch.error", cat="pod",
                       args={"key": key, "batch": bid, "error": repr(e)})
            for r in requests:
                r.future.set_exception(e)
            self._note_dropped(requests)
            stats.on_failure(requests=len(requests), rows=local_n,
                             reason=reason, busy_s=time.monotonic() - t0)
            BREAKERS.record_failure(key)
            if nproc > 1:
                # pod-fatal: a host that bails after the count all-gather
                # (bundle read failure, bad dtype, ...) has already
                # diverged from the collective schedule its peers are
                # entering — swallowing the error here would leave them
                # hung in the apply.  Fail loudly so the driver/harness
                # tears the pod down.
                raise
            return
        bad = self._screen_nonfinite(requests, Yh) if requests else ()
        t1 = time.monotonic()
        off = 0
        lats = []
        bad_rows = 0
        rargs = {"key": key, "batch": bid, "reason": reason,
                 "pid": pid, "nproc": nproc} if traced else None
        for i, r in enumerate(requests):
            if i in bad:
                r.future.set_exception(NonFiniteOutput(key, r.n))
                bad_rows += r.n
                off += r.n
                continue
            r.future.set_result(Yh[off:off + r.n])
            off += r.n
            lats.append(t1 - r.t_enqueue)
            if traced:
                tr.rec("serve.request", "serve", r.t_enqueue,
                       time.monotonic(), r.trace, rargs)
        self._note_served(requests, bad, lats)
        if bad:
            _NONFINITE.inc(bad_rows, key=key)
            stats.on_failure(requests=len(bad), rows=bad_rows,
                             reason=reason, busy_s=0.0)
            BREAKERS.record_failure(key)
        else:
            BREAKERS.record_success(key)
        if not requests or len(bad) < len(requests):
            stats.on_batch(requests=len(requests) - len(bad),
                           rows=local_n - bad_rows, bucket=bucket,
                           reason=reason, busy_s=t1 - t0, latencies_s=lats,
                           remote_rows=total - local_n)
