"""ServeQueue: async region serving with mesh-wide coalescing.

Any number of :class:`MLRegion`\\ s submit inference requests (a block of
bridged rows) keyed by their bundle path; each submit returns a
:class:`ServeFuture`.  Pending requests coalesce per key and are
dispatched as one padded mega-batch by the :class:`Batcher` when a flush
triggers:

  * **max-batch** — a key's pending rows reach ``policy.max_batch_rows``;
  * **deadline**  — the oldest pending request ages past
    ``policy.max_delay_s`` (enforced by the dispatcher thread, or by
    :meth:`poll` for thread-free deterministic drivers);
  * **explicit**  — :meth:`flush` drains everything now.

Backpressure: total queued rows are capped at
``policy.max_pending_rows``; ``submit`` blocks until the dispatcher
drains (or raises :class:`Backpressure` with ``policy.block=False`` /
on timeout), so a runaway producer cannot grow the queue unboundedly.

Multi-tenancy (opt-in): construct with ``tenancy=TenantBoard(...)`` and
submit with ``tenant="name"``.  Admission then charges the tenant's
token bucket before enqueue, per-tenant pending caps add a second
backpressure layer under the global one, and — under overload (pending
rows exceed one ``max_batch_rows`` of capacity) — flush order across
keys is picked by deficit-round-robin over tenant weights instead of
FIFO, so one tenant's burst cannot starve another's deadline
(:mod:`repro.serve.tenancy`).

Threading model: all queue state lives behind one condition variable.
Dispatches happen *outside* the lock (in the flusher's thread), so
producers keep enqueueing for other keys while a mega-batch runs.
Without :meth:`start`, the queue is synchronous-deterministic: max-batch
flushes run inline in the submitting thread and ``ServeFuture.result``
flushes the key on demand — no background thread, bit-reproducible
driver loops.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.obs import TRACER
from repro.obs.metrics import note_static_fallback
from repro.serve.batcher import Batcher
from repro.serve.stats import ServeStats


class Backpressure(RuntimeError):
    """The queue is full (policy.max_pending_rows) and cannot admit more."""


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """When to coalesce-and-dispatch, and how much may wait."""

    max_batch_rows: int = 1024        # flush a key at this many pending rows
    max_delay_s: Optional[float] = None   # deadline flush (None: no deadline)
    min_bucket: int = 8               # smallest padded bucket
    max_pending_rows: int = 8192      # backpressure across all keys
    block: bool = True                # submit blocks when full vs raises
    block_timeout_s: float = 30.0     # blocked submit gives up after this


class ServeFuture:
    """Resolves to the engine-output rows ``[n, ...]`` for one request.

    Resolution is first-wins: once set, later ``set_result`` /
    ``set_exception`` calls are dropped.  The pod watchdog relies on
    this — a zombie collective thread that finishes after the watchdog
    already re-dispatched locally cannot overwrite the delivered rows.
    """

    __slots__ = ("_event", "_value", "_exc", "_queue", "_key", "_lock",
                 "trace")

    def __init__(self, queue: "ServeQueue", key: str):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._value = None
        self._exc: Optional[BaseException] = None
        self._queue = queue
        self._key = key
        self.trace: Optional[str] = None  # obs trace id (when tracing)

    def done(self) -> bool:
        return self._event.is_set()

    def set_result(self, value) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._value = value
            self._event.set()
            return True

    def set_exception(self, exc: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._exc = exc
            self._event.set()
            return True

    def result(self, timeout: Optional[float] = None):
        if not self._event.is_set():
            # thread-free queues make progress on demand; threaded queues
            # will resolve us from the dispatcher, so just wait
            self._queue._progress(self._key)
            if not self._event.wait(timeout):
                raise TimeoutError(
                    f"serve request for {self._key!r} not resolved within "
                    f"{timeout}s (queue depth "
                    f"{self._queue.depth(self._key)} rows)")
        if self._exc is not None:
            raise self._exc
        return self._value


class _Request:
    __slots__ = ("key", "x", "n", "future", "t_enqueue", "ctx", "trace",
                 "tenant")

    def __init__(self, key, x, n, future, t_enqueue, ctx, trace=None,
                 tenant=None):
        self.key, self.x, self.n = key, x, n
        self.future, self.t_enqueue = future, t_enqueue
        self.ctx = ctx  # submitter's ShardCtx: sharding is thread-local
        self.trace = trace  # obs trace id, minted at submit, rides along
        self.tenant = tenant  # tenancy id (None on tenancy-free queues)


class _StatsGate:
    """Revocable forwarding proxy for :class:`ServeStats`.

    The pod watchdog hands the collective dispatch this gate instead of
    the real stats object; on timeout it calls :meth:`kill` before
    re-dispatching locally, so the zombie collective thread — should it
    ever finish — cannot double-account the batch it lost.  ``kill()``
    returns False when the dispatch already delivered through the gate,
    in which case the watchdog treats the round as completed instead.
    """

    def __init__(self, stats):
        self._stats = stats
        self._lock = threading.Lock()
        self._dead = False
        self._consumed = False

    def on_batch(self, **kw) -> None:
        with self._lock:
            if self._dead:
                return
            self._consumed = True
        self._stats.on_batch(**kw)

    def on_failure(self, **kw) -> None:
        with self._lock:
            if self._dead:
                return
            self._consumed = True
        self._stats.on_failure(**kw)

    def kill(self) -> bool:
        """Revoke the gate; True when nothing was delivered through it."""
        with self._lock:
            self._dead = True
            return not self._consumed


class ServeQueue:
    def __init__(self, policy: FlushPolicy = FlushPolicy(), *,
                 batcher: Optional[Batcher] = None, controller=None,
                 tenancy=None, latency_window: int = 2048):
        self.policy = policy
        self.controller = controller  # e.g. tune.AdaptiveFlushController
        self.tenancy = tenancy  # repro.serve.tenancy.TenantBoard (or None)
        self.latency_window = int(latency_window)
        self._batcher = batcher or Batcher(min_bucket=policy.min_bucket)
        if tenancy is not None:
            # the batcher attributes per-request outcomes (served rows,
            # latencies, drops) back to tenants; the controller reads
            # per-key QoS tiers for its deadline targets
            self._batcher.tenancy = tenancy
            if controller is not None and \
                    getattr(controller, "tenancy", None) is None:
                controller.tenancy = tenancy
        self._cv = threading.Condition()
        self._pending: Dict[str, List[_Request]] = {}
        self._rows_total = 0
        self._stats: Dict[str, ServeStats] = {}
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._crashed: Optional[BaseException] = None
        self._closed = False

    # ------------------------------------------------- adaptive policy ---
    # An attached controller overrides the static deadline and max-batch
    # trigger per key from observed arrival rates + predicted batch
    # latency; any controller failure degrades to the static policy, so
    # an adaptive queue can never serve *worse* than its FlushPolicy.
    def _delay_for(self, key: str) -> Optional[float]:
        if self.controller is not None:
            try:
                return self.controller.delay_for(key, self._stats.get(key))
            except Exception as exc:
                note_static_fallback(key, "controller-error", repr(exc))
                return self.policy.max_delay_s
        return self.policy.max_delay_s

    def _batch_rows_for(self, key: str) -> int:
        if self.controller is not None:
            try:
                return max(1, int(self.controller.batch_rows_for(
                    key, self._stats.get(key))))
            except Exception as exc:
                note_static_fallback(key, "controller-error", repr(exc))
                return self.policy.max_batch_rows
        return self.policy.max_batch_rows

    def _may_deadline(self) -> bool:
        """Could *any* key ever get a deadline flush from the thread?"""
        return self.policy.max_delay_s is not None or \
            self.controller is not None

    # ------------------------------------------------------------ state ---
    def stats(self, key: str) -> ServeStats:
        with self._cv:
            return self._stat_locked(key)

    def _stat_locked(self, key: str) -> ServeStats:
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = ServeStats(
                key, latency_window=self.latency_window)
        return st

    def depth(self, key: Optional[str] = None) -> int:
        """Pending rows for one key (or across all keys)."""
        with self._cv:
            if key is None:
                return self._rows_total
            return sum(r.n for r in self._pending.get(key, ()))

    def keys(self):
        with self._cv:
            return list(self._pending)

    # -------------------------------------------------------- liveness ---
    def liveness(self) -> Dict[str, object]:
        """Queue liveness for readiness probes (``/healthz``)."""
        with self._cv:
            t = self._thread
            return {
                "mode": "threaded" if t is not None else "thread-free",
                "dispatcher_alive": bool(t is not None and t.is_alive()),
                "stopping": self._stopping,
                "closed": self._closed,
                "crashed": repr(self._crashed) if self._crashed else None,
                "pending_rows": self._rows_total,
                "pending_keys": len(self._pending),
            }

    def healthy(self) -> bool:
        """False when a started dispatcher thread has died (requests
        would queue forever).  Thread-free queues are always healthy —
        callers make their own progress."""
        with self._cv:
            if self._crashed is not None:
                return False
            t = self._thread
            return t is None or (t.is_alive() and not self._stopping)

    def snapshot(self) -> Dict[str, object]:
        """Liveness plus every key's serve-stats snapshot (``/varz``);
        with a tenancy board, the per-tenant occupancy/p99/drop board
        and the weight-residency state ride along."""
        with self._cv:
            stats = dict(self._stats)
        snap = {"liveness": self.liveness(),
                "keys": {k: s.snapshot() for k, s in sorted(stats.items())}}
        if self.tenancy is not None:
            snap["tenants"] = self.tenancy.snapshot()
            from repro.serve.residency import RESIDENCY
            snap["residency"] = RESIDENCY.snapshot()
        return snap

    def tenant_offenders(self) -> List[str]:
        """Tenant ids misbehaving now (dropping rows / stuck past their
        pending cap) — ``/healthz`` names them ``tenant:<id>``."""
        if self.tenancy is None:
            return []
        return self.tenancy.offenders()

    # ----------------------------------------------------------- submit ---
    def submit(self, key: str, rows, *,
               tenant: Optional[str] = None) -> ServeFuture:
        """Queue ``rows`` ([n, ...features], n >= 1) for bundle ``key``.

        With a tenancy board attached, ``tenant`` names the submitting
        tenant (default tenant otherwise): admission charges its token
        bucket *before* enqueue — an empty bucket blocks for refill
        (``policy.block``) or raises
        :class:`repro.serve.tenancy.TenantThrottled` — and the tenant's
        pending-row cap backpressures under the global one.
        """
        from repro.dist.sharding import current_ctx
        board = self.tenancy
        if board is not None:
            from repro.serve.tenancy import DEFAULT_TENANT
            tenant = tenant or DEFAULT_TENANT
        x = jnp.asarray(rows)
        if x.ndim < 1 or x.shape[0] < 1:
            raise ValueError(f"submit needs [n, ...] rows, got {x.shape}")
        n = int(x.shape[0])
        if board is not None:
            # token-bucket admission happens at the door, outside every
            # lock: refill is wall-clock, so a blocked submit sleeps in
            # the board rather than waiting on the queue's condvar
            board.admit(tenant, n, block=self.policy.block,
                        timeout_s=self.policy.block_timeout_s)
        fut = ServeFuture(self, key)
        t_sub = time.monotonic()
        trace = TRACER.new_trace_id() if TRACER.enabled else None
        fut.trace = trace  # shadow scoring rides the same id
        req = _Request(key, x, n, fut, t_sub, current_ctx(), trace, tenant)
        deadline = t_sub + self.policy.block_timeout_s
        while True:
            admitted, drain_inline, flush_inline = False, False, False
            with self._cv:
                self._check_open_locked()
                pend = self._pending.get(key)
                if pend and pend[0].x.shape[1:] != x.shape[1:]:
                    raise ValueError(
                        f"feature-shape mismatch for {key!r}: queued "
                        f"{pend[0].x.shape[1:]}, submitted {x.shape[1:]}")
                # backpressure: an oversized request is admitted alone into
                # an empty queue (flushing as its own batch: no deadlock);
                # the tenant's own pending cap applies under the global one
                if self._admit_locked(n) and (
                        board is None or board.has_room(tenant, n)):
                    admitted = True
                    self._pending.setdefault(key, []).append(req)
                    self._rows_total += n
                    self._stat_locked(key).on_enqueue(n)
                    if sum(r.n for r in self._pending[key]) >= \
                            self._batch_rows_for(key):
                        if self._thread is not None:
                            self._cv.notify_all()
                        else:
                            flush_inline = True
                    elif self._thread is not None and self._may_deadline():
                        self._cv.notify_all()  # recompute thread deadline
                elif not self.policy.block:
                    raise Backpressure(
                        f"{self._rows_total}+{n} rows exceeds "
                        f"max_pending_rows={self.policy.max_pending_rows}")
                elif self._thread is not None:
                    # a dispatcher will drain; wait for it to make space
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cv.wait(timeout=left):
                        raise Backpressure(
                            f"submit blocked >{self.policy.block_timeout_s}s "
                            f"({self._rows_total} rows pending)")
                else:
                    # thread-free queue: nobody else can flush, so the
                    # submitting thread must make space itself
                    drain_inline = True
            if admitted:
                if board is not None:
                    board.on_enqueue(tenant, key, n)
                if trace is not None:
                    # submitter-thread span: admission (incl. any time
                    # blocked on backpressure).  The dispatcher's
                    # serve.request span starts at t_enqueue, so together
                    # the request's spans tile enqueue -> resolve gap-free.
                    TRACER.rec("queue.submit", "queue", t_sub,
                               time.monotonic(), trace,
                               {"key": key, "rows": n})
                if flush_inline:
                    self.flush(key, reason="max_batch")
                return fut
            if drain_inline:
                if self.flush(reason="backpressure") == 0 or \
                        time.monotonic() > deadline:
                    raise Backpressure(
                        f"queue full ({self._rows_total} rows) and inline "
                        f"drain freed nothing")

    def _admit_locked(self, n: int) -> bool:
        if self._rows_total == 0:
            return True
        return self._rows_total + n <= self.policy.max_pending_rows

    def _check_open_locked(self) -> None:
        if self._closed:
            raise RuntimeError("submit on a closed ServeQueue")
        if self._crashed is not None:
            raise RuntimeError(
                f"serve dispatcher thread died: {self._crashed!r}"
            ) from self._crashed

    # ------------------------------------------------------------ flush ---
    def flush(self, key: Optional[str] = None, *,
              reason: str = "explicit") -> int:
        """Dispatch everything pending for ``key`` (or all keys) now.

        Returns the number of rows dispatched.  Runs in the caller's
        thread; the queue lock is *not* held during the batched apply,
        so concurrent submits proceed.
        """
        dispatched = 0
        keys = [key] if key is not None else self._flush_order()
        for k in keys:
            with self._cv:
                reqs = self._pending.pop(k, [])
                rows = sum(r.n for r in reqs)
                self._rows_total -= rows
                st = self._stat_locked(k)
                if rows:
                    self._cv.notify_all()  # wake backpressured submitters
            if reqs:
                self._note_dispatch(reqs)
                self._batcher.dispatch(k, reqs, st, reason)
                dispatched += rows
        return dispatched

    def _flush_order(self) -> List[str]:
        """Key order for an all-keys flush: FIFO insertion order, unless
        a tenancy board is attached and the queue is overloaded (more
        pending rows than one max-batch of capacity) — then deficit-
        round-robin over tenant weights picks who drains first."""
        with self._cv:
            if self.tenancy is None or len(self._pending) < 2 or \
                    self._rows_total <= self.policy.max_batch_rows:
                return list(self._pending)
            pairs = [(k, sum(r.n for r in reqs))
                     for k, reqs in self._pending.items()]
        try:
            return self.tenancy.order_keys(pairs)
        except Exception as exc:
            note_static_fallback("tenancy", "drr-error", repr(exc))
            return [k for k, _ in pairs]

    def _note_dispatch(self, reqs: List) -> None:
        """Tenant accounting for rows leaving the queue (any reason)."""
        if self.tenancy is None:
            return
        agg: Dict[str, int] = {}
        for r in reqs:
            t = getattr(r, "tenant", None)
            if t is not None:
                agg[t] = agg.get(t, 0) + r.n
        for t, rows in agg.items():
            self.tenancy.on_dispatch(t, rows)

    def pod_flush(self, key: Optional[str] = None, *, ctx=None) -> int:
        """Collective flush: this host's pending rows join one cross-host
        mega-batch with every other pod process's rows for ``key``.

        SPMD contract — every process in the pod must call ``pod_flush``
        at the same point with the same key sequence (with ``key=None``,
        all hosts must hold the same key set; keys dispatch in sorted
        order so the collective schedules line up).  A host with nothing
        pending still participates with a zero slab.  Returns the number
        of *local* rows dispatched.

        Only thread-free queues may pod-flush: a per-host dispatcher
        thread firing on its own clock would run the collectives in
        different orders on different hosts and deadlock the pod.
        ``ctx`` pins the serving ShardCtx for hosts with no pending
        requests (otherwise the first request's submit-time ctx governs,
        as in ordinary dispatch).

        Dropout tolerance (multi-process only): each flush round writes
        a heartbeat through the coordinator KV store and runs the
        collective under a watchdog (``REPRO_POD_WATCHDOG_S``).  If the
        collective stalls past the timeout — a peer dropped or hung —
        the survivors mark the pod degraded (healthz names the offending
        ``pod:host-<k>``), abandon the collective to a zombie daemon
        thread, and re-dispatch their local rows through the ordinary
        single-host path, so no request is lost and no host deadlocks.
        The degrade *decision* lands within the watchdog; the re-dispatched
        batch itself may still execute only once the torn collective
        releases the devices (backends with FIFO per-device streams, e.g.
        XLA CPU, pin them until the transport's own peer timeout) — drain
        is transport-bound, loss-freedom is not.  While degraded, flushes
        stay local-only until ``POD_HEALTH.try_rejoin`` clears.
        """
        from repro.launch import multihost
        from repro.resilience.faults import FAULTS
        with self._cv:
            if self._thread is not None:
                raise RuntimeError(
                    "pod_flush on a started queue: cross-host flushes are "
                    "collective and must run from the driver loop, not a "
                    "per-host dispatcher thread (use a thread-free queue)")
            keys = [key] if key is not None else sorted(self._pending)
        if FAULTS.enabled:
            # fires before the heartbeat on purpose: a dropped host must
            # look dropped — it never writes this round's beat
            FAULTS.fire("pod.flush", key=key)
        multi = multihost.is_multiprocess()
        if key is None and multi and not multihost.POD_HEALTH.degraded:
            # cross-host key agreement: each host flushes the *union* of
            # everyone's pending key sets, not just its own — hosts with
            # disjoint keys would otherwise run different collective
            # sequences and deadlock the pod.  A host missing a key
            # participates with a zero slab, as the SPMD contract allows.
            keys = self._agree_pod_keys(keys)
        dispatched = 0
        for k in keys:
            with self._cv:
                reqs = self._pending.pop(k, [])
                rows = sum(r.n for r in reqs)
                self._rows_total -= rows
                st = self._stat_locked(k)
                if rows:
                    self._cv.notify_all()  # wake backpressured submitters
            self._note_dispatch(reqs)
            if not multi:
                # single process: the collective is trivially local and
                # cannot stall on a peer — no watchdog overhead
                self._batcher.dispatch_pod(k, reqs, st, ctx=ctx)
            elif multihost.POD_HEALTH.degraded:
                # survivors serve local-only: entering a collective with
                # a dead peer would hang again
                if reqs:
                    self._dispatch_local_degraded(k, reqs, st)
            else:
                # always dispatch — a zero-row host still owes the pod
                # its collectives (dispatch_pod returns early only when
                # *every* host is empty)
                self._dispatch_pod_guarded(k, reqs, st, ctx)
            dispatched += rows
        return dispatched

    def _agree_pod_keys(self, local: List[str]) -> List[str]:
        """All-gather every host's pending key set; return the sorted
        union (collective — all hosts must call this together, which
        ``pod_flush(None)``'s SPMD contract already guarantees).

        Runs under the pod watchdog like any other collective: if a peer
        dropped before the gather, the survivors degrade the pod and
        fall back to their local key list (whose requests the caller
        then serves through the degraded local-only path).
        """
        import json
        from repro.launch import multihost
        health = multihost.POD_HEALTH
        round_id = health.beat()
        box: Dict[str, object] = {}
        done = threading.Event()

        def run():
            try:
                box["got"] = multihost.allgather_bytes(
                    json.dumps(sorted(local)).encode())
            except BaseException as e:
                box["exc"] = e
            finally:
                done.set()

        t = threading.Thread(target=run, daemon=True,
                             name="repro-pod-key-agree")
        t.start()
        if done.wait(timeout=multihost.pod_watchdog_s()):
            exc = box.get("exc")
            if exc is not None:
                raise exc  # transport failure is pod-fatal, same as dispatch
            agreed = set()
            for blob in box["got"]:
                agreed.update(json.loads(bytes(blob).decode()))
            return sorted(agreed)
        offenders = health.check_round(round_id)
        health.mark_degraded(offenders)
        TRACER.instant("pod.watchdog", cat="pod",
                       args={"phase": "key_agreement", "round": round_id,
                             "offenders": list(offenders)})
        return sorted(local)

    def _dispatch_pod_guarded(self, k: str, reqs: List, st, ctx) -> None:
        """Run one collective dispatch under the pod watchdog."""
        from repro.launch import multihost
        health = multihost.POD_HEALTH
        round_id = health.beat()
        gate = _StatsGate(st)
        box: Dict[str, BaseException] = {}
        done = threading.Event()

        def run():
            try:
                self._batcher.dispatch_pod(k, reqs, gate, ctx=ctx)
            except BaseException as e:
                box["exc"] = e
            finally:
                done.set()

        t = threading.Thread(target=run, daemon=True,
                             name="repro-pod-dispatch")
        t.start()
        if done.wait(timeout=multihost.pod_watchdog_s()):
            exc = box.get("exc")
            if exc is not None:
                raise exc  # pod-fatal contract preserved
            return
        # watchdog fired.  kill() returning False means the collective
        # delivered in the race window between timeout and now — take it.
        if not gate.kill():
            return
        offenders = health.check_round(round_id)
        health.mark_degraded(offenders)
        TRACER.instant("pod.watchdog", cat="pod",
                       args={"key": k, "round": round_id,
                             "offenders": list(offenders)})
        if reqs:
            # zero-lost: the abandoned collective can no longer win —
            # first-wins futures drop anything the zombie produces late
            self._dispatch_local_degraded(k, reqs, st)

    def _dispatch_local_degraded(self, k: str, reqs: List, st) -> None:
        """Serve pod-submitted requests through the single-host path.

        Their submit-time ShardCtx names the (now torn) pod mesh, whose
        remote devices a local dispatch cannot place onto — strip it so
        the batch serves meshless-eager; row-wise surrogates make the
        results bit-identical either way.
        """
        for r in reqs:
            r.ctx = None
        self._batcher.dispatch(k, reqs, st, reason="pod_degraded")

    def poll(self) -> int:
        """Flush keys whose max-batch/deadline triggers fired (no thread).

        Driver loops that own their own cadence call this instead of
        running a dispatcher thread: same flush decisions, caller's
        thread, deterministic timing.
        """
        dispatched = 0
        for k, why in self._due():
            dispatched += self.flush(k, reason=why)
        return dispatched

    def _due(self):
        with self._cv:
            return self._due_locked()

    def _progress(self, key: str) -> None:
        """Called by a waiting future: flush on demand unless a dispatcher
        thread with a deadline for this key is guaranteed to resolve us.
        (A cold controller over a deadline-free static policy returns
        None — the future must make its own progress, same as no
        controller at all.)"""
        if self._thread is None or self._delay_for(key) is None:
            self.flush(key, reason="demand")

    # ------------------------------------------------------- dispatcher ---
    def start(self) -> "ServeQueue":
        """Run a daemon dispatcher thread enforcing size + deadline flushes."""
        with self._cv:
            if self._thread is not None:
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name="repro-serve-dispatch")
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        with self._cv:
            t = self._thread
            self._stopping = True
            self._cv.notify_all()
        if t is not None:
            t.join()
        with self._cv:
            self._thread = None
        if drain:
            self.flush(reason="drain")

    def _run(self) -> None:
        try:
            while True:
                with self._cv:
                    if self._stopping:
                        return
                    due = self._due_locked()
                    if not due:
                        self._cv.wait(timeout=self._nearest_deadline())
                        continue
                for k, why in due:
                    self.flush(k, reason=why)
        except BaseException as e:
            # a dying dispatcher must not leave submitters hanging to
            # block_timeout_s: fail every pending future now, mark the
            # queue crashed (healthz flips, new submits refuse), then
            # re-raise so the crash traceback still reaches stderr
            self._on_dispatcher_crash(e)
            raise

    def _on_dispatcher_crash(self, exc: BaseException) -> None:
        with self._cv:
            self._crashed = exc
            pending, self._pending = self._pending, {}
            self._rows_total = 0
            stats = {k: self._stat_locked(k) for k in pending}
            self._cv.notify_all()  # unblock backpressured submitters
        err = RuntimeError(f"serve dispatcher thread died: {exc!r}")
        err.__cause__ = exc
        TRACER.instant("queue.crash", cat="queue",
                       args={"error": repr(exc)})
        for k, reqs in pending.items():
            self._note_failed(reqs)
            for r in reqs:
                r.future.set_exception(err)
            stats[k].on_failure(requests=len(reqs),
                                rows=sum(r.n for r in reqs),
                                reason="dispatcher_crash", busy_s=0.0)

    def _note_failed(self, reqs: List) -> None:
        """Tenant accounting for requests failed without a dispatch
        (dispatcher crash, drain-free close)."""
        self._note_dispatch(reqs)
        if self.tenancy is None:
            return
        agg: Dict[str, list] = {}
        for r in reqs:
            t = getattr(r, "tenant", None)
            if t is not None:
                c = agg.setdefault(t, [0, 0])
                c[0] += 1
                c[1] += r.n
        for t, (n_req, n_rows) in agg.items():
            self.tenancy.on_dropped(t, n_req, n_rows)

    # ------------------------------------------------------------ close ---
    def close(self, drain: bool = True, *, timeout: float = 30.0) -> None:
        """Orderly shutdown for interpreter teardown / atexit.

        Refuses new submits from this point on, stops the dispatcher
        thread, drains (``drain=True``) or fails (``drain=False``) the
        remaining pending batches, and then stops the shadow-scorer
        worker — in that order, so teardown can never race a mid-replay
        scorer against a dying queue.  Idempotent.
        """
        with self._cv:
            if self._closed:
                return
            self._closed = True
        if self._thread is not None:
            self.stop(drain=drain)
        elif drain:
            self.flush(reason="close")
        if not drain:
            with self._cv:
                pending, self._pending = self._pending, {}
                self._rows_total = 0
                stats = {k: self._stat_locked(k) for k in pending}
                self._cv.notify_all()
            err = RuntimeError("ServeQueue closed before dispatch")
            for k, reqs in pending.items():
                self._note_failed(reqs)
                for r in reqs:
                    r.future.set_exception(err)
                stats[k].on_failure(requests=len(reqs),
                                    rows=sum(r.n for r in reqs),
                                    reason="close", busy_s=0.0)
        from repro.obs.quality import SHADOW
        SHADOW.close(drain=drain, timeout=timeout)

    def _due_locked(self):
        now = time.monotonic()
        due = []
        for k, reqs in self._pending.items():
            if not reqs:
                continue
            delay = self._delay_for(k)
            if sum(r.n for r in reqs) >= self._batch_rows_for(k):
                due.append((k, "max_batch"))
            elif delay is not None and \
                    now - reqs[0].t_enqueue >= delay:
                due.append((k, "deadline"))
        return self._order_due_locked(due)

    def _order_due_locked(self, due):
        """Under overload with a tenancy board, due keys flush in DRR
        order (weighted fair share) instead of dict insertion order."""
        if self.tenancy is None or len(due) < 2 or \
                self._rows_total <= self.policy.max_batch_rows:
            return due
        try:
            pairs = [(k, sum(r.n for r in self._pending.get(k, ())))
                     for k, _ in due]
            order = {k: i for i, k in
                     enumerate(self.tenancy.order_keys(pairs))}
            return sorted(due, key=lambda kw: order.get(kw[0], len(order)))
        except Exception as exc:
            note_static_fallback("tenancy", "drr-error", repr(exc))
            return due

    def _nearest_deadline(self) -> Optional[float]:
        if not self._may_deadline():
            return None
        now = time.monotonic()
        waits = []
        for k, reqs in self._pending.items():
            if not reqs:
                continue
            delay = self._delay_for(k)
            if delay is not None:
                waits.append(delay - (now - reqs[0].t_enqueue))
        if not waits:
            return None
        return max(1e-4, min(waits))

    # -------------------------------------------------- context manager ---
    def __enter__(self) -> "ServeQueue":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)
