"""Pallas TPU flash attention with an int8 KV/score path.

Decode-regime attention is KV-read-bound: each query block streams the
whole KV cache from HBM.  This variant stores K and V as int8 (plus
per-token K scales and per-channel V scales — a quarter of the f32 KV
bytes on the bandwidth-bound axis) and computes the score dot on the
MXU as int8 x int8 -> int32:

  * **q** is quantized per row *inside the kernel* (absmax/127 row
    scales): the score dot contracts over head_dim, so the row scale
    commutes out exactly — ``s = (qq @ kq.T) * (qs * scale) * ks.T``;
  * **k** is quantized per token (scale constant over head_dim, the
    contraction axis of the score dot);
  * softmax and the p@v dot stay f32: V dequantizes in VMEM right
    before the accumulate.  Quantizing p would couple its rounding to
    the online-softmax block structure (the running max differs per
    block_kv choice), making candidates incomparable against a
    block-independent oracle; dequantizing V locally keeps the HBM
    savings — V still *travels* as int8 — while the oracle stays exact.

The declared tolerance mirrors ``fused_mlp_int8``'s rationale: kernel
and int8-simulating oracle agree except where a q value rounds to a
different int8 step between the two paths' f32 orders — one step of a
unit-scale row, not f32 epsilon.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import registry
from repro.kernels.flash_attention.flash_attention import NEG_INF

QMAX = 127.0

_BLOCK_LADDER = (16, 32, 64, 128, 256)
_DEFAULT_BLOCK = 128

TOL = (2e-2, 2e-2)


def _kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref, *, block_k,
            causal, q_offset, kv_valid, scale):
    bq, hd = q_ref.shape[1], q_ref.shape[3]
    skv = kq_ref.shape[1]
    q = q_ref[0, :, 0, :].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(q), axis=1, keepdims=True)
    qs = jnp.where(absmax > 0, absmax, 1.0) / QMAX
    qq = jnp.round(q / qs).astype(jnp.int8)
    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0) \
        + q_offset
    vs = vs_ref[0, 0, 0, :]  # per-channel V scales [hd]

    nk = skv // block_k

    def body(ki, carry):
        acc, m, l = carry
        kq = kq_ref[0, pl.dslice(ki * block_k, block_k), 0, :]
        ks = ks_ref[0, pl.dslice(ki * block_k, block_k), 0, 0]
        vq = vq_ref[0, pl.dslice(ki * block_k, block_k), 0, :]
        s32 = jnp.dot(qq, kq.T, preferred_element_type=jnp.int32)
        # rank-1 dequant: row scale x token scale, with 1/sqrt(hd) folded
        s = s32.astype(jnp.float32) * (qs * scale) * ks[None, :]
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = k_pos < kv_valid
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1, keepdims=True)
        # V dequantizes in VMEM: it crossed HBM as int8, compute is f32
        v = vq.astype(jnp.float32) * vs[None, :]
        acc_new = acc * corr + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((bq, hd), jnp.float32)
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc, m, l))
    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_int8(q, kq, ks, vq, vs, *, causal=True, block_q=128,
                         block_k=128, q_offset=0, kv_valid_len=None,
                         interpret=True):
    """q: [B, Sq, H, hd] float; kq/vq: int8 [B, Skv, KV, hd];
    ks: f32 [B, Skv, KV, 1] per-token; vs: f32 [B, 1, KV, hd]
    per-channel (see :func:`repro.quant.quantize.quantize_kv`)."""
    B, Sq, H, hd = q.shape
    Skv, KV = kq.shape[1], kq.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)
    pq = -Sq % block_q
    pk = -Skv % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kqp = jnp.pad(kq, ((0, 0), (0, pk), (0, 0), (0, 0)))
    ksp = jnp.pad(ks, ((0, 0), (0, pk), (0, 0), (0, 0)),
                  constant_values=1.0)
    vqp = jnp.pad(vq, ((0, 0), (0, pk), (0, 0), (0, 0)))
    valid = Skv if kv_valid_len is None else kv_valid_len

    grid = (B, H, (Sq + pq) // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, causal=causal,
                          q_offset=q_offset, kv_valid=valid, scale=scale),
        out_shape=jax.ShapeDtypeStruct((B, Sq + pq, H, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, Skv + pk, 1, hd),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
            pl.BlockSpec((1, Skv + pk, 1, 1),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
            pl.BlockSpec((1, Skv + pk, 1, hd),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i: (b, i, h, 0)),
        interpret=interpret,
    )(qp, kqp, ksp, vqp, vs)
    return out[:, :Sq]


def flash_attention_int8_ref(q, kq, ks, vq, vs, *, causal=True,
                             q_offset=0):
    """int8-simulating naive-softmax oracle: identical quantization
    decisions (q per row, K/V pre-quantized), materialized scores.
    Block-structure independent — any (block_q, block_kv) candidate
    must match it."""
    B, Sq, H, hd = q.shape
    Skv, KV = kq.shape[1], kq.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)
    qf = jnp.asarray(q, jnp.float32)
    absmax = jnp.max(jnp.abs(qf), axis=-1, keepdims=True)
    qs = jnp.where(absmax > 0, absmax, 1.0) / QMAX
    qq = jnp.round(qf / qs).astype(jnp.int8)
    # expand GQA heads: kv head h // group serves q head h
    kqe = jnp.repeat(kq, group, axis=2)
    kse = jnp.repeat(ks, group, axis=2)
    vqe = jnp.repeat(vq, group, axis=2)
    vse = jnp.repeat(vs, group, axis=2)
    s32 = jnp.einsum("bqhd,bkhd->bhqk", qq, kqe,
                     preferred_element_type=jnp.int32)
    s = (s32.astype(jnp.float32)
         * jnp.transpose(qs * scale, (0, 2, 1, 3))  # [B,H,Sq,1]
         * jnp.transpose(kse, (0, 2, 3, 1)))        # [B,H,1,Skv]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        q_pos = jnp.arange(Sq)[:, None] + q_offset
        mask = k_pos <= q_pos
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    v = vqe.astype(jnp.float32) * vse  # [B,Skv,H,hd]
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return o.astype(q.dtype)


# ----------------------------------------------------------- KernelSpec ----
def _inspect(q, kq, ks, vq, vs, *, causal=True, q_offset=0):
    B, Sq, H, hd = q.shape
    problem = {"b": int(B), "sq": int(Sq), "skv": int(kq.shape[1]),
               "h": int(H), "kv": int(kq.shape[2]), "hd": int(hd),
               "causal": bool(causal), "q_offset": int(q_offset),
               "dtype": str(np.dtype(q.dtype))}
    return problem, (q, kq, ks, vq, vs)


def _run(problem, arrays, params, *, interpret):
    q, kq, ks, vq, vs = arrays
    return flash_attention_int8(q, kq, ks, vq, vs,
                                causal=problem["causal"],
                                q_offset=problem["q_offset"],
                                block_q=params["block_q"],
                                block_k=params["block_kv"],
                                interpret=interpret)


def _ref(problem, arrays):
    q, kq, ks, vq, vs = arrays
    return flash_attention_int8_ref(q, kq, ks, vq, vs,
                                    causal=problem["causal"],
                                    q_offset=problem["q_offset"])


def _make(problem, rng):
    from repro.quant.quantize import quantize_kv

    def t(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32),
                           problem["dtype"])
    q = t(problem["b"], problem["sq"], problem["h"], problem["hd"])
    k = t(problem["b"], problem["skv"], problem["kv"], problem["hd"])
    v = t(problem["b"], problem["skv"], problem["kv"], problem["hd"])
    kq, ks, vq, vs = quantize_kv(k, v)
    return (q, kq, ks, vq, vs)


def _key(problem, backend):
    p = problem
    shape = (f"b{p['b']}-sq{p['sq']}-skv{p['skv']}-h{p['h']}-kv{p['kv']}-"
             f"hd{p['hd']}-c{int(p['causal'])}")
    return f"{shape}|{p['dtype']}|{backend}"


def _fits(problem, params, budget=None):
    """Per-operand VMEM pricing: the q block and f32 scratch at the
    activation dtype, K/V resident as *int8* tiles plus their f32 scale
    strips — the whole point of the variant's cost model."""
    if budget is None:
        budget = registry.device_vmem_budget()
    bq, bk = params["block_q"], params["block_kv"]
    hd = problem["hd"]
    act = np.dtype(problem["dtype"]).itemsize
    skv_p = registry.round_up(problem["skv"], bk)
    t = registry.tile_bytes
    resident = (2 * t(bq, hd, act)          # q block, double-buffered
                + 2 * 2 * t(skv_p, hd, 1)   # int8 K and V, double-buffered
                + 2 * t(skv_p, 1, 4)        # K token scales
                + 2 * t(1, hd, 4)           # V channel scales
                + t(bq, hd, 1)              # qq scratch
                + t(bq, bk, 4)              # f32 score block
                + t(bk, hd, 4)              # dequantized V chunk
                + t(bq, hd, 4)              # acc
                + 2 * t(bq, 1, 4)           # m, l
                + 2 * t(bq, hd, act))       # out block, double-buffered
    return resident <= budget


def _cands(problem):
    clip = {"block_q": registry.round_up(problem["sq"], 16),
            "block_kv": registry.round_up(problem["skv"], 16)}
    return registry.ladder_candidates(
        SPEC.params, clip, fits=lambda c: _fits(problem, c))


SPEC = registry.register(registry.KernelSpec(
    name="flash_attention_int8",
    params=(registry.TunableParam("block_q", _DEFAULT_BLOCK, _BLOCK_LADDER),
            registry.TunableParam("block_kv", _DEFAULT_BLOCK,
                                  _BLOCK_LADDER)),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, candidates=_cands, fits=_fits,
    tol=TOL, tier="int8",
    default_problems=(
        # the decode regime the int8 KV path exists for: short q block
        # against a long quantized cache
        {"b": 4, "sq": 32, "skv": 512, "h": 8, "kv": 2, "hd": 64,
         "causal": True, "q_offset": 480, "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "force_kernel", "block_q",
                                             "block_kv"))
def flash_attention_int8_op(q, kq, ks, vq, vs, *, causal=True, q_offset=0,
                            force_kernel=False, block_q=None,
                            block_kv=None):
    """Attention over a pre-quantized KV cache (see
    :func:`repro.quant.quantize.quantize_kv` for the layout)."""
    problem, arrays = _inspect(q, kq, ks, vq, vs, causal=causal,
                               q_offset=q_offset)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel,
                             overrides={"block_q": block_q,
                                        "block_kv": block_kv})
