"""Naive softmax oracle for flash_attention (GQA via kv repeat)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0,
                        kv_valid_len=None):
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    group = H // KV
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (hd ** 0.5)
    k_pos = jnp.arange(Skv)
    valid = Skv if kv_valid_len is None else kv_valid_len
    mask = k_pos[None, :] < valid
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    else:
        mask = jnp.broadcast_to(mask, (Sq, Skv))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
