"""Pallas TPU flash attention (online softmax, GQA-native).

Grid: (batch, q_heads, Sq / block_q).  Each program holds one q block
[block_q, hd] in VMEM plus its kv head's full K/V [Skv, hd] (the
BlockSpec index map selects kv head q_head // group — GQA without
materializing repeated KV, unlike the portable jnp path).  The kv loop is
a `fori_loop` over block_k chunks with running (max, denom, acc) carried
in VMEM — scores never exist at [Sq, Skv] size.

Causal masking uses absolute positions (q_offset supports decode windows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, q_offset,
            kv_valid, scale):
    bq, hd = q_ref.shape[1], q_ref.shape[3]
    skv = k_ref.shape[1]
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
    qi = pl.program_id(2)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0) + q_offset

    nk = skv // block_k

    def body(ki, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(ki * block_k, block_k), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ki * block_k, block_k), 0, :].astype(jnp.float32)
        s = q @ k.T  # [bq, block_k]
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = k_pos < kv_valid
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1, keepdims=True)
        acc_new = acc * corr + p @ v
        return acc_new, m_new, l_new

    acc = jnp.zeros((bq, hd), jnp.float32)
    m = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc, m, l))
    o_ref[0, :, 0, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128,
                    q_offset=0, kv_valid_len=None, interpret=True):
    """q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] with H % KV == 0."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    group = H // KV
    scale = 1.0 / (hd ** 0.5)
    pq = -Sq % block_q
    pk = -Skv % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    valid = Skv if kv_valid_len is None else kv_valid_len

    grid = (B, H, (Sq + pq) // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, causal=causal,
                          q_offset=q_offset, kv_valid=valid, scale=scale),
        out_shape=jax.ShapeDtypeStruct((B, Sq + pq, H, hd), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, Skv + pk, 1, hd),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
            pl.BlockSpec((1, Skv + pk, 1, hd),
                         lambda b, h, i, g=group: (b, 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i: (b, i, h, 0)),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
