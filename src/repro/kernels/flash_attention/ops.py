"""jit'd wrapper: Pallas flash kernel on TPU, oracle elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "force_kernel"))
def flash_attention_op(q, k, v, *, causal=True, q_offset=0,
                       force_kernel=False):
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               interpret=not on_tpu)
    return flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset)
