"""Registry shim + spec for the Pallas flash-attention kernel.

Tunables: ``block_q`` (query rows per grid step) and ``block_kv`` (the
kv-loop chunk).  Validation tolerance is declared rather than bit-exact:
the online-softmax rescaling order changes with the block structure, so
two block_kv choices legitimately round differently — candidates must
match the naive-softmax oracle to f32 tolerance instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

_BLOCK_LADDER = (16, 32, 64, 128, 256)
_DEFAULT_BLOCK = 128


# ----------------------------------------------------------- KernelSpec ----
def _inspect(q, k, v, *, causal=True, q_offset=0):
    B, Sq, H, hd = q.shape
    problem = {"b": int(B), "sq": int(Sq), "skv": int(k.shape[1]),
               "h": int(H), "kv": int(k.shape[2]), "hd": int(hd),
               "causal": bool(causal), "q_offset": int(q_offset),
               "dtype": str(np.dtype(q.dtype))}
    return problem, (q, k, v)


def _run(problem, arrays, params, *, interpret):
    q, k, v = arrays
    return flash_attention(q, k, v, causal=problem["causal"],
                           q_offset=problem["q_offset"],
                           block_q=params["block_q"],
                           block_k=params["block_kv"], interpret=interpret)


def _ref(problem, arrays):
    q, k, v = arrays
    return flash_attention_ref(q, k, v, causal=problem["causal"],
                               q_offset=problem["q_offset"])


def _make(problem, rng):
    def t(*shape):
        return jnp.asarray(rng.normal(size=shape).astype(np.float32),
                           problem["dtype"])
    q = t(problem["b"], problem["sq"], problem["h"], problem["hd"])
    k = t(problem["b"], problem["skv"], problem["kv"], problem["hd"])
    v = t(problem["b"], problem["skv"], problem["kv"], problem["hd"])
    return (q, k, v)


def _key(problem, backend):
    p = problem
    shape = (f"b{p['b']}-sq{p['sq']}-skv{p['skv']}-h{p['h']}-kv{p['kv']}-"
             f"hd{p['hd']}-c{int(p['causal'])}")
    return f"{shape}|{p['dtype']}|{backend}"


def _fits(problem, params, budget=None):
    """One grid step holds a q block, the kv head's full (padded) K/V,
    the score block, and the running (acc, m, l).

    Streamed operands (q, K, V, out) are priced at the problem's own
    dtype width — a bf16 cache packs twice the K/V rows of an f32 one —
    while the softmax scratch (scores, acc, m, l) is always computed and
    held in f32, whatever the input dtype.
    """
    if budget is None:
        budget = registry.device_vmem_budget()
    bq, bk = params["block_q"], params["block_kv"]
    hd = problem["hd"]
    db = np.dtype(problem["dtype"]).itemsize
    skv_p = registry.round_up(problem["skv"], bk)
    t = registry.tile_bytes
    resident = (2 * t(bq, hd, db)            # q block, double-buffered
                + 2 * 2 * t(skv_p, hd, db)   # K and V, double-buffered
                + t(bq, bk, 4)               # f32 score block
                + t(bq, hd, 4)               # f32 acc
                + 2 * t(bq, 1, 4)            # m, l (lane-padded)
                + 2 * t(bq, hd, db))         # out block, double-buffered
    return resident <= budget


def _cands(problem):
    clip = {"block_q": registry.round_up(problem["sq"], 16),
            "block_kv": registry.round_up(problem["skv"], 16)}
    return registry.ladder_candidates(
        SPEC.params, clip, fits=lambda c: _fits(problem, c))


SPEC = registry.register(registry.KernelSpec(
    name="flash_attention",
    params=(registry.TunableParam("block_q", _DEFAULT_BLOCK, _BLOCK_LADDER),
            registry.TunableParam("block_kv", _DEFAULT_BLOCK, _BLOCK_LADDER)),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, candidates=_cands, fits=_fits,
    tol=(2e-5, 2e-5),
    default_problems=(
        # prefill-shaped: square causal attention, GQA group of 4
        {"b": 1, "sq": 256, "skv": 256, "h": 8, "kv": 2, "hd": 64,
         "causal": True, "q_offset": 0, "dtype": "float32"},
        # decode-window-shaped: short q against a long kv
        {"b": 4, "sq": 32, "skv": 512, "h": 8, "kv": 2, "hd": 64,
         "causal": True, "q_offset": 480, "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "force_kernel", "block_q",
                                             "block_kv"))
def flash_attention_op(q, k, v, *, causal=True, q_offset=0,
                       force_kernel=False, block_q=None, block_kv=None):
    problem, arrays = _inspect(q, k, v, causal=causal, q_offset=q_offset)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel,
                             overrides={"block_q": block_q,
                                        "block_kv": block_kv})
