"""Pallas kernel packages + the shared kernel registry.

Each kernel package (``fused_mlp``, ``flash_attention``,
``stencil_gather``, ``rwkv6_chunk``) ships ``<name>.py`` (the Pallas
kernel), ``ref.py`` (the jnp oracle), and ``ops.py`` (a thin shim that
registers a :class:`repro.kernels.registry.KernelSpec` and dispatches
through :func:`repro.kernels.registry.dispatch`).  See
``src/repro/tune/README.md`` for the KernelSpec contract and how the
autotuner sweeps registered kernels.
"""
from repro.kernels.registry import (KernelSpec, TunableParam, all_specs,
                                    device_vmem_budget, dispatch,
                                    ensure_builtin_specs, get_spec, register)

__all__ = ["KernelSpec", "TunableParam", "all_specs", "device_vmem_budget",
           "dispatch", "ensure_builtin_specs", "get_spec", "register"]
