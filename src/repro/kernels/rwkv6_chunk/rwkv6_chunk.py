"""Pallas TPU kernel: RWKV6 (Finch) WKV chunk scan.

One program per (batch, head).  The [hd_k, hd_v] state matrix lives in a
VMEM accumulator; the time loop runs *inside* the kernel (fori_loop), so
the recurrence never round-trips HBM between tokens — the portable jnp
path needs O(c * hd^2) associative-scan intermediates instead.  Rank-1
updates map to VPU outer products; hd = 64 keeps lanes full.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, *,
            seq_len):
    hd = r_ref.shape[-1]
    S = s0_ref[0, 0].astype(jnp.float32)  # [hd, hd]
    u = u_ref[0].astype(jnp.float32)      # [hd]

    def body(t, S):
        r = r_ref[0, t, 0, :].astype(jnp.float32)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]             # [hd_k, hd_v]
        o = (r[None, :] @ (S + u[:, None] * kv))[0]  # [hd_v]
        o_ref[0, t, 0, :] = o.astype(o_ref.dtype)
        return w[:, None] * S + kv

    S = jax.lax.fori_loop(0, seq_len, body, S)
    sT_ref[0, 0] = S.astype(sT_ref.dtype)


def rwkv6_chunk(r, k, v, w, u, s0, *, interpret=True):
    """r,k,v,w: [B, T, H, hd]; u: [H, hd]; s0: [B, H, hd, hd].

    Returns (o [B, T, H, hd], sT [B, H, hd, hd]).
    """
    B, T, H, hd = r.shape
    out = pl.pallas_call(
        functools.partial(_kernel, seq_len=T),
        out_shape=(
            jax.ShapeDtypeStruct((B, T, H, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, hd), lambda b, h: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, T, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h: (b, h, 0, 0)),
        ),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out
