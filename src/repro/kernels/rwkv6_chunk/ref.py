"""Pure-jnp sequential oracle for the RWKV6 WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_chunk_ref(r, k, v, w, u, s0):
    """r,k,v,w: [B,T,H,hd]; u: [H,hd]; s0: [B,H,hd,hd] (f32)."""
    rf, kf, vf, wf = (t.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,hd,hd]
        o = jnp.einsum("bhi,bhij->bhj", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, o

    sT, o = jax.lax.scan(step, s0.astype(jnp.float32), (rf, kf, vf, wf))
    return o.transpose(1, 0, 2, 3).astype(r.dtype), sT
