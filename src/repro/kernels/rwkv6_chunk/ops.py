"""Registry shim + spec for the RWKV6 WKV chunk-scan kernel.

No tunable parameters: the grid is (batch, head) and the time loop runs
inside the kernel, so there is no tile ladder to sweep — the registry
still owns the backend dispatch (and the parity suite still validates
the kernel against its sequential oracle like every other spec).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.rwkv6_chunk.ref import rwkv6_chunk_ref
from repro.kernels.rwkv6_chunk.rwkv6_chunk import rwkv6_chunk


# ----------------------------------------------------------- KernelSpec ----
def _inspect(r, k, v, w, u, s0):
    B, T, H, hd = r.shape
    problem = {"b": int(B), "t": int(T), "h": int(H), "hd": int(hd),
               "dtype": str(np.dtype(r.dtype))}
    return problem, (r, k, v, w, u, s0)


def _run(problem, arrays, params, *, interpret):
    del params  # no tunables
    return rwkv6_chunk(*arrays, interpret=interpret)


def _ref(problem, arrays):
    return rwkv6_chunk_ref(*arrays)


def _make(problem, rng):
    B, T, H, hd = problem["b"], problem["t"], problem["h"], problem["hd"]
    dt = problem["dtype"]

    def t(*shape, lo=None, hi=None):
        a = (rng.uniform(lo, hi, shape) if lo is not None
             else rng.normal(size=shape)).astype(np.float32)
        return jnp.asarray(a, dt)
    r, k, v = t(B, T, H, hd), t(B, T, H, hd), t(B, T, H, hd)
    w = t(B, T, H, hd, lo=0.7, hi=0.999)
    u = t(H, hd)
    s0 = t(B, H, hd, hd) * 0.1
    return (r, k, v, w, u, s0)


def _key(problem, backend):
    p = problem
    return (f"b{p['b']}-t{p['t']}-h{p['h']}-hd{p['hd']}"
            f"|{p['dtype']}|{backend}")


SPEC = registry.register(registry.KernelSpec(
    name="rwkv6_chunk",
    params=(),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, candidates=lambda problem: [{}],
    tol=(1e-5, 1e-5),
    default_problems=(
        {"b": 2, "t": 64, "h": 2, "hd": 16, "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
def rwkv6_chunk_op(r, k, v, w, u, s0, *, force_kernel=False):
    problem, arrays = _inspect(r, k, v, w, u, s0)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel)
