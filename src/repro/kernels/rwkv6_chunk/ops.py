"""jit'd wrapper: Pallas kernel on TPU, sequential oracle elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6_chunk.ref import rwkv6_chunk_ref
from repro.kernels.rwkv6_chunk.rwkv6_chunk import rwkv6_chunk


def rwkv6_chunk_op(r, k, v, w, u, s0, *, force_kernel=False):
    on_tpu = jax.default_backend() == "tpu"
    if force_kernel or on_tpu:
        return rwkv6_chunk(r, k, v, w, u, s0, interpret=not on_tpu)
    return rwkv6_chunk_ref(r, k, v, w, u, s0)
