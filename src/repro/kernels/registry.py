"""Shared kernel registry: one declaration per Pallas kernel, one dispatcher.

Every kernel package used to hand-roll the same wrapper four times: check
``jax.default_backend()``, honor ``force_kernel``, run the Pallas kernel
(interpret mode off-TPU) or the jnp oracle, and — for fused_mlp only —
consult the autotune cache.  This module factors that control plane into
a :class:`KernelSpec` each package registers once:

  * **tunable params** with candidate ladders (``batch_tile`` for
    fused_mlp, ``block_q``/``block_kv`` for flash attention,
    ``block_h``/``block_w`` for stencil gather; rwkv6 has none — its
    grid is fixed by the problem shape);
  * a **VMEM cost model** (``fits``) the dispatcher and the tuner share,
    budgeted against the *actual device* (:func:`device_vmem_budget`)
    rather than a hardcoded constant;
  * the **jitted ref oracle** every tuned candidate is validated against
    (``tol=None`` demands bit-identity; flash attention declares a f32
    tolerance because the online-softmax block order legitimately
    changes rounding);
  * an **interpret fallback**: off-TPU the kernel path runs only under
    ``force_kernel`` (Pallas interpret mode), everything else takes the
    oracle.

The four ``*_op`` wrappers become thin shims over :func:`dispatch`,
which resolves tunable params at trace time: explicit caller overrides
win, then validated winners from the kernel-namespaced
:class:`repro.tune.cache.TuneCache`, then the spec defaults — any value
is re-checked against the cost model so a cache written on a roomier
device can never overflow this one.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.obs import TRACER
from repro.obs import metrics as _m

_DISPATCHES = _m.counter(
    "repro_kernel_dispatch_total",
    "kernel dispatches by resolved-params provenance and precision tier",
    ("kernel", "provenance", "tier"))

# ------------------------------------------------------------ VMEM budget ---
# Every shipping TPU generation (v2 through v6e) exposes ~16 MiB of VMEM
# per TensorCore (see the TPU memory-hierarchy docs), so the kind-keyed
# budget is a single constant today.  The *budget* leaves a reserve for
# the compiler's own scratch (semaphores, spills, double-buffering
# bookkeeping) — the same 4 MiB headroom the old hardcoded 12 MiB budget
# implied on a 16 MiB part.  The ``device_kind`` parameter stays in the
# signature (and in the lru key) so per-generation entries have an
# obvious landing spot the moment a part diverges.
_VMEM_PHYSICAL = 16 * 2 ** 20
_VMEM_RESERVE = 4 * 2 ** 20
_OFF_TPU_BUDGET = 12 * 2 ** 20  # interpret mode: keep the old constant


def _vmem_budget_for_kind(device_kind: str) -> int:
    """Usable VMEM budget for a TPU ``device_kind`` string ("TPU v4",
    "TPU v5 lite", ...): physical size minus the compiler reserve."""
    del device_kind  # uniform across shipping generations — see above
    return _VMEM_PHYSICAL - _VMEM_RESERVE


@functools.lru_cache(maxsize=None)
def _device_vmem_budget_cached(backend: str, device_kind: str) -> int:
    if backend != "tpu":
        return _OFF_TPU_BUDGET
    return _vmem_budget_for_kind(device_kind)


def device_vmem_budget() -> int:
    """VMEM byte budget of the backend this process dispatches to.

    Queried from the device (kind-keyed: VMEM size is a property of the
    TPU generation, not exposed by ``memory_stats()``, which reports
    HBM); off-TPU — where kernels only ever run in interpret mode —
    the old 12 MiB constant is kept so tuner decisions stay
    deterministic in CI.
    """
    backend = jax.default_backend()
    try:
        kind = jax.devices()[0].device_kind if backend == "tpu" else ""
    except Exception:
        kind = ""
    return _device_vmem_budget_cached(backend, kind)


# ------------------------------------------------------------- KernelSpec ---
@dataclasses.dataclass(frozen=True)
class TunableParam:
    """One tunable kernel parameter and its sweep ladder."""

    name: str
    default: int
    ladder: Tuple[int, ...]


@dataclasses.dataclass
class KernelSpec:
    """Declaration the registry dispatches and the tuner sweeps.

    The call protocol splits a kernel invocation into a static
    ``problem`` dict (shapes, dtype name, config like ``acts`` or
    ``causal`` — everything that keys the tune cache and synthesizes
    sweep inputs) and the positional ``arrays`` tuple:

      * ``inspect(*args, **kwargs) -> (problem, arrays)`` — from an op
        call (arrays may be tracers: only shape/dtype are read);
      * ``run_call(problem, arrays, params, interpret)`` — the Pallas
        kernel with resolved tunables;
      * ``ref_call(problem, arrays)`` — the jnp oracle;
      * ``make_call(problem, rng) -> arrays`` — synthetic inputs for a
        sweep of the same problem;
      * ``cache_key(problem, backend) -> str`` — tune-cache key; and
        ``cache_keys`` (optional) for ordered lookup fallbacks (e.g.
        fused_mlp tries the exact batch before the pow2 bucket);
      * ``candidates(problem) -> [param dicts]`` — defaults first;
      * ``fits(problem, params, budget=None) -> bool`` — VMEM cost
        model (None budget = :func:`device_vmem_budget`);
      * ``supports(problem) -> bool`` — whether the kernel path applies
        at all (fused_mlp: the net must fit VMEM);
      * ``tol`` — (rtol, atol) validation tolerance, None = bit-exact;
      * ``tier`` — precision tier ("f32" default, "int8" for the
        quantized variants).  An int8 variant validates against its own
        int8-*simulating* oracle at a tolerance sized to one requant
        step; accuracy-vs-f32 is the quant gate's concern
        (:mod:`repro.quant.gate`), measured on real calibration rows.
    """

    name: str
    params: Tuple[TunableParam, ...]
    inspect: Callable
    run_call: Callable
    ref_call: Callable
    make_call: Callable
    cache_key: Callable
    candidates: Callable
    fits: Optional[Callable] = None
    supports: Optional[Callable] = None
    cache_keys: Optional[Callable] = None
    tol: Optional[Tuple[float, float]] = None
    tier: str = "f32"
    default_problems: Tuple[dict, ...] = ()

    def defaults(self) -> Dict[str, int]:
        return {p.name: p.default for p in self.params}

    def lookup_keys(self, problem: dict, backend: str) -> List[str]:
        if self.cache_keys is not None:
            return list(self.cache_keys(problem, backend))
        return [self.cache_key(problem, backend)]


# --------------------------------------------------------------- registry ---
_SPECS: Dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    _SPECS[spec.name] = spec
    return spec


def get_spec(name: str) -> KernelSpec:
    ensure_builtin_specs()
    try:
        return _SPECS[name]
    except KeyError:
        raise KeyError(f"unknown kernel {name!r}; registered: "
                       f"{sorted(_SPECS)}") from None


def all_specs() -> List[KernelSpec]:
    ensure_builtin_specs()
    return [_SPECS[k] for k in sorted(_SPECS)]


_BUILTIN_OPS = ("repro.kernels.fused_mlp.ops",
                "repro.kernels.fused_mlp.int8",
                "repro.kernels.flash_attention.ops",
                "repro.kernels.flash_attention.int8",
                "repro.kernels.stencil_gather.ops",
                "repro.kernels.rwkv6_chunk.ops")


def ensure_builtin_specs() -> None:
    """Import the kernel packages so their specs self-register."""
    import importlib
    for mod in _BUILTIN_OPS:
        importlib.import_module(mod)


# --------------------------------------------------------------- dispatch ---
def tuned_params(spec: KernelSpec, problem: dict) -> Dict[str, int]:
    """Validated tune-cache winner for ``problem``, or {} when untuned.

    Runs at trace time (the op shims call it while the engine's apply is
    being traced), so a cache problem must degrade to the defaults, not
    raise into the trace.
    """
    if not spec.params:
        return {}
    try:
        from repro.tune.cache import best_params
        return best_params(spec.name,
                           spec.lookup_keys(problem,
                                            jax.default_backend())) or {}
    except Exception:
        return {}


def resolve_params_info(spec: KernelSpec, problem: dict,
                        overrides: Optional[dict] = None
                        ) -> Tuple[Dict[str, int], str]:
    """Merge explicit overrides > tuned winners > spec defaults, then
    re-check the result against the VMEM cost model — a tuned (or
    caller-supplied) config that would overflow *this* device's budget
    falls back to the defaults.

    Returns ``(params, provenance)``; the provenance string (one of
    ``explicit``/``tuned``/``default``/``default:vmem-fallback``, the
    first two mixed as ``explicit+tuned``) is what the obs layer records
    per dispatch, so a trace shows whether a kernel ran its sweep winner
    or silently fell back.
    """
    overrides = {k: v for k, v in (overrides or {}).items() if v is not None}
    tuned = None
    params: Dict[str, int] = {}
    sources = set()
    for p in spec.params:
        if p.name in overrides:
            params[p.name] = int(overrides[p.name])
            sources.add("explicit")
            continue
        if tuned is None:
            tuned = tuned_params(spec, problem)
        if p.name in tuned:
            params[p.name] = int(tuned[p.name])
            sources.add("tuned")
        else:
            params[p.name] = p.default
            sources.add("default")
    provenance = "+".join(s for s in ("explicit", "tuned", "default")
                          if s in sources) or "default"
    if spec.fits is not None and params and not spec.fits(problem, params):
        params = spec.defaults()
        provenance = "default:vmem-fallback"
    return params, provenance


def resolve_params(spec: KernelSpec, problem: dict,
                   overrides: Optional[dict] = None) -> Dict[str, int]:
    return resolve_params_info(spec, problem, overrides)[0]


def quantized_variant(spec: KernelSpec) -> Optional[KernelSpec]:
    """The registered int8 twin of a base spec (``<name>_int8``), or
    None when the kernel has no quantized variant."""
    ensure_builtin_specs()
    return _SPECS.get(spec.name + "_int8")


def select_tier_spec(spec: KernelSpec, problem: Optional[dict] = None, *,
                     gated: bool, explicit: Optional[str] = None
                     ) -> Tuple[KernelSpec, str]:
    """Precision-tier resolution for one dispatch site.

    Extends the param-provenance order to tiers — **explicit >
    tuned-quantized-if-gated > tuned > default**:

      * ``explicit`` pins the tier: ``"f32"`` (REPRO_QUANT=never) always
        serves the base spec, ``"int8"`` (REPRO_QUANT=force, the CI
        fail-path drill) serves the variant whenever it exists and
        supports the problem — the gate verdict is bypassed;
      * otherwise the int8 variant serves only when the bundle's
        accuracy gate passed (``gated=True``) *and* the variant's own
        ``supports`` accepts the problem;
      * anything else falls through to the base spec, whose params then
        resolve tuned-before-default as always.

    Returns ``(spec_to_dispatch, tier)``.
    """
    if explicit == "f32":
        return spec, spec.tier
    q = quantized_variant(spec)
    if q is None or (explicit != "int8" and not gated):
        return spec, spec.tier
    if problem is not None and q.supports is not None \
            and not q.supports(problem):
        return spec, spec.tier
    return q, q.tier


def dispatch(spec: KernelSpec, problem: dict, arrays: tuple, *,
             force_kernel: bool = False, overrides: Optional[dict] = None):
    """The shared on-TPU / ``force_kernel`` / interpret-fallback branch.

    On TPU (or under ``force_kernel``, which runs the Pallas kernel in
    interpret mode off-TPU) the kernel path runs with trace-time
    resolved tunables; otherwise the jnp oracle serves the call.
    """
    from repro.resilience.faults import FAULTS
    if FAULTS.enabled:
        # dispatch runs at jit trace time, so a raise here surfaces as a
        # compile failure on the serve path (once per shape, not per call)
        FAULTS.fire("kernel.dispatch", key=spec.name)
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = force_kernel or on_tpu
    if use_kernel and spec.supports is not None:
        use_kernel = bool(spec.supports(problem))
    if not use_kernel:
        _DISPATCHES.inc(1, kernel=spec.name, provenance="ref",
                        tier=spec.tier)
        if TRACER.enabled:
            TRACER.instant("kernel.dispatch", cat="kernel",
                           args={"kernel": spec.name, "path": "ref",
                                 "tier": spec.tier})
        return spec.ref_call(problem, arrays)
    params, provenance = resolve_params_info(spec, problem, overrides)
    # dispatch() runs at jit trace time, so this lands once per compiled
    # shape, not once per serving call — an instant, not a span, because
    # kernel wall time belongs to XLA's own profile
    _DISPATCHES.inc(1, kernel=spec.name, provenance=provenance,
                    tier=spec.tier)
    if TRACER.enabled:
        TRACER.instant("kernel.dispatch", cat="kernel",
                       args={"kernel": spec.name, "params": dict(params),
                             "provenance": provenance, "tier": spec.tier,
                             "interpret": not on_tpu})
    return spec.run_call(problem, arrays, params, interpret=not on_tpu)


# ------------------------------------------------------------ shared bits ---
def round_up(n: int, m: int) -> int:
    return n + (-n % m)


def tile_bytes(rows: int, cols: int, dtype_bytes: int = 4) -> int:
    """Bytes one [rows, cols] buffer occupies in VMEM after (sublane,
    lane) register-layout padding — (8, 128) for f32."""
    sublane = max(8 * 4 // dtype_bytes, 8)
    return round_up(rows, sublane) * round_up(cols, 128) * dtype_bytes


def ladder_candidates(spec_params: Sequence[TunableParam],
                      clip: Optional[Dict[str, int]] = None,
                      fits: Optional[Callable] = None) -> List[dict]:
    """Cartesian product of the params' ladders, defaults-first, each
    axis clipped to ``clip[name]`` (inclusive), filtered by ``fits``.

    Defaults-first matters: the sweep measures ``candidates[0]`` as the
    baseline every winner's speedup is reported against, and ties keep
    the default.
    """
    clip = clip or {}
    axes: List[List[int]] = []
    for p in spec_params:
        hi = clip.get(p.name)
        vals = [p.default]
        for v in p.ladder:
            if v == p.default or (hi is not None and v > hi):
                continue
            vals.append(int(v))
        axes.append(vals)
    combos: List[dict] = [{}]
    for p, vals in zip(spec_params, axes):
        combos = [dict(c, **{p.name: v}) for c in combos for v in vals]
    # the all-defaults combo is first by construction; drop dupes, keep order
    seen, out = set(), []
    for c in combos:
        key = tuple(sorted(c.items()))
        if key in seen:
            continue
        seen.add(key)
        if fits is None or fits(c):
            out.append(c)
    return out
