"""Pure-jnp oracle for stencil_gather (also the portable TensorMap path)."""
from __future__ import annotations

import jax.numpy as jnp


def stencil_gather_ref(x, offsets, out_h, out_w, *, origin=(0, 0)):
    feats = []
    for dy, dx in offsets:
        i0 = origin[0] + dy
        j0 = origin[1] + dx
        feats.append(x[i0:i0 + out_h, j0:j0 + out_w])
    return jnp.stack(feats, axis=-1)
