"""Registry shim + spec for the stencil-gather (im2col) data bridge.

Tunables: the output row/column tiles ``block_h``/``block_w``.  The
kernel is a pure gather, so validation is bit-exact; the tile choice
only trades grid-step overhead against tile-padding waste.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.stencil_gather.ref import stencil_gather_ref
from repro.kernels.stencil_gather.stencil_gather import stencil_gather

_H_LADDER = (8, 16, 32, 64)
_W_LADDER = (128, 256, 512)


# ----------------------------------------------------------- KernelSpec ----
def _inspect(x, *, offsets, out_h, out_w, origin=(0, 0)):
    offsets = tuple(tuple(int(v) for v in o) for o in offsets)
    problem = {"h": int(x.shape[0]), "w": int(x.shape[1]),
               "out_h": int(out_h), "out_w": int(out_w),
               "offsets": offsets, "origin": tuple(int(v) for v in origin),
               "dtype": str(np.dtype(x.dtype))}
    return problem, (x,)


def _run(problem, arrays, params, *, interpret):
    return stencil_gather(arrays[0], problem["offsets"], problem["out_h"],
                          problem["out_w"], origin=problem["origin"],
                          block_h=params["block_h"],
                          block_w=params["block_w"], interpret=interpret)


def _ref(problem, arrays):
    return stencil_gather_ref(arrays[0], problem["offsets"],
                              problem["out_h"], problem["out_w"],
                              origin=problem["origin"])


def _make(problem, rng):
    x = jnp.asarray(rng.normal(size=(problem["h"], problem["w"]))
                    .astype(np.float32), problem["dtype"])
    return (x,)


def _halo(problem):
    o0, o1 = problem["origin"]
    dys = [o0 + dy for dy, _ in problem["offsets"]]
    dxs = [o1 + dx for _, dx in problem["offsets"]]
    return max(dys), max(dxs)


def _key(problem, backend):
    """Tile choice depends on the output extent, the feature count, and
    the halo — not on the individual offsets, so stencils sharing those
    share a tuned entry (tile params are correctness-neutral)."""
    dy, dx = _halo(problem)
    p = problem
    shape = (f"h{p['h']}-w{p['w']}-oh{p['out_h']}-ow{p['out_w']}-"
             f"f{len(p['offsets'])}-dy{dy}-dx{dx}")
    return f"{shape}|{p['dtype']}|{backend}"


def _fits(problem, params, budget=None):
    """The full (padded) source grid is VMEM-resident plus the gathered
    output tile — whose last-dim F pads to a full lane group."""
    if budget is None:
        budget = registry.device_vmem_budget()
    bh, bw = params["block_h"], params["block_w"]
    dy, dx = _halo(problem)
    gh = problem["out_h"] + (-problem["out_h"] % bh) + max(0, dy)
    gw = problem["out_w"] + (-problem["out_w"] % bw) + max(0, dx)
    t = registry.tile_bytes
    grid_bytes = t(gh, gw)
    out_tile = bh * registry.round_up(bw, 8) * \
        registry.round_up(len(problem["offsets"]), 128) * 4
    return grid_bytes + 2 * out_tile <= budget


def _cands(problem):
    clip = {"block_h": registry.round_up(problem["out_h"], 8),
            "block_w": registry.round_up(problem["out_w"], 128)}
    return registry.ladder_candidates(
        SPEC.params, clip, fits=lambda c: _fits(problem, c))


SPEC = registry.register(registry.KernelSpec(
    name="stencil_gather",
    params=(registry.TunableParam("block_h", 8, _H_LADDER),
            registry.TunableParam("block_w", 128, _W_LADDER)),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, candidates=_cands, fits=_fits, tol=None,
    default_problems=(
        # miniweather-like sweep grid, 5-point stencil
        {"h": 512, "w": 512, "out_h": 508, "out_w": 508,
         "offsets": ((0, 1), (2, 0), (1, 1), (0, 0), (1, 2)),
         "origin": (1, 1), "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
@functools.partial(jax.jit, static_argnames=("offsets", "out_h", "out_w",
                                             "origin", "force_kernel",
                                             "block_h", "block_w"))
def stencil_gather_op(x, *, offsets, out_h, out_w, origin=(0, 0),
                      force_kernel=False, block_h=None, block_w=None):
    problem, arrays = _inspect(x, offsets=offsets, out_h=out_h, out_w=out_w,
                               origin=origin)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel,
                             overrides={"block_h": block_h,
                                        "block_w": block_w})


def functor_offsets(tensor_map):
    """Extract static (dy, dx) offsets from a 2-D point-slice TensorMap."""
    offs = []
    for desc in tensor_map.descriptors:
        for eo in desc.elem_offsets:
            offs.append((desc.offsets[0] + eo[0], desc.offsets[1] + eo[1]))
    return tuple(offs)
