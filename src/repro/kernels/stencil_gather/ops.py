"""jit'd public wrapper: picks the Pallas kernel on TPU, oracle elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.kernels.stencil_gather.ref import stencil_gather_ref
from repro.kernels.stencil_gather.stencil_gather import stencil_gather


@functools.partial(jax.jit, static_argnames=("offsets", "out_h", "out_w",
                                             "origin", "force_kernel"))
def stencil_gather_op(x, *, offsets, out_h, out_w, origin=(0, 0),
                      force_kernel=False):
    offsets = tuple(tuple(o) for o in offsets)
    if force_kernel or jax.default_backend() == "tpu":
        return stencil_gather(x, offsets, out_h, out_w, origin=origin,
                              interpret=jax.default_backend() != "tpu")
    return stencil_gather_ref(x, offsets, out_h, out_w, origin=origin)


def functor_offsets(tensor_map):
    """Extract static (dy, dx) offsets from a 2-D point-slice TensorMap."""
    offs = []
    for desc in tensor_map.descriptors:
        for eo in desc.elem_offsets:
            offs.append((desc.offsets[0] + eo[0], desc.offsets[1] + eo[1]))
    return tuple(offs)
