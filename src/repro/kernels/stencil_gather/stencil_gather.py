"""Pallas TPU kernel for the data-bridge stencil gather (paper Fig. 4).

The tensor-map hot path for stencil functors is an im2col-style gather:
for every sweep point (i, j) emit F features, each a fixed (dy, dx) offset
read of the source grid.  On TPU we tile the OUTPUT over (8, 128)-aligned
blocks; the source grid block (output tile + halo) streams HBM->VMEM once
and every feature is a shifted VMEM view — no HBM round-trips between
features, unlike F separate strided slices.

Offsets are static (they come from symbolic shape extraction), so the
feature loop unrolls at trace time into vector moves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, offsets, block_h, block_w):
    """x_ref: full (padded) grid in VMEM; o_ref: [block_h, block_w, F]."""
    bi = pl.program_id(0)
    bj = pl.program_id(1)
    i0 = bi * block_h
    j0 = bj * block_w
    for f, (dy, dx) in enumerate(offsets):
        tile = x_ref[pl.dslice(i0 + dy, block_h), pl.dslice(j0 + dx, block_w)]
        o_ref[:, :, f] = tile


def stencil_gather(x, offsets, out_h, out_w, *, origin=(0, 0),
                   block_h: int = 8, block_w: int = 128,
                   interpret: bool = True):
    """Gather im2col features.

    x: [H, W] source grid.  offsets: list of (dy, dx) per feature, relative
    to the sweep origin.  Returns [out_h, out_w, F] with
    ``out[i, j, f] = x[origin0 + i + dy_f, origin1 + j + dx_f]``.
    """
    F = len(offsets)
    offs = [(origin[0] + dy, origin[1] + dx) for dy, dx in offsets]
    ph = -out_h % block_h
    pw = -out_w % block_w
    # pad so every (block + max offset) read stays in bounds
    max_dy = max(o[0] for o in offs)
    max_dx = max(o[1] for o in offs)
    xp = jnp.pad(x, ((0, max(0, ph + max_dy)), (0, max(0, pw + max_dx))))
    gh = (out_h + ph) // block_h
    gw = (out_w + pw) // block_w

    out = pl.pallas_call(
        functools.partial(_kernel, offsets=offs, block_h=block_h,
                          block_w=block_w),
        out_shape=jax.ShapeDtypeStruct((out_h + ph, out_w + pw, F), x.dtype),
        grid=(gh, gw),
        in_specs=[pl.BlockSpec(xp.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((block_h, block_w, F),
                               lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(xp)
    return out[:out_h, :out_w]
