"""jit'd wrapper + spec adapter for the inference engine."""
from __future__ import annotations

import jax

from repro.kernels.fused_mlp.fused_mlp import fits_vmem, fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref


def fused_mlp_op(x, weights, biases, acts, *, force_kernel=False):
    widths = [weights[0].shape[0]] + [w.shape[1] for w in weights]
    on_tpu = jax.default_backend() == "tpu"
    if (force_kernel or on_tpu) and fits_vmem(widths):
        return fused_mlp(x, weights, biases, acts, interpret=not on_tpu)
    return fused_mlp_ref(x, weights, biases, acts)


def fused_mlp_from_spec(spec, params, x):
    """Adapter: run a pure-dense Sequential bundle through the kernel.

    Layer spec pattern: dense [act] dense [act] ... ; activations between
    denses become the per-layer act, trailing dense gets 'identity'.
    """
    weights, biases, acts = [], [], []
    import jax.numpy as jnp
    pending_w = None
    for layer_spec, p in zip(spec["layers"], params):
        if layer_spec["kind"] == "dense":
            if pending_w is not None:
                acts.append("identity")
            weights.append(p["w"])
            biases.append(p.get("b", jnp.zeros((p["w"].shape[1],),
                                               p["w"].dtype)))
            pending_w = True
        elif layer_spec["kind"] == "act":
            acts.append(layer_spec["name"])
            pending_w = None
        elif layer_spec["kind"] == "flatten":
            x = x.reshape(x.shape[0], -1)
    if pending_w is not None:
        acts.append("identity")
    return fused_mlp_op(x, weights, biases, acts)
