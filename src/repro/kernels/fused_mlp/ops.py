"""Registry shim + spec adapter for the fused-MLP inference kernel.

Backend dispatch (on-TPU / ``force_kernel`` / interpret fallback) and
tuned-parameter resolution live in :mod:`repro.kernels.registry`; this
module only declares the kernel's :class:`KernelSpec` — how to derive a
problem from a call, synthesize sweep inputs, key the tune cache, and
cost VMEM — plus the shard_map wrapper and the engine's spec adapter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.kernels.fused_mlp.fused_mlp import fits_vmem, fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref

DEFAULT_TILE = 128
_TILE_LADDER = (16, 32, 64, 128, 256, 512)


# ----------------------------------------------------------- KernelSpec ----
def _inspect(x, weights, biases, acts):
    widths = (int(weights[0].shape[0]),) + tuple(int(w.shape[1])
                                                 for w in weights)
    problem = {"widths": widths, "acts": tuple(acts),
               "batch": int(x.shape[0]), "dtype": str(np.dtype(x.dtype))}
    return problem, (x, tuple(weights), tuple(biases))


def _run(problem, arrays, params, *, interpret):
    x, ws, bs = arrays
    return fused_mlp(x, list(ws), list(bs), problem["acts"],
                     batch_tile=params["batch_tile"], interpret=interpret)


def _ref(problem, arrays):
    x, ws, bs = arrays
    return fused_mlp_ref(x, list(ws), list(bs), problem["acts"])


def _make(problem, rng):
    widths, dtype = problem["widths"], problem["dtype"]
    ws = tuple(jnp.asarray(rng.normal(size=(a, b)).astype(np.float32) * 0.3,
                           dtype) for a, b in zip(widths[:-1], widths[1:]))
    bs = tuple(jnp.asarray(rng.normal(size=(b,)).astype(np.float32) * 0.1,
                           dtype) for b in widths[1:])
    x = jnp.asarray(rng.normal(size=(problem["batch"], widths[0]))
                    .astype(np.float32), dtype)
    return (x, ws, bs)


def _key(problem, backend):
    from repro.tune.cache import shape_key
    return shape_key(problem["widths"], problem["dtype"], backend,
                     problem["batch"])


def _keys(problem, backend):
    """Exact batch first (serve-path dispatches and per-shard shard_map
    batches arrive bucket-shaped, including non-pow2 shard-rounded
    buckets), then the power-of-two bucket covering eager calls."""
    from repro.serve.batcher import bucket_size
    from repro.tune.cache import shape_key
    b = problem["batch"]
    return [shape_key(problem["widths"], problem["dtype"], backend, bb)
            for bb in dict.fromkeys((b, bucket_size(b)))]


def candidate_tiles(widths, bucket, extra=(), dtype="float32"):
    """Tiles worth sweeping for one bucket: the standard ladder clipped
    to the bucket, the bucket itself (grid of 1), and any extras —
    deduped, VMEM-checked at the problem's actual dtype width (a bf16
    net packs twice the tiles of an f32 one), default first so ties
    keep the default.  (The single source for the fused_mlp candidate
    set; the tuner and the spec both consume it.)"""
    dtype_bytes = np.dtype(dtype).itemsize
    tiles = [DEFAULT_TILE]
    for t in _TILE_LADDER + (int(bucket),) + tuple(extra):
        t = int(t)
        if 0 < t <= bucket and t not in tiles:
            tiles.append(t)
    return [t for t in tiles if fits_vmem(widths, t,
                                          dtype_bytes=dtype_bytes)]


def _cands(problem):
    return [{"batch_tile": t}
            for t in candidate_tiles(problem["widths"], problem["batch"],
                                     dtype=problem["dtype"])]


def _fits(problem, params, budget=None):
    # per-operand dtype threading: the cost model prices tiles at the
    # problem's dtype width, not a hardcoded f32
    return fits_vmem(problem["widths"], params["batch_tile"], budget=budget,
                     dtype_bytes=np.dtype(problem["dtype"]).itemsize)


def _supports(problem):
    return fits_vmem(problem["widths"],
                     dtype_bytes=np.dtype(problem["dtype"]).itemsize)


SPEC = registry.register(registry.KernelSpec(
    name="fused_mlp",
    params=(registry.TunableParam("batch_tile", DEFAULT_TILE, _TILE_LADDER),),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, cache_keys=_keys, candidates=_cands, fits=_fits,
    supports=_supports, tol=None,
    default_problems=(
        {"widths": (5, 128, 128, 1), "acts": ("relu", "relu", "identity"),
         "batch": 256, "dtype": "float32"},
        {"widths": (16, 256, 256, 4), "acts": ("relu", "relu", "identity"),
         "batch": 512, "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
def fused_mlp_op(x, weights, biases, acts, *, force_kernel=False,
                 batch_tile=None):
    problem, arrays = _inspect(x, weights, biases, acts)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel,
                             overrides={"batch_tile": batch_tile})


def fused_mlp_sharded(x, weights, biases, acts, *, mesh, data_axes,
                      force_kernel=False, batch_tile=None):
    """Batch-sharded fused MLP under GSPMD via shard_map.

    Weights replicate (the whole net already fits VMEM per chip — that is
    the kernel's premise); the batch splits over ``data_axes`` and each
    shard runs the VMEM-resident kernel on its local rows, so pure-MLP
    bundles keep the fast path when the engine serves a sharded mesh.

    Falls back to the unsharded op when the batch does not divide the
    shard count (serve-path buckets are powers of two, so in practice
    only tiny eager calls fall back).
    """
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    if n_shards <= 1 or x.shape[0] % n_shards:
        return fused_mlp_op(x, weights, biases, acts,
                            force_kernel=force_kernel,
                            batch_tile=batch_tile)
    from jax.experimental.shard_map import shard_map
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
    xspec = P(*((ax,) + (None,) * (x.ndim - 1)))

    def local(xs, ws, bs):
        # xs carries the *per-shard* batch here, so the tuned-tile
        # lookup keys on the rows each chip actually serves
        return fused_mlp_op(xs, ws, bs, acts, force_kernel=force_kernel,
                            batch_tile=batch_tile)

    f = shard_map(local, mesh=mesh, in_specs=(xspec, P(), P()),
                  out_specs=xspec, check_rep=False)
    return f(x, list(weights), list(biases))


def mlp_stack_from_spec(spec, params, x):
    """Walk a pure-dense Sequential bundle spec into the fused kernel's
    call shape: ``(x, weights, biases, acts)``.

    Layer spec pattern: dense [act] dense [act] ... ; activations between
    denses become the per-layer act, trailing dense gets 'identity'.
    ``params=None`` walks acts/flatten only (weights come back empty) —
    the int8 adapter serves pre-quantized residency instead.
    """
    weights, biases, acts = [], [], []
    pending_w = None
    plist = params if params is not None else [None] * len(spec["layers"])
    for layer_spec, p in zip(spec["layers"], plist):
        if layer_spec["kind"] == "dense":
            if pending_w is not None:
                acts.append("identity")
            if p is not None:
                weights.append(p["w"])
                biases.append(p.get("b", jnp.zeros((p["w"].shape[1],),
                                                   p["w"].dtype)))
            pending_w = True
        elif layer_spec["kind"] == "act":
            acts.append(layer_spec["name"])
            pending_w = None
        elif layer_spec["kind"] == "flatten":
            x = x.reshape(x.shape[0], -1)
    if pending_w is not None:
        acts.append("identity")
    return x, weights, biases, acts


def fused_mlp_from_spec(spec, params, x, *, mesh=None, data_axes=()):
    """Adapter: run a pure-dense Sequential bundle through the kernel."""
    x, weights, biases, acts = mlp_stack_from_spec(spec, params, x)
    if mesh is not None and data_axes:
        return fused_mlp_sharded(x, weights, biases, acts, mesh=mesh,
                                 data_axes=tuple(data_axes))
    return fused_mlp_op(x, weights, biases, acts)
