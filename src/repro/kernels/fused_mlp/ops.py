"""jit'd wrapper + spec adapter for the inference engine."""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.kernels.fused_mlp.fused_mlp import fits_vmem, fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref


def _tile_for(widths, x, batch_tile):
    """Resolve the batch tile: explicit arg > tuned cache > default 128.

    The cache lookup happens at trace time (x.shape is static inside the
    engine's jit), so serving pays one dict probe per compiled shape,
    not per call.  Tuned tiles are re-checked against ``fits_vmem`` —
    a cache written on a machine with a bigger VMEM budget must not
    push this one over.
    """
    if batch_tile is None:
        from repro.tune.cache import best_tile
        batch_tile = best_tile(widths, x.dtype, jax.default_backend(),
                               int(x.shape[0]))
    if batch_tile is None or not fits_vmem(widths, batch_tile):
        batch_tile = 128
    return batch_tile


def fused_mlp_op(x, weights, biases, acts, *, force_kernel=False,
                 batch_tile=None):
    widths = [weights[0].shape[0]] + [w.shape[1] for w in weights]
    on_tpu = jax.default_backend() == "tpu"
    if (force_kernel or on_tpu) and fits_vmem(widths):
        tile = _tile_for(widths, x, batch_tile)
        return fused_mlp(x, weights, biases, acts, batch_tile=tile,
                         interpret=not on_tpu)
    return fused_mlp_ref(x, weights, biases, acts)


def fused_mlp_sharded(x, weights, biases, acts, *, mesh, data_axes,
                      force_kernel=False, batch_tile=None):
    """Batch-sharded fused MLP under GSPMD via shard_map.

    Weights replicate (the whole net already fits VMEM per chip — that is
    the kernel's premise); the batch splits over ``data_axes`` and each
    shard runs the VMEM-resident kernel on its local rows, so pure-MLP
    bundles keep the fast path when the engine serves a sharded mesh.

    Falls back to the unsharded op when the batch does not divide the
    shard count (serve-path buckets are powers of two, so in practice
    only tiny eager calls fall back).
    """
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    if n_shards <= 1 or x.shape[0] % n_shards:
        return fused_mlp_op(x, weights, biases, acts,
                            force_kernel=force_kernel,
                            batch_tile=batch_tile)
    from jax.experimental.shard_map import shard_map
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
    xspec = P(*((ax,) + (None,) * (x.ndim - 1)))

    def local(xs, ws, bs):
        # xs carries the *per-shard* batch here, so the tuned-tile
        # lookup keys on the rows each chip actually serves
        return fused_mlp_op(xs, ws, bs, acts, force_kernel=force_kernel,
                            batch_tile=batch_tile)

    f = shard_map(local, mesh=mesh, in_specs=(xspec, P(), P()),
                  out_specs=xspec, check_rep=False)
    return f(x, list(weights), list(biases))


def fused_mlp_from_spec(spec, params, x, *, mesh=None, data_axes=()):
    """Adapter: run a pure-dense Sequential bundle through the kernel.

    Layer spec pattern: dense [act] dense [act] ... ; activations between
    denses become the per-layer act, trailing dense gets 'identity'.
    """
    weights, biases, acts = [], [], []
    import jax.numpy as jnp
    pending_w = None
    for layer_spec, p in zip(spec["layers"], params):
        if layer_spec["kind"] == "dense":
            if pending_w is not None:
                acts.append("identity")
            weights.append(p["w"])
            biases.append(p.get("b", jnp.zeros((p["w"].shape[1],),
                                               p["w"].dtype)))
            pending_w = True
        elif layer_spec["kind"] == "act":
            acts.append(layer_spec["name"])
            pending_w = None
        elif layer_spec["kind"] == "flatten":
            x = x.reshape(x.shape[0], -1)
    if pending_w is not None:
        acts.append("identity")
    if mesh is not None and data_axes:
        return fused_mlp_sharded(x, weights, biases, acts, mesh=mesh,
                                 data_axes=tuple(data_axes))
    return fused_mlp_op(x, weights, biases, acts)
