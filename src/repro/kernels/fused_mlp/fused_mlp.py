"""Pallas TPU kernel: whole-surrogate fused MLP inference.

The paper's NAS space produces small dense networks (hidden <= 4096).  On
GPU each layer is a separate cuBLAS call with HBM round-trips between
layers; on TPU the whole net fits VMEM, so one kernel keeps weights
resident, tiles the batch over the grid, and chains the layers on the MXU
with no intermediate HBM traffic — the TPU-native reading of the paper's
Observation 2 (surrogates win by raising hardware utilization).

VMEM budget: sum(W_l) + 2 * batch_tile * max_width * 4B must stay under
the device's VMEM budget (queried per device kind, 12 MiB off-TPU);
``fits_vmem`` guards this and the registry dispatch falls back to the
jnp path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def _kernel(*refs, n_layers, acts):
    x_ref = refs[0]
    o_ref = refs[-1]
    wb = refs[1:-1]  # alternating w, b
    h = x_ref[...]
    for l in range(n_layers):
        w = wb[2 * l][...]
        b = wb[2 * l + 1][...]
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        h = _ACTS[acts[l]](h)
    o_ref[...] = h.astype(o_ref.dtype)


def fits_vmem(widths, batch_tile=128, budget=None, dtype_bytes=4):
    """Exact VMEM accounting for one grid step of the fused kernel.

    VMEM tiles are padded to the TPU register layout — (8, 128) sublane x
    lane for f32 — so a [129, 5] weight occupies 136 x 128 lanes, not
    129 x 5.  Bias rows cost a full (8, 128)-padded tile each, and the
    batch tile rounds up to a sublane multiple.  The tuner trusts this
    predicate to reject configs that would overflow, so it must account
    every resident byte: weights + biases + input/output activation
    tiles (double-buffered pipeline: 2x each).

    ``budget=None`` queries the actual device's VMEM via the backend
    (:func:`repro.kernels.registry.device_vmem_budget`; 12 MiB off-TPU).
    """
    from repro.kernels.registry import device_vmem_budget, tile_bytes
    if budget is None:
        budget = device_vmem_budget()
    wbytes = sum(tile_bytes(a, b, dtype_bytes)
                 for a, b in zip(widths[:-1], widths[1:]))
    bbytes = sum(tile_bytes(1, b, dtype_bytes) for b in widths[1:])
    abytes = 2 * 2 * tile_bytes(batch_tile, max(widths), dtype_bytes)
    return wbytes + bbytes + abytes <= budget


def fused_mlp(x, weights, biases, acts, *, batch_tile: int = 128,
              interpret: bool = True):
    """x: [B, F0]; weights: list of [F_l, F_{l+1}]; acts: per-layer name."""
    B, F0 = x.shape
    n_layers = len(weights)
    Fo = weights[-1].shape[1]
    pb = -B % batch_tile
    xp = jnp.pad(x, ((0, pb), (0, 0)))
    grid = ((B + pb) // batch_tile,)

    in_specs = [pl.BlockSpec((batch_tile, F0), lambda i: (i, 0))]
    args = [xp]
    for w, b in zip(weights, biases):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        args += [w, b]

    out = pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers, acts=tuple(acts)),
        out_shape=jax.ShapeDtypeStruct((B + pb, Fo), x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((batch_tile, Fo), lambda i: (i, 0)),
        interpret=interpret,
    )(*args)
    return out[:B]
