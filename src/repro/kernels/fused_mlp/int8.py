"""Pallas TPU kernel: int8 fused MLP (the quantized serving tier).

Same shape as :mod:`repro.kernels.fused_mlp.fused_mlp` — whole net
resident in VMEM, batch tiled over the grid — but the weight matrices
arrive **statically quantized per output channel** (int8 values + one
f32 scale per column, prepared once at bundle load by
:mod:`repro.quant.quantize`), and each activation tile is **dynamically
quantized per row inside the kernel**: absmax/127 row scales, an
int8 x int8 -> int32 MXU dot, and the rank-1 dequant
(``hs[:, None] * ws[None, :]``) fused straight into the bias+activation
epilogue.  Activations never leave VMEM between layers, and the HBM
traffic the roofline prices — the weights — drops to a quarter of the
f32 kernel's.

Validation tolerance (declared on the spec, consumed by the tuner and
the registry parity tests): the oracle is the int8-*simulating* jnp
path (:func:`repro.quant.quantize.quant_mlp_ref`), not the f32 net —
quantization error is the quant gate's concern, measured against real
calibration rows per bundle, not a kernel-correctness concern.  Kernel
vs oracle differ only where an activation sits exactly on a rounding
boundary and the two paths' f32 rounding pushes it to different int8
steps; one flipped step moves that lane by ``absmax/127``, so the
tolerance is sized to one quantization step of a unit-scale activation
(2/127 ~ 1.6e-2) rather than f32 epsilon.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.kernels import registry
from repro.kernels.fused_mlp.fused_mlp import _ACTS

QMAX = 127.0

DEFAULT_TILE = 128
_TILE_LADDER = (16, 32, 64, 128, 256, 512)

#: one int8 re-quantization step of a unit-scale activation (see module
#: docstring: a borderline round can legitimately differ between the
#: kernel and the simulation oracle)
TOL = (2e-2, 2e-2)


def _kernel(*refs, n_layers, acts):
    x_ref = refs[0]
    o_ref = refs[-1]
    wsb = refs[1:-1]  # per layer: wq (int8), ws (f32), b (f32)
    h = x_ref[...].astype(jnp.float32)
    for l in range(n_layers):
        wq = wsb[3 * l][...]
        ws = wsb[3 * l + 1][...]
        b = wsb[3 * l + 2][...]
        absmax = jnp.max(jnp.abs(h), axis=1, keepdims=True)
        hs = jnp.where(absmax > 0, absmax, 1.0) / QMAX
        hq = jnp.round(h / hs).astype(jnp.int8)
        acc = jnp.dot(hq, wq, preferred_element_type=jnp.int32)
        h = _ACTS[acts[l]](acc.astype(jnp.float32) * hs * ws + b)
    o_ref[...] = h.astype(o_ref.dtype)


def fits_vmem_int8(widths, batch_tile=128, budget=None, act_bytes=4):
    """Per-operand VMEM accounting for one grid step of the int8 kernel.

    Unlike the f32 predicate, tiles are priced at their **own** dtypes:
    int8 weights pad to the (32, 128) int8 register layout (1 byte per
    element), the f32 scale/bias rows to (8, 128), and the activation
    working set counts the f32 tile (in/out, double-buffered), its int8
    quantized twin, and the int32 accumulator.
    """
    from repro.kernels.registry import device_vmem_budget, tile_bytes
    if budget is None:
        budget = device_vmem_budget()
    wbytes = sum(tile_bytes(a, b, 1)
                 for a, b in zip(widths[:-1], widths[1:]))
    sbytes = 2 * sum(tile_bytes(1, b, 4) for b in widths[1:])  # ws + b
    mw = max(widths)
    abytes = (2 * 2 * tile_bytes(batch_tile, mw, act_bytes)  # h in/out x2
              + tile_bytes(batch_tile, mw, 1)                # hq scratch
              + tile_bytes(batch_tile, mw, 4))               # int32 acc
    return wbytes + sbytes + abytes <= budget


def fused_mlp_int8(x, qlayers, acts, *, batch_tile: int = 128,
                   interpret: bool = True):
    """x: [B, F0] float; qlayers: [(wq int8 [Fi,Fo], ws f32 [Fo],
    b f32 [Fo]), ...]; acts: per-layer activation name."""
    B, F0 = x.shape
    n_layers = len(qlayers)
    Fo = qlayers[-1][0].shape[1]
    pb = -B % batch_tile
    xp = jnp.pad(x, ((0, pb), (0, 0)))
    grid = ((B + pb) // batch_tile,)

    in_specs = [pl.BlockSpec((batch_tile, F0), lambda i: (i, 0))]
    args = [xp]
    for wq, ws, b in qlayers:
        in_specs.append(pl.BlockSpec(wq.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(ws.shape, lambda i: (0,)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        args += [wq, ws, b]

    out = pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers, acts=tuple(acts)),
        out_shape=jax.ShapeDtypeStruct((B + pb, Fo), x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((batch_tile, Fo), lambda i: (i, 0)),
        interpret=interpret,
    )(*args)
    return out[:B]


# ----------------------------------------------------------- KernelSpec ----
def _inspect(x, qlayers, acts):
    widths = (int(qlayers[0][0].shape[0]),) + tuple(int(q[0].shape[1])
                                                    for q in qlayers)
    problem = {"widths": widths, "acts": tuple(acts),
               "batch": int(x.shape[0]), "dtype": str(np.dtype(x.dtype))}
    return problem, (x, tuple(tuple(q) for q in qlayers))


def _run(problem, arrays, params, *, interpret):
    x, qlayers = arrays
    return fused_mlp_int8(x, list(qlayers), problem["acts"],
                          batch_tile=params["batch_tile"],
                          interpret=interpret)


def _ref(problem, arrays):
    from repro.quant.quantize import quant_mlp_ref
    x, qlayers = arrays
    return quant_mlp_ref(x, list(qlayers), problem["acts"])


def _make(problem, rng):
    from repro.quant.quantize import quantize_params
    widths, dtype = problem["widths"], problem["dtype"]
    ws = [rng.normal(size=(a, b)).astype(np.float32) * 0.3
          for a, b in zip(widths[:-1], widths[1:])]
    bs = [rng.normal(size=(b,)).astype(np.float32) * 0.1
          for b in widths[1:]]
    x = jnp.asarray(rng.normal(size=(problem["batch"], widths[0]))
                    .astype(np.float32), dtype)
    return (x, tuple(tuple(q) for q in quantize_params(ws, bs)))


def _key(problem, backend):
    from repro.tune.cache import shape_key
    return shape_key(problem["widths"], problem["dtype"], backend,
                     problem["batch"])


def _keys(problem, backend):
    from repro.serve.batcher import bucket_size
    from repro.tune.cache import shape_key
    b = problem["batch"]
    return [shape_key(problem["widths"], problem["dtype"], backend, bb)
            for bb in dict.fromkeys((b, bucket_size(b)))]


def candidate_tiles_int8(widths, bucket, extra=()):
    """Tiles worth sweeping for one bucket under the *int8* VMEM model
    (a net too fat for the f32 kernel can still fit quantized)."""
    tiles = [DEFAULT_TILE]
    for t in _TILE_LADDER + (int(bucket),) + tuple(extra):
        t = int(t)
        if 0 < t <= bucket and t not in tiles:
            tiles.append(t)
    return [t for t in tiles if fits_vmem_int8(widths, t)]


def _cands(problem):
    return [{"batch_tile": t}
            for t in candidate_tiles_int8(problem["widths"],
                                          problem["batch"])]


def _fits(problem, params, budget=None):
    act_bytes = np.dtype(problem["dtype"]).itemsize
    return fits_vmem_int8(problem["widths"], params["batch_tile"],
                          budget=budget, act_bytes=act_bytes)


def _supports(problem):
    return fits_vmem_int8(problem["widths"],
                          act_bytes=np.dtype(problem["dtype"]).itemsize)


SPEC = registry.register(registry.KernelSpec(
    name="fused_mlp_int8",
    params=(registry.TunableParam("batch_tile", DEFAULT_TILE, _TILE_LADDER),),
    inspect=_inspect, run_call=_run, ref_call=_ref, make_call=_make,
    cache_key=_key, cache_keys=_keys, candidates=_cands, fits=_fits,
    supports=_supports, tol=TOL, tier="int8",
    default_problems=(
        {"widths": (5, 128, 128, 1), "acts": ("relu", "relu", "identity"),
         "batch": 256, "dtype": "float32"},
        {"widths": (16, 256, 256, 4), "acts": ("relu", "relu", "identity"),
         "batch": 512, "dtype": "float32"},
    )))


# ------------------------------------------------------------------ ops ----
def fused_mlp_int8_op(x, qlayers, acts, *, force_kernel=False,
                      batch_tile=None):
    problem, arrays = _inspect(x, qlayers, acts)
    return registry.dispatch(SPEC, problem, arrays,
                             force_kernel=force_kernel,
                             overrides={"batch_tile": batch_tile})


def fused_mlp_int8_sharded(x, qlayers, acts, *, mesh, data_axes,
                           force_kernel=False, batch_tile=None):
    """Batch-sharded int8 fused MLP: quantized weights+scales replicate
    (they fit VMEM per chip by the kernel's premise), the batch splits
    over ``data_axes`` — the int8 twin of ``fused_mlp_sharded``."""
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    if n_shards <= 1 or x.shape[0] % n_shards:
        return fused_mlp_int8_op(x, qlayers, acts,
                                 force_kernel=force_kernel,
                                 batch_tile=batch_tile)
    from jax.experimental.shard_map import shard_map
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
    xspec = P(*((ax,) + (None,) * (x.ndim - 1)))

    def local(xs, qs):
        return fused_mlp_int8_op(xs, qs, acts, force_kernel=force_kernel,
                                 batch_tile=batch_tile)

    f = shard_map(local, mesh=mesh, in_specs=(xspec, P()),
                  out_specs=xspec, check_rep=False)
    return f(x, [tuple(q) for q in qlayers])


def fused_mlp_int8_from_spec(spec, qlayers, x, *, mesh=None, data_axes=()):
    """Adapter: run a pure-dense bundle through the int8 kernel using
    pre-quantized layer residency (``InferenceEngine`` quantizes once at
    load; see ``engine._quant_residency``)."""
    from repro.kernels.fused_mlp.ops import mlp_stack_from_spec
    x, _, _, acts = mlp_stack_from_spec(spec, None, x)
    if mesh is not None and data_axes:
        return fused_mlp_int8_sharded(x, qlayers, acts, mesh=mesh,
                                      data_axes=tuple(data_axes))
    return fused_mlp_int8_op(x, qlayers, acts)
