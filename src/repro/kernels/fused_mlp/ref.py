"""Pure-jnp oracle for fused_mlp."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "tanh": jnp.tanh,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda x: x,
}


def fused_mlp_ref(x, weights, biases, acts):
    h = x.astype(jnp.float32)
    for w, b, a in zip(weights, biases, acts):
        h = _ACTS[a](h @ w + b)
    return h.astype(x.dtype)
