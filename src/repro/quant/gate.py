"""Per-bundle accuracy gate for the int8 serving tier.

A quantized variant is never served on speed alone: the bundle must
first pass this gate — RMSE of the int8-*simulated* forward against the
f32 oracle on held-out calibration rows (:mod:`repro.quant.calibrate`),
in physical output units, judged against the **same per-bundle RMSE
budget the shadow scorer alerts on** (:mod:`repro.quant.budgets`).  One
accuracy criterion, two enforcement points: offline before eligibility,
online while serving.

Verdicts persist in the ``quant_gate`` tune-cache namespace
(``artifacts/tune/quant_gate.json``) with the same schema-2 envelope and
atomic-write discipline as kernel sweep results.  The record shape is
chosen so the cache's own resolution rules enforce the gate:

  * a **pass** is ``{"params": {"gated": 1}, "exact": True, ...}`` —
    resolvable by ``best_params`` like any validated winner;
  * a **fail** is ``{"params": {"gated": 0}, "exact": False, ...}`` —
    ``exact=False`` means ``best_params`` can *never* resolve it, the
    same invariant that keeps failed sweep candidates out of dispatch.

Each verdict binds to the bundle's on-disk fingerprint (mtime_ns +
size): retraining the bundle silently un-gates it until re-gated, so a
stale blessing can never quantize fresh weights.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from repro.obs import metrics as _m
from repro.quant.budgets import rmse_budget

#: tune-cache namespace the verdicts persist under
GATE_NAMESPACE = "quant_gate"

_GATE_FAILS = _m.counter(
    "repro_quant_gate_fail_total",
    "quant gate evaluations that failed the RMSE budget", ("bundle",))
_GATE_RMSE = _m.gauge(
    "repro_quant_gate_rmse",
    "observed int8-vs-f32 RMSE at the last gate evaluation", ("bundle",))


def _cache():
    from repro.tune.cache import default_cache
    return default_cache(GATE_NAMESPACE)


def _key(bundle_path) -> str:
    return os.path.abspath(str(bundle_path))


def verdict(bundle_path) -> Optional[dict]:
    """The persisted gate record for a bundle, or None if never gated."""
    return _cache().get(_key(bundle_path))


def gate_passed(bundle_path) -> bool:
    """True iff the bundle holds a *passing* verdict bound to its
    current on-disk fingerprint.  A fail, a missing verdict, or a
    verdict from before the last retrain all answer False — the engine
    treats every False identically: serve f32."""
    rec = verdict(bundle_path)
    if not rec or not rec.get("exact", False):
        return False
    from repro.core.engine import _bundle_mtime
    fp = rec.get("fingerprint")
    return fp is not None and list(fp) == list(_bundle_mtime(str(bundle_path)))


def _forwards(bundle_path, rows, scale_mult: float):
    """(y_f32, y_int8sim) on the calibration rows, both in physical
    units (bundle normalization applied around both paths — the budgets
    are written in output units, not normalized ones)."""
    import jax.numpy as jnp

    from repro.core.engine import bundle_norm
    from repro.kernels.fused_mlp.ops import mlp_stack_from_spec
    from repro.nn.serialize import load_model
    from repro.quant.quantize import quant_mlp_ref, quantize_params

    net, params, spec = load_model(str(bundle_path))
    kinds = {l["kind"] for l in spec["layers"]}
    if not kinds <= {"dense", "act", "flatten"}:
        raise ValueError(f"bundle {bundle_path!s}: int8 tier only covers "
                         f"pure-MLP bundles, found layers {sorted(kinds)}")
    norm = bundle_norm(spec, net)
    x = jnp.asarray(np.asarray(rows, np.float32))
    if norm is not None:
        x = (x - norm[0]) / norm[1]
    y32 = net.apply(params, x)
    xq, weights, biases, acts = mlp_stack_from_spec(spec, params, x)
    qlayers = quantize_params(weights, biases, scale_mult=scale_mult)
    yq = quant_mlp_ref(xq, qlayers, acts)
    if norm is not None:
        y32 = y32 * norm[3] + norm[2]
        yq = yq * norm[3] + norm[2]
    return np.asarray(y32, np.float64), np.asarray(yq, np.float64)


def gate_bundle(bundle_path, rows, *, budget: Optional[float] = None,
                scale_mult: float = 1.0, budget_key: Optional[str] = None,
                extra: Optional[dict] = None) -> dict:
    """Evaluate and persist the gate verdict for one bundle.

    ``rows``: calibration inputs (:func:`repro.quant.calibrate
    .calibration_rows`).  ``budget``: explicit RMSE budget; when None it
    resolves from the shared registry under ``budget_key`` (default: the
    bundle path — the key the shadow scorer uses).  No budget anywhere
    is a configuration error, not a free pass.  ``scale_mult`` feeds
    straight into weight quantization (1.0 = correct absmax
    calibration; the CI fail-path drill passes a wrong one) and is
    recorded in the verdict so the engine serves the exact blessed
    config.  Returns the persisted record.
    """
    key = _key(bundle_path)
    if budget is None:
        budget = rmse_budget(budget_key if budget_key is not None else key)
        if budget is None and budget_key is None:
            budget = rmse_budget(str(bundle_path))
    if budget is None:
        raise ValueError(
            f"no RMSE budget for bundle {bundle_path!s}: pass budget= or "
            f"register one via repro.quant.budgets.set_rmse_budget")
    y32, yq = _forwards(bundle_path, rows, scale_mult)
    rmse = float(np.sqrt(np.mean((yq - y32) ** 2)))
    passed = bool(np.isfinite(rmse)) and rmse <= float(budget)

    from repro.core.engine import InferenceEngine, _bundle_mtime
    rec = {"params": {"gated": int(passed)}, "exact": passed,
           "rmse": rmse, "budget": float(budget),
           "rows": int(np.asarray(rows).shape[0]),
           "scale_mult": float(scale_mult),
           "fingerprint": list(_bundle_mtime(str(bundle_path)))}
    if extra:
        rec.update(extra)
    _cache().put(key, rec)
    _GATE_RMSE.set(rmse, bundle=str(bundle_path))
    if not passed:
        _GATE_FAILS.inc(1, bundle=str(bundle_path))
    # the engine resolves its tier at load: drop the cached engine so
    # the next get() re-reads the fresh verdict
    InferenceEngine.invalidate(str(bundle_path))
    return rec
