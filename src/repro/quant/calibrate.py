"""Calibration-row harvest for the quant gate.

The gate measures quantization error on *real* application inputs, not
synthetic gaussians: rows come from the held-out split of the same
``SurrogateDB`` assimilation data the surrogate was trained on (the
paper's §IV-B collection store), so the RMSE the gate certifies is the
RMSE the shadow scorer will observe online.  The split uses the exact
``train_test_split`` seed/fraction the trainer uses — calibration never
sees training rows, and the gate's verdict is an honest generalization
number, not a memorization one.

:func:`activation_ranges` additionally harvests per-layer activation
absmax over those rows.  The serving kernel re-derives row scales
dynamically per batch (so the ranges are not baked into the bundle),
but the harvested spread is recorded in the gate verdict for
observability: a layer whose calibration absmax dwarfs its median is
the classic outlier-channel failure mode when a gate RMSE comes back
surprising.
"""
from __future__ import annotations

import pathlib
from typing import Dict, List, Union

import numpy as np


def calibration_rows(db, region: str, *, max_rows: int = 2048,
                     test_frac: float = 0.2, seed: int = 0) -> np.ndarray:
    """Held-out input rows for one region: ``[n, in_features]`` f32.

    ``db`` is a :class:`repro.core.database.SurrogateDB` or a path to
    one.  Raises when the region holds no held-out rows — gating
    against an empty calibration set would certify nothing.
    """
    from repro.core.database import SurrogateDB
    if isinstance(db, (str, pathlib.Path)):
        db = SurrogateDB(db)
    store = db.group(region)
    _, held = store.train_test_split(test_frac=test_frac, seed=seed)
    x = np.asarray(held["inputs"], np.float32)
    if x.shape[0] == 0:
        raise ValueError(
            f"region {region!r}: no held-out calibration rows "
            f"(test_frac={test_frac} of {store.name} is empty)")
    return x[:max_rows]


def activation_ranges(bundle_path, rows) -> List[Dict[str, float]]:
    """Per-layer activation absmax stats of the f32 forward over the
    calibration rows: ``[{"absmax", "p50"}, ...]``, one entry per dense
    layer *input* (what the dynamic row quantizer will see at serve
    time).  Pure observability — nothing is baked into the bundle.
    """
    import jax.numpy as jnp

    from repro.core.engine import bundle_norm
    from repro.kernels.fused_mlp.fused_mlp import _ACTS
    from repro.kernels.fused_mlp.ops import mlp_stack_from_spec
    from repro.nn.serialize import load_model

    net, params, spec = load_model(str(bundle_path))
    norm = bundle_norm(spec, net)
    x = jnp.asarray(np.asarray(rows, np.float32))
    if norm is not None:
        x = (x - norm[0]) / norm[1]
    h, weights, biases, acts = mlp_stack_from_spec(spec, params, x)
    stats: List[Dict[str, float]] = []
    for w, b, act in zip(weights, biases, acts):
        row_absmax = np.asarray(jnp.max(jnp.abs(h), axis=1))
        stats.append({"absmax": float(row_absmax.max(initial=0.0)),
                      "p50": float(np.median(row_absmax))
                      if row_absmax.size else 0.0})
        h = _ACTS[act](h @ w + b)
    return stats
