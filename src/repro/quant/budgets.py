"""Shared per-bundle RMSE budget registry.

One process-wide table mapping a bundle key (the serve-queue key: the
bundle path) to its accuracy budget.  Three consumers read it:

  * the **quant gate** (:mod:`repro.quant.gate`): a quantized variant is
    eligible only if its RMSE vs the f32 oracle stays under the budget;
  * the **shadow scorer** (:mod:`repro.obs.quality`): the online drift
    alert criticals past the same number (its own ``set_budget`` still
    wins for keys configured there explicitly);
  * ``serve_bench --shadow-check``: the corruption drill's threshold,
    which used to be a hardcoded constant that could silently diverge
    from the gate's.

Import contract: stdlib only — safe from ``repro.obs.quality`` (which
must stay importable pre-bootstrap) and from anywhere else.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

#: WARN fires at this fraction of the RMSE budget unless overridden
DEFAULT_WARN_RATIO = 0.5

_lock = threading.Lock()
_budgets: Dict[str, Tuple[float, float]] = {}  # key -> (warn_at, crit_at)


def set_rmse_budget(key: str, rmse_budget: float,
                    warn_ratio: float = DEFAULT_WARN_RATIO) -> None:
    """Register ``key``'s accuracy budget: RMSE past ``rmse_budget`` is
    out of budget (gate fail / CRITICAL drift), past ``warn_ratio *
    rmse_budget`` is the WARN band."""
    pair = (float(rmse_budget) * float(warn_ratio), float(rmse_budget))
    with _lock:
        _budgets[str(key)] = pair


def rmse_budget(key: str) -> Optional[float]:
    """The hard RMSE budget for ``key``, or None when unregistered."""
    with _lock:
        pair = _budgets.get(str(key))
    return pair[1] if pair is not None else None


def budget_pair(key: str) -> Optional[Tuple[float, float]]:
    """(warn_at, crit_at) for ``key``, or None when unregistered."""
    with _lock:
        return _budgets.get(str(key))


def clear_budgets() -> None:
    """Forget every registered budget (tests)."""
    with _lock:
        _budgets.clear()
