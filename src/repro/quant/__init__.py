"""Quantized inference tier: int8 kernels behind per-bundle accuracy gates.

The paper's claim is speedup at *minimal accuracy loss*; the roofline
analysis (EXPERIMENTS.md) shows the dominant serving regime is
HBM-bandwidth-bound, so quartering weight bytes is the largest remaining
hot-path lever — but only behind the same accuracy criterion the shadow
scorer enforces online.  The package splits the concern four ways:

  * :mod:`repro.quant.budgets` — the shared per-bundle RMSE budget
    registry (single source for the accuracy criterion: the quant gate,
    the shadow scorer's drift alert, and ``serve_bench --shadow-check``
    all read the same number, so the two accuracy gates cannot drift
    apart);
  * :mod:`repro.quant.quantize` — per-output-channel static weight
    quantization plus the jnp int8-simulation reference paths (the
    oracles the Pallas int8 kernels validate against, and the off-TPU
    serving path);
  * :mod:`repro.quant.calibrate` — calibration rows harvested from
    held-out ``SurrogateDB`` assimilation data;
  * :mod:`repro.quant.gate` — the per-bundle accuracy gate: RMSE of the
    int8-simulated forward vs the f32 oracle on those rows, persisted as
    a verdict in the ``quant_gate`` tune-cache namespace.  Only a gated
    bundle is eligible for the int8 dispatch tier.

Package import stays lazy: ``repro.obs.quality`` imports
:mod:`repro.quant.budgets` (stdlib-only) from its budget-resolution
path, and that must not drag jax in.
"""
from repro.quant.budgets import (budget_pair, clear_budgets, rmse_budget,
                                 set_rmse_budget)

__all__ = ["budget_pair", "clear_budgets", "gate_bundle", "gate_passed",
           "quant_mlp_ref", "quantize_params",
           "quantize_weights_per_channel", "rmse_budget",
           "set_rmse_budget", "verdict"]

_LAZY = {
    "gate_bundle": "repro.quant.gate", "gate_passed": "repro.quant.gate",
    "verdict": "repro.quant.gate",
    "quant_mlp_ref": "repro.quant.quantize",
    "quantize_params": "repro.quant.quantize",
    "quantize_weights_per_channel": "repro.quant.quantize",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.quant' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
