"""Per-channel int8 quantization math, shared by kernel and oracle.

The factoring is chosen so every scale is constant over its dot's
contraction dimension and therefore commutes out of the int32
accumulator exactly:

  * **weights** are quantized statically **per output channel**
    (column j of ``W[in, out]`` gets its own absmax/127 scale): the
    scale varies only along the output axis, never along ``in``;
  * **activations** are quantized dynamically **per row** at serve time
    (each batch row gets absmax/127): the scale varies only along the
    batch axis, never along the feature (contraction) axis.

So ``h @ W == (hs * hq) @ (wq * ws) == (hq @ wq) * hs[:, None] *
ws[None, :]`` up to rounding — one int8 x int8 -> int32 MXU dot plus a
rank-1 f32 dequant folded into the bias+activation epilogue.

Every function here is the *definition* the Pallas kernels must agree
with: :func:`quant_mlp_ref` is the jitted oracle the tuner validates
``fused_mlp_int8`` candidates against, and the engine's off-TPU int8
serving path.  Keep kernel and oracle using the same ops
(``jnp.round`` — round-half-even — and the same zero-row guard) so
interpret-mode parity is tight.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp

from repro.kernels.fused_mlp.fused_mlp import _ACTS

#: symmetric int8: values land in [-127, 127] (x/absmax * 127)
QMAX = 127.0


def quantize_weights_per_channel(w, *, scale_mult: float = 1.0):
    """Static per-output-channel symmetric int8 quantization.

    Returns ``(wq int8 [in, out], ws f32 [out])`` with ``w ~= wq * ws``.
    ``scale_mult`` deliberately mis-scales the calibration (the gate's
    fail-path drill injects a wrong calibration with it); 1.0 is the
    correct absmax calibration.
    """
    w = jnp.asarray(w, jnp.float32)
    absmax = jnp.max(jnp.abs(w), axis=0)
    ws = jnp.where(absmax > 0, absmax, 1.0) / QMAX * float(scale_mult)
    wq = jnp.clip(jnp.round(w / ws), -QMAX, QMAX).astype(jnp.int8)
    return wq, ws


def quantize_rows(h) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dynamic per-row symmetric int8 quantization of an activation
    batch ``h [rows, feat]``: returns ``(hq int8, hs f32 [rows, 1])``.
    A zero row (serve-path padding) quantizes to zeros with scale 1/127,
    never a divide-by-zero."""
    absmax = jnp.max(jnp.abs(h), axis=1, keepdims=True)
    hs = jnp.where(absmax > 0, absmax, 1.0) / QMAX
    hq = jnp.round(h / hs).astype(jnp.int8)
    return hq, hs


def quantize_kv(k, v):
    """int8 KV-cache quantization for the flash-attention int8 path.

    K is quantized **per token** (axis -1 absmax per [b, s, kv] token:
    the score dot contracts over head_dim, so the scale must be constant
    along it); V **per channel** (head_dim column: the p@v dot contracts
    over tokens).  Returns ``(kq, ks [B,Skv,KV,1], vq, vs [B,1,KV,hd])``.
    """
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    kmax = jnp.max(jnp.abs(k), axis=-1, keepdims=True)
    ks = jnp.where(kmax > 0, kmax, 1.0) / QMAX
    kq = jnp.round(k / ks).astype(jnp.int8)
    vmax = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    vs = jnp.where(vmax > 0, vmax, 1.0) / QMAX
    vq = jnp.round(v / vs).astype(jnp.int8)
    return kq, ks, vq, vs


def quantize_params(weights: Sequence, biases: Sequence, *,
                    scale_mult: float = 1.0):
    """Quantize a fused-MLP layer stack: per-layer ``(wq, ws, b_f32)``.

    Biases stay f32 — they add into the dequantized epilogue, and at
    <= 4096 floats per layer their bytes are noise next to the weights.
    """
    out: List[tuple] = []
    for w, b in zip(weights, biases):
        wq, ws = quantize_weights_per_channel(w, scale_mult=scale_mult)
        out.append((wq, ws, jnp.asarray(b, jnp.float32)))
    return out


def qdot(hq, hs, wq, ws):
    """One dequantized int8 layer dot: int8 x int8 -> int32 accumulate,
    then the rank-1 (row scale x channel scale) f32 dequant."""
    acc = jnp.dot(hq, wq, preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * hs * ws


def quant_mlp_ref(x, qlayers, acts):
    """int8-simulating fused-MLP forward (the f32-activation-flow twin
    of the ``fused_mlp_int8`` Pallas kernel; also the off-TPU serving
    path for gated bundles).  ``qlayers``: [(wq, ws, b), ...]."""
    h = jnp.asarray(x, jnp.float32)
    for (wq, ws, b), act in zip(qlayers, acts):
        hq, hs = quantize_rows(h)
        h = _ACTS[act](qdot(hq, hs, wq, ws) + b)
    return h.astype(x.dtype)
