from repro.core.database import SurrogateDB
from repro.core.engine import InferenceEngine
from repro.core.functor import (SSlice, SymExpr, TensorFunctor, sym,
                                tensor_functor)
from repro.core.region import MLRegion, approx_ml
from repro.core.tensor_map import TensorMap
