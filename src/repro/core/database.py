"""SurrogateDB: the data-collection store (paper §IV-B).

HDF5 is unavailable offline, so the store is an npz-chunk directory that
keeps HDF5's group/dataset semantics: one *group* per annotated region,
holding three datasets — ``inputs`` (bridged input tensors), ``outputs``
(bridged output tensors) and ``runtime`` (wall time of the accurate path
per invocation, used by the NAS stage to price performance/accuracy
trade-offs without re-running the application).

Layout:
    <root>/<region>/meta.json
    <root>/<region>/chunk_00000.npz   (inputs, outputs, runtime arrays)
"""
from __future__ import annotations

import atexit
import json
import pathlib
import threading
import weakref

import numpy as np

# collect-mode rows buffered below chunk_rows must never be lost to process
# exit: every live store flushes at interpreter shutdown
_LIVE_STORES: "weakref.WeakSet[RegionStore]" = weakref.WeakSet()


@atexit.register
def _flush_all_at_exit():
    for store in list(_LIVE_STORES):
        try:
            store.flush()
        except Exception:
            pass  # shutdown best-effort; a partial flush must not mask exit


class RegionStore:
    def __init__(self, root: pathlib.Path, name: str, chunk_rows: int = 4096):
        self.dir = root / name
        self.dir.mkdir(parents=True, exist_ok=True)
        self.name = name
        self.chunk_rows = chunk_rows
        self._buf_in, self._buf_out, self._buf_rt = [], [], []
        self._lock = threading.Lock()
        _LIVE_STORES.add(self)

    # -------------------------------------------------------- writing -----
    def append(self, inputs, outputs, runtime: float):
        """Append one invocation's bridged tensors (leading dim = batch)."""
        with self._lock:
            self._buf_in.append(np.asarray(inputs))
            self._buf_out.append(np.asarray(outputs))
            self._buf_rt.append(float(runtime))
            if sum(x.shape[0] for x in self._buf_in) >= self.chunk_rows:
                self._flush_locked()

    def flush(self):
        with self._lock:
            if self._buf_in:
                self._flush_locked()

    def _flush_locked(self):
        existing = sorted(self.dir.glob("chunk_*.npz"))
        idx = len(existing)
        inputs = np.concatenate(self._buf_in, axis=0)
        outputs = np.concatenate(self._buf_out, axis=0)
        in_shape, out_shape = list(inputs.shape[1:]), list(outputs.shape[1:])

        # meta.json describes the FULL store, not just the last flush
        meta_path = self.dir / "meta.json"
        prior = json.loads(meta_path.read_text()) if meta_path.exists() \
            else None
        if prior is not None:
            # schema drift is refused BEFORE anything touches disk: the
            # mismatched buffer is dropped so retries (and the atexit
            # flush) cannot corrupt or duplicate the store
            for key, shape in (("input_shape", in_shape),
                               ("output_shape", out_shape)):
                if prior.get(key) is not None and prior[key] != shape:
                    self._buf_in, self._buf_out, self._buf_rt = [], [], []
                    raise ValueError(
                        f"region {self.name!r}: {key} changed from "
                        f"{prior[key]} to {shape}; refusing to mix schemas")
        rows = int(inputs.shape[0])
        if prior is not None and "rows" in prior:
            rows += int(prior["rows"])
        else:  # legacy store without row accounting: scan once
            for c in existing:
                with np.load(c) as z:
                    rows += int(z["inputs"].shape[0])

        np.savez(
            self.dir / f"chunk_{idx:05d}.npz",
            inputs=inputs,
            outputs=outputs,
            runtime=np.asarray(self._buf_rt, np.float64),
        )
        meta = {"region": self.name, "chunks": idx + 1, "rows": rows,
                "input_shape": in_shape, "output_shape": out_shape}
        meta_path.write_text(json.dumps(meta))
        self._buf_in, self._buf_out, self._buf_rt = [], [], []

    # -------------------------------------------------------- reading -----
    def load(self):
        """Returns dict(inputs, outputs, runtime) stacked over all chunks."""
        self.flush()
        chunks = sorted(self.dir.glob("chunk_*.npz"))
        if not chunks:
            raise FileNotFoundError(f"no data collected for region "
                                    f"{self.name!r} in {self.dir}")
        ins, outs, rts = [], [], []
        for c in chunks:
            z = np.load(c)
            ins.append(z["inputs"])
            outs.append(z["outputs"])
            rts.append(z["runtime"])
        return {"inputs": np.concatenate(ins), "outputs": np.concatenate(outs),
                "runtime": np.concatenate(rts)}

    def train_test_split(self, test_frac=0.2, seed=0):
        d = self.load()
        n = d["inputs"].shape[0]
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        cut = int(n * (1 - test_frac))
        tr, te = perm[:cut], perm[cut:]
        return ({"inputs": d["inputs"][tr], "outputs": d["outputs"][tr]},
                {"inputs": d["inputs"][te], "outputs": d["outputs"][te]})


class SurrogateDB:
    def __init__(self, path):
        self.root = pathlib.Path(path)
        self.root.mkdir(parents=True, exist_ok=True)
        self._groups = {}

    def group(self, name: str) -> RegionStore:
        if name not in self._groups:
            self._groups[name] = RegionStore(self.root, name)
        return self._groups[name]

    def groups(self):
        return [p.name for p in self.root.iterdir() if p.is_dir()]

    def flush(self):
        for g in self._groups.values():
            g.flush()
