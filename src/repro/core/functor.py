"""Tensor functor: HPAC-ML's symbolic slice DSL (paper Fig. 3, top).

A functor declares, for symbolic sweep coordinates (s-constants), how
application-memory elements form one tensor entry:

    ifn = tensor_functor("ifnctr: [i, j, 0:5] = ([i-1,j],[i+1,j],[i,j-1:j+2])")

The string grammar mirrors the paper's pragma:
    ss-specifier ::= '[' s-slice, ... ']'
    s-slice      ::= s-expr [ ':' [s-expr] [ ':' [s-expr] ] ]
    s-expr       ::= s-constant | int | s-expr ('+'|'-'|'*') s-expr

Functors can also be built programmatically from ``sym`` objects.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Sequence, Union


# ------------------------------ symbolic expressions -----------------------
@dataclass(frozen=True)
class SymExpr:
    """affine expression: sum_i coeff[s_i] * s_i + const"""
    coeffs: tuple  # tuple[(name, coeff), ...] sorted
    const: int = 0

    @staticmethod
    def of(x) -> "SymExpr":
        if isinstance(x, SymExpr):
            return x
        if isinstance(x, int):
            return SymExpr((), x)
        raise TypeError(x)

    def __add__(self, o):
        o = SymExpr.of(o)
        d = dict(self.coeffs)
        for n, c in o.coeffs:
            d[n] = d.get(n, 0) + c
        return SymExpr(tuple(sorted((n, c) for n, c in d.items() if c)),
                       self.const + o.const)

    def __radd__(self, o):
        return self.__add__(o)

    def __sub__(self, o):
        return self + SymExpr.of(o) * -1

    def __rsub__(self, o):
        return SymExpr.of(o) + self * -1

    def __mul__(self, k: int):
        if isinstance(k, SymExpr):
            if k.coeffs and self.coeffs:
                raise ValueError("non-affine symbolic expression")
            if k.coeffs:  # constant * symbol
                return k * self.const
            k = k.const
        return SymExpr(tuple((n, c * k) for n, c in self.coeffs),
                       self.const * k)

    __rmul__ = __mul__

    @property
    def symbols(self):
        return tuple(n for n, _ in self.coeffs)

    def evaluate(self, env: dict) -> int:
        return self.const + sum(c * env[n] for n, c in self.coeffs)

    def __repr__(self):
        parts = [f"{'' if c == 1 else c}{n}" for n, c in self.coeffs]
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts).replace("+-", "-")


def sym(name: str) -> SymExpr:
    """An s-constant: a placeholder concretized when the functor is mapped."""
    return SymExpr(((name, 1),), 0)


@dataclass(frozen=True)
class SSlice:
    """One s-slice: a point (stop None) or a [start:stop:step) range."""
    start: SymExpr
    stop: Optional[SymExpr] = None
    step: int = 1

    @property
    def is_point(self):
        return self.stop is None

    def n_elements(self) -> int:
        """Static element count (start/stop must differ by a constant)."""
        if self.is_point:
            return 1
        diff = self.stop - self.start
        if diff.coeffs:
            raise ValueError(f"slice extent must be constant, got {diff}")
        return max(0, -(-diff.const // self.step))


def _as_sslice(x) -> SSlice:
    if isinstance(x, SSlice):
        return x
    if isinstance(x, slice):
        return SSlice(SymExpr.of(x.start if x.start is not None else 0),
                      SymExpr.of(x.stop) if x.stop is not None else None,
                      x.step if x.step is not None else 1)
    return SSlice(SymExpr.of(x))


# ------------------------------ grammar parser -----------------------------
_TOK = re.compile(r"\s*(\d+|[A-Za-z_]\w*|[\[\]():,+\-*=])")


def _tokens(s: str):
    out, i = [], 0
    while i < len(s):
        m = _TOK.match(s, i)
        if not m:
            raise SyntaxError(f"bad functor syntax at: {s[i:i+20]!r}")
        out.append(m.group(1))
        i = m.end()
    return out


class _Parser:
    def __init__(self, toks):
        self.toks, self.i = toks, 0

    def peek(self):
        return self.toks[self.i] if self.i < len(self.toks) else None

    def eat(self, t=None):
        cur = self.peek()
        if t is not None and cur != t:
            raise SyntaxError(f"expected {t!r}, got {cur!r}")
        self.i += 1
        return cur

    def expr(self):
        # term (('+'|'-') term)*
        e = self.term()
        while self.peek() in ("+", "-"):
            op = self.eat()
            t = self.term()
            e = e + t if op == "+" else e - t
        return e

    def term(self):
        f = self.factor()
        while self.peek() == "*":
            self.eat()
            g = self.factor()
            e = f * g if isinstance(g, (int, SymExpr)) else None
            f = e
        return f

    def factor(self):
        t = self.peek()
        if t == "-":
            self.eat()
            return self.factor() * -1
        if t == "(":
            self.eat("(")
            e = self.expr()
            self.eat(")")
            return e
        self.eat()
        if t.isdigit():
            return SymExpr.of(int(t))
        return sym(t)

    def sslice(self):
        start = self.expr()
        stop, step = None, 1
        if self.peek() == ":":
            self.eat()
            stop = self.expr()
            if self.peek() == ":":
                self.eat()
                step = self.expr().const
        return SSlice(start, stop, step)

    def ss_specifier(self):
        self.eat("[")
        slices = [self.sslice()]
        while self.peek() == ",":
            self.eat()
            slices.append(self.sslice())
        self.eat("]")
        return tuple(slices)


@dataclass(frozen=True)
class TensorFunctor:
    """LHS shape spec + RHS element-access slices (paper §III-B)."""
    name: str
    lhs: tuple  # tuple[SSlice]
    rhs: tuple  # tuple[tuple[SSlice]]

    @property
    def sweep_symbols(self):
        """Symbols defining the sweep (point slices of the LHS)."""
        out = []
        for s in self.lhs:
            for n in s.start.symbols:
                if n not in out:
                    out.append(n)
            if s.stop is not None:
                for n in s.stop.symbols:
                    if n not in out:
                        out.append(n)
        return tuple(out)

    @property
    def n_features(self):
        return sum(_slice_elems(sl) for sl in self.rhs)

    def map(self, array, ranges, direction="to"):
        from repro.core.tensor_map import TensorMap
        return TensorMap(self, array, ranges, direction)

    def __repr__(self):
        return f"TensorFunctor({self.name}: {list(self.lhs)} = {list(self.rhs)})"


def _slice_elems(slice_group: Sequence[SSlice]) -> int:
    n = 1
    for s in slice_group:
        n *= s.n_elements()
    return n


def tensor_functor(decl: Union[str, None] = None, *, name=None, lhs=None,
                   rhs=None) -> TensorFunctor:
    """Declare a functor from the pragma-style string or from DSL objects.

    String form:  "name: [i, j, 0:5] = ([i-1,j], [i+1,j], [i,j-1:j+2])"
    """
    if decl is not None:
        head, _, body = decl.partition(":")
        name = head.strip()
        lhs_s, _, rhs_s = body.partition("=")
        p = _Parser(_tokens(lhs_s.strip()))
        lhs_t = p.ss_specifier()
        p = _Parser(_tokens(rhs_s.strip()))
        p.eat("(")
        groups = [p.ss_specifier()]
        while p.peek() == ",":
            p.eat()
            groups.append(p.ss_specifier())
        p.eat(")")
        return TensorFunctor(name, lhs_t, tuple(groups))
    lhs_t = tuple(_as_sslice(s) for s in lhs)
    rhs_t = tuple(tuple(_as_sslice(s) for s in grp) for grp in rhs)
    return TensorFunctor(name or "functor", lhs_t, rhs_t)
