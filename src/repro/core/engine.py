"""Inference engine: loads a model bundle once, jit-compiles its apply, and
serves region invocations (the Torch-C++ role in the paper's runtime).

Supports sharded inference: with a mesh installed, inputs are constrained
over the ``data`` axis, so surrogate batches scale across chips like any
other data-parallel workload.  On TPU the engine routes pure-MLP bundles
through the ``fused_mlp`` Pallas kernel (all layers resident in VMEM —
the paper's Observation 2, hardware-utilization, reinterpreted for TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.nn.serialize import load_model


class InferenceEngine:
    _cache: dict = {}

    def __init__(self, model_path: str, use_kernel: str = "auto"):
        self.path = str(model_path)
        self.net, self.params, self.spec = load_model(model_path)
        self.use_kernel = use_kernel
        self._apply = None

    @classmethod
    def get(cls, model_path) -> "InferenceEngine":
        """Process-wide cache: a model file is loaded once (paper §IV-B)."""
        key = str(model_path)
        if key not in cls._cache:
            cls._cache[key] = cls(key)
        return cls._cache[key]

    def _is_pure_mlp(self):
        kinds = [l["kind"] for l in self.spec["layers"]]
        return all(k in ("dense", "act", "flatten") for k in kinds)

    def _build(self):
        net = self.net
        extra = self.spec.get("extra") or {}
        norm = None
        if "x_mu" in extra:
            import numpy as np
            ish = tuple(self.spec["in_shape"][1:])
            osh = tuple(net.out_shape()[1:])
            norm = tuple(jnp.asarray(np.asarray(extra[k], np.float32)
                                     .reshape(s))
                         for k, s in (("x_mu", ish), ("x_sd", ish),
                                      ("y_mu", osh), ("y_sd", osh)))

        if self.use_kernel != "never" and self._is_pure_mlp() and \
                jax.default_backend() == "tpu":
            from repro.kernels.fused_mlp import ops as fused_ops

            def raw(params, x):
                return fused_ops.fused_mlp_from_spec(self.spec, params, x)
        else:
            def raw(params, x):
                return net.apply(params, x)

        def apply_fn(params, x):
            x = constrain(x, "data", None)
            if norm is not None:
                x = (x - norm[0]) / norm[1]
            y = raw(params, x)
            if norm is not None:
                y = y * norm[3] + norm[2]
            return y

        self._apply = jax.jit(apply_fn)

    def __call__(self, x):
        if self._apply is None:
            self._build()
        return self._apply(self.params, x)

    def infer_shape(self, in_shape):
        return self.net.out_shape()
